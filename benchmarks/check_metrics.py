"""CI gate on the exported telemetry schema (ISSUE-8).

Reads the ``metrics.jsonl`` snapshots written by live smoke runs
(``launch.train --metrics-dir`` / ``launch.serve --metrics-dir``) and
fails when the exported metric set drifts from the documented schema
(``repro/obs/schema.py`` -- the same table the README renders):

  * a documented family missing from every artifact: an instrumented
    call site was deleted (or the exporter broke) without updating the
    schema, so dashboards silently go dark;
  * a ``smoke_required`` family with zero samples across all artifacts:
    the family is still registered but nothing feeds it -- dead
    telemetry that looks alive in ``/metrics``;
  * an exported family absent from the schema: undocumented telemetry
    that the README and this gate cannot vouch for (the strictness cuts
    both ways);
  * fewer than 25 distinct documented families sampled, or any of the
    four layers (train / serving / kernel / chaos) entirely unsampled --
    the ISSUE-8 acceptance floor for the CI smoke.

Usage: PYTHONPATH=src python -m benchmarks.check_metrics DIR [DIR ...]
(each DIR holds a ``metrics.jsonl``; the LAST snapshot line per file is
the end-of-run state).
"""
from __future__ import annotations

import json
import os
import sys

from repro.obs import schema

MIN_SAMPLED_FAMILIES = 25


def load_samples(directory: str) -> dict:
    """{family name: sample count} from the newest snapshot in
    ``DIR/metrics.jsonl``."""
    path = os.path.join(directory, "metrics.jsonl")
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        raise SystemExit(f"check_metrics: {path} is empty")
    snap = json.loads(lines[-1])
    return {m["name"]: len(m["samples"]) for m in snap["metrics"]}


def check(dirs) -> int:
    merged: dict = {}
    for d in dirs:
        for name, n in load_samples(d).items():
            merged[name] = merged.get(name, 0) + n

    problems = []
    for name, spec in schema.SPECS.items():
        if name not in merged:
            problems.append(f"documented family {name!r} missing from "
                            f"every artifact")
        elif spec.smoke_required and merged[name] == 0:
            problems.append(f"family {name!r} is smoke_required but has "
                            f"no samples")
    for name in sorted(merged):
        if name not in schema.SPECS:
            problems.append(f"exported family {name!r} is not in the "
                            f"documented schema (repro/obs/schema.py)")

    sampled = {n for n, c in merged.items() if c and n in schema.SPECS}
    if len(sampled) < MIN_SAMPLED_FAMILIES:
        problems.append(f"only {len(sampled)} documented families carry "
                        f"samples (floor: {MIN_SAMPLED_FAMILIES})")
    for layer in schema.LAYERS:
        if not any(schema.SPECS[n].layer == layer for n in sampled):
            problems.append(f"no sampled family from the {layer!r} layer")

    for p in problems:
        print(f"check_metrics: {p}", file=sys.stderr)
    print(f"check_metrics: {len(schema.SPECS)} documented families, "
          f"{len(sampled)} sampled across {len(dirs)} artifact dir(s), "
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        raise SystemExit("usage: python -m benchmarks.check_metrics "
                         "DIR [DIR ...]")
    raise SystemExit(check(argv))


if __name__ == "__main__":
    main()
