"""CI gate on the exported telemetry schema (ISSUE-8).

Reads the ``metrics.jsonl`` snapshots written by live smoke runs
(``launch.train --metrics-dir`` / ``launch.serve --metrics-dir``) and
fails when the exported metric set drifts from the documented schema
(``repro/obs/schema.py`` -- the same table the README renders): missing
documented families, unsampled ``smoke_required`` families, undocumented
exports, or the ISSUE-8 coverage floor (>= 25 sampled families spanning
all four layers) not met.

Since ISSUE-9 the detector is the ``metrics-schema`` rule of
``repro.analysis`` (also run by ``python -m repro.analysis
--metrics-dir``); this wrapper keeps the historical CLI and exit codes.

Usage: PYTHONPATH=src python -m benchmarks.check_metrics DIR [DIR ...]
(each DIR holds a ``metrics.jsonl``; the LAST snapshot line per file is
the end-of-run state).
"""
from __future__ import annotations

import json
import os
import sys


def load_samples(directory: str) -> dict:
    """{family name: sample count} from the newest snapshot in
    ``DIR/metrics.jsonl``."""
    path = os.path.join(directory, "metrics.jsonl")
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        raise SystemExit(f"check_metrics: {path} is empty")
    snap = json.loads(lines[-1])
    return {m["name"]: len(m["samples"]) for m in snap["metrics"]}


def check(dirs) -> int:
    from repro.analysis import core
    core._load_shipped()
    merged: dict = {}
    for d in dirs:
        for name, n in load_samples(d).items():
            merged[name] = merged.get(name, 0) + n
    report = core.run_layer("metrics", [core.MetricsExport(merged)])
    for f in report.findings:
        print(f"check_metrics: {f.message}", file=sys.stderr)
    from repro.obs import schema
    sampled = {n for n, c in merged.items() if c and n in schema.SPECS}
    print(f"check_metrics: {len(schema.SPECS)} documented families, "
          f"{len(sampled)} sampled across {len(dirs)} artifact dir(s), "
          f"{len(report.findings)} problem(s)")
    return 1 if report.findings else 0


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        raise SystemExit("usage: python -m benchmarks.check_metrics "
                         "DIR [DIR ...]")
    raise SystemExit(check(argv))


if __name__ == "__main__":
    main()
