"""Per-method forward-cost comparison at one fixed model shape -- the
paper's Table 1/2 cost story tracked across every REGISTERED adapter
method, not just the two the paper plots.

Emits, for each method with params (oftv2 / oftv1 / lora / hoft / ...):

  method/<kind>/fwd          median us/call of the adapted linear forward
                             (derived: trainable params + fusion mode)
  fusion_plan/method/<kind>/<mode>/expect_<mode>
                             the mode the dispatcher ACTUALLY picked for
                             methods declaring fused kernels -- gated by
                             benchmarks/check_fusion.py like every other
                             fusion-plan row, so a silent fallback of e.g.
                             the HOFT fused path fails CI.

The method list comes from the registry, so a newly registered method
shows up in the bench (and the CI smoke) for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_jit
from repro import methods
from repro.config.base import AdapterConfig, QuantConfig
from repro.core import adapter as ad

D_IN, D_OUT, TOKENS = 512, 512, 2048


def _acfg(kind: str, fused: bool) -> AdapterConfig:
    return AdapterConfig(kind=kind, block_size=32, neumann_terms=5, rank=16,
                         reflections=8, fuse_linear=fused)


def run():
    rows = []
    qcfg = QuantConfig(kind="none")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (TOKENS, D_IN))
    w = jax.random.normal(jax.random.fold_in(key, 1), (D_IN, D_OUT)) / 22.6

    for kind in methods.available():
        method = methods.get(kind)
        if not method.has_params:
            continue
        fused = method.supports_fused_forward
        acfg = _acfg(kind, fused)
        adp = ad.adapter_init(jax.random.fold_in(key, 2), "q", D_IN, D_OUT,
                              acfg)
        fn = jax.jit(lambda xx, ww, aa, _acfg=acfg: ad.adapted_linear(
            xx, {"w": ww}, aa, _acfg, qcfg))
        us = time_jit(fn, x, w, adp)
        mode = ad.fusion_mode(acfg, qcfg, ("w",))
        rows.append((f"method/{kind}/fwd", us,
                     f"params={ad.adapter_param_count('q', D_IN, D_OUT, acfg)};"
                     f"mode={mode};tokens={TOKENS};d={D_IN}x{D_OUT}"))
        if fused:
            # check_fusion-gated: a method declaring supports_fused_forward
            # must actually get a fused mode from the dispatcher
            got = "fused" if mode != "unfused" else "unfused"
            rows.append((f"fusion_plan/method/{kind}/expect_fused", 0.0,
                         f"got={got};mode={mode}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
