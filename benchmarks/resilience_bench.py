"""Resilience acceptance numbers (ISSUE-7): recovery cost and degradation
overhead, gated in CI by check_fusion's generic ``expect_ge`` machinery.

Rows:
  resilience/save_restore_roundtrip        -- checkpoint save + checksum-
     verified restore of a real train state (wall us)
  resilience/resume_parity/expect_ge_1.0   -- ratio=1.0 iff a preempted +
     resumed run's stitched loss trajectory equals the uninterrupted
     run's step-for-step (allclose); anything else fails the gate
  resilience/requeue_throughput/expect_ge_0.2 -- paged-engine tok/s with
     the chaos harness seizing most KV blocks mid-flight (forcing
     preempt -> requeue -> prefix-cached retry), relative to the same
     traffic unpressured; the gate bounds graceful degradation at 5x
     (tokens_dropped must be 0 -- degradation sheds SPEED, never tokens)
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks import common


def _train_setup(tmp, steps):
    from repro.config.base import (AdapterConfig, ModelConfig,
                                   ParallelConfig, QuantConfig, RunConfig,
                                   TrainConfig)
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import SyntheticSpec
    from repro.models import build
    cfg = ModelConfig(name="resil", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4),
        quant=QuantConfig(kind="none"),
        parallel=ParallelConfig(),
        train=TrainConfig(global_batch=8, seq_len=32, steps=steps,
                          learning_rate=4e-3, warmup_steps=2,
                          ckpt_every=steps, ckpt_keep=2, log_every=0,
                          ckpt_dir=tmp))

    def loader():
        return ShardedLoader(SyntheticSpec(vocab_size=cfg.vocab_size,
                                           seq_len=32, noise=0.05),
                             global_batch=8, seed=0)

    return build(run), run, loader


def _recovery_rows():
    from repro.checkpoint.manager import CheckpointManager
    from repro.distributed.chaos import FaultEvent, FaultSchedule
    from repro.distributed.fault import PreemptionGuard
    from repro.train.loop import run_training

    steps = 6 if common.SMOKE else 16
    quiet = lambda s: None                                 # noqa: E731

    model, run_f, loader = _train_setup(tempfile.mkdtemp(), steps)
    full = run_training(model, run_f, loader(), log=quiet)["losses"]

    ck = tempfile.mkdtemp()
    model_c, run_c, loader_c = _train_setup(ck, steps)
    mgr = CheckpointManager(ck, keep=2, async_save=False)
    chaos = FaultSchedule([FaultEvent(steps // 2, "preempt")])
    out1 = run_training(model_c, run_c, loader_c(), manager=mgr,
                        guard=PreemptionGuard(install=False), chaos=chaos,
                        log=quiet)

    # save + checksum-verified restore round trip of the preempted state
    # (a scratch manager: writing into `mgr` would advance latest_step and
    # sabotage the resume measured below)
    scratch = CheckpointManager(tempfile.mkdtemp(), keep=1,
                                async_save=False)
    t0 = time.perf_counter()
    scratch.save(1, out1["state"], metadata={"step": 1})
    restored, _ = scratch.restore(1, like=out1["state"])
    roundtrip_us = (time.perf_counter() - t0) * 1e6
    n_leaves = len(jax.tree_util.tree_leaves(restored))

    t0 = time.perf_counter()
    out2 = run_training(model_c, run_c, loader_c(), manager=mgr,
                        guard=PreemptionGuard(install=False), log=quiet)
    resume_us = (time.perf_counter() - t0) * 1e6
    stitched = out1["losses"] + out2["losses"]
    parity = float(np.allclose(stitched, full, rtol=1e-5, atol=1e-6))
    return [
        ("resilience/save_restore_roundtrip", roundtrip_us,
         f"leaves={n_leaves}"),
        ("resilience/resume_parity/expect_ge_1.0", resume_us,
         f"ratio={parity:.2f};steps={steps}"),
    ]


def _requeue_rows():
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig)
    from repro.models import build
    from repro.serving import (AdapterPool, Request, SamplingParams,
                               ServingEngine, init_adapters)

    cfg = ModelConfig(name="resil-serve", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="none"))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    pool = AdapterPool(model)
    for i, tree in enumerate(init_adapters(model, 2, jax.random.PRNGKey(7))):
        pool.register(f"t{i}", tree)

    # gen is NOT reduced under --smoke: with fewer than 8 new tokens no
    # request ever needs a 4th block and the seize would exert no pressure
    # (preemptions=0 would make the row meaningless)
    gen = 8

    def reqs():
        key = jax.random.PRNGKey(3)
        return [Request(f"r{i}", np.asarray(jax.random.randint(
                    jax.random.fold_in(key, i), (8,), 0, cfg.vocab_size)),
                    adapter_id=i % 2,
                    sampling=SamplingParams(max_new_tokens=gen))
                for i in range(4)]

    def engine():
        return ServingEngine(model, params, pool, n_slots=4, mode="paged",
                             page_size=4, prefill_chunk=8, num_blocks=24)

    # warm (compile) + unpressured baseline
    engine().run(reqs())
    eng = engine()
    t0 = time.perf_counter()
    base = eng.run(reqs())
    base_dt = time.perf_counter() - t0
    base_tokens = sum(len(t) for t in base.values())

    # same traffic, chaos seizing most of the pool mid-flight
    eng = engine()
    for r in reqs():
        eng.submit(r)
    results = {}
    t0 = time.perf_counter()
    for _ in range(2):
        for res in eng.step():
            results[res.rid] = res
    eng.kv.seize(10 ** 6)
    for _ in range(4):
        for res in eng.step():
            results[res.rid] = res
    eng.kv.release_seized()
    results.update(eng.drain())
    press_dt = time.perf_counter() - t0
    press_tokens = sum(r.n_generated for r in results.values())

    dropped = base_tokens - press_tokens
    ratio = (press_tokens / press_dt) / (base_tokens / base_dt)
    h = eng.health()["counters"]
    return [("resilience/requeue_throughput/expect_ge_0.2",
             press_dt * 1e6,
             f"ratio={ratio:.2f};tokens_dropped={dropped};"
             f"preemptions={h['preemptions']};retries={h['retries']}")]


def run():
    return _recovery_rows() + _requeue_rows()


if __name__ == "__main__":
    common.emit(run())
