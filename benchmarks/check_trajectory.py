"""CI gate on the committed benchmark trajectory (ROADMAP item 5:
"bench rows vanish with each CI run").

``BENCH_<pr>.json`` files at the repo root are committed snapshots of
``benchmarks/run.py --smoke --json`` -- one per PR that changed what the
suite emits.  This script compares a fresh run's report against the
NEWEST committed snapshot and fails when a row NAME disappeared: a
renamed or dropped row silently breaks the cross-PR trajectory (numbers
are expected to drift between machines and are not compared).

Usage: python -m benchmarks.check_trajectory <fresh.json> [repo_root]
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path


def newest_snapshot(root: Path):
    """(path, pr_number) of the highest-numbered BENCH_<n>.json, or
    (None, None) when no trajectory has been committed yet."""
    best, best_n = None, -1
    for p in root.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", p.name)
        if m and int(m.group(1)) > best_n:
            best, best_n = p, int(m.group(1))
    return best, (best_n if best else None)


def check(fresh_rows, snap_rows, snap_name: str) -> int:
    fresh = {r["name"] for r in fresh_rows}
    snap = {r["name"] for r in snap_rows}
    missing = sorted(snap - fresh)
    for name in missing:
        print(f"check_trajectory: row {name!r} is in {snap_name} but the "
              "fresh run no longer emits it", file=sys.stderr)
    new = sorted(fresh - snap)
    print(f"check_trajectory: {len(snap)} snapshot rows ({snap_name}); "
          f"{len(missing)} vanished, {len(new)} new")
    if new:
        print("check_trajectory: new rows (commit an updated BENCH_<pr>."
              f"json next time the suite changes): {new[:10]}"
              f"{' ...' if len(new) > 10 else ''}")
    return 1 if missing else 0


def main() -> None:
    if len(sys.argv) not in (2, 3):
        print("usage: check_trajectory.py <fresh.json> [repo_root]",
              file=sys.stderr)
        sys.exit(2)
    root = Path(sys.argv[2]) if len(sys.argv) == 3 else Path(".")
    snap_path, _ = newest_snapshot(root)
    if snap_path is None:
        print("check_trajectory: no BENCH_*.json snapshot committed -- "
              "nothing to compare", file=sys.stderr)
        sys.exit(1)
    with open(sys.argv[1]) as f:
        fresh_rows = json.load(f)
    with snap_path.open() as f:
        snap_rows = json.load(f)
    sys.exit(check(fresh_rows, snap_rows, snap_path.name))


if __name__ == "__main__":
    main()
