"""Paper §4 (QOFT vs QLoRA requantization): merge trained-ish adapters back
into the base weight, NF4-requantize, and measure dynamic-range shift +
requant error. The paper's claim: orthogonal merges preserve column norms
exactly and perturb the dynamic range less than low-rank additive merges."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config.base import AdapterConfig, QuantConfig
from repro.core import lora as lora_lib
from repro.core import merging, skew
from repro.core.adapter import merge_adapter


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    qcfg = QuantConfig(kind="nf4", block_size=64, double_quant=False)
    for d, n in [(512, 512), (1024, 4096)]:
        kw, kq, ka, kb = jax.random.split(jax.random.fold_in(key, d), 4)
        w = 0.02 * jax.random.normal(kw, (d, n))
        # "trained" adapters: non-trivial magnitudes
        # scale keeps ||Q|| << 1 (the Neumann-convergence regime the paper's
        # zero-init + small-LR finetuning stays in; §3.3)
        acfg_o = AdapterConfig(kind="oftv2", block_size=32, neumann_terms=8)
        oft_p = {"q_packed": skew.random_skew(kq, (d // 32,), 32,
                                              scale=0.03)}
        acfg_l = AdapterConfig(kind="lora", rank=16, alpha=32.0)
        lora_p = lora_lib.lora_init(ka, d, n, 16)
        lora_p["lora_b"] = 0.01 * jax.random.normal(kb, (16, n))

        rep_o = merging.requantization_report(w, oft_p, acfg_o, qcfg)
        rep_l = merging.requantization_report(w, lora_p, acfg_l, qcfg)
        for tag, rep in [("qoft", rep_o), ("qlora", rep_l)]:
            rows.append((f"requant/{d}x{n}/{tag}", 0.0,
                         f"norm_drift={rep['column_norm_drift']:.2e};"
                         f"range_shift={rep['dynamic_range_shift']:.2e};"
                         f"requant_rel={rep['requant_rel_fro']:.2e}"))
        bound = float(merging.lora_worstcase_range_shift(lora_p, acfg_l))
        rows.append((f"requant/{d}x{n}/qlora_worstcase_bound", 0.0,
                     f"{bound:.2e}"))
    return rows


if __name__ == "__main__":
    emit(run())
