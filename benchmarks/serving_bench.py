"""Multi-tenant serving: mixed-adapter batched decode vs the N-sequential-
batches baseline, plus the serving fusion plan.

The claim being benchmarked (README "Multi-tenant serving"): with the
multi-adapter kernels, a batch mixing requests for N different adapters
decodes in ONE pass -- tok/s stays near-flat as N grows at fixed batch --
whereas without per-row routing the same traffic needs N sequential
single-adapter batches, each paying the full per-step launch cost.

Rows:
  serving/multi_adapter_decode/N{n}_B{b}  -- engine run, mixed adapters
  serving/sequential_baseline/N{n}_B{b}   -- N sequential generate() calls
  serving/speedup/N{n}_B{b}/expect_ge_2.0 -- multi_over_seq ratio; the
     check_fusion CI gate fails the run if it drops below the threshold
  fusion_plan/serving/{dense,nf4}/...     -- expected multi-kernel per
     linear; the same gate fails on any silent 'unfused' fallback.

Both paths are explicitly warmed up (compile excluded) even under --smoke:
the speedup row is a CI-checked acceptance number, not a vibe.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common

PROMPT_LEN = 8
GEN = 16
BATCH = 4


def _build_model(qkind: str):
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig)
    from repro.models import build
    cfg = ModelConfig(name="serve-bench", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                      d_ff=128, vocab_size=256, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5, fuse_linear=True),
                    quant=QuantConfig(kind="nf4", block_size=32)
                    if qkind == "nf4" else QuantConfig(kind="none"))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _requests(cfg, n_adapters: int, batch: int):
    from repro.serving import Request
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(batch):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (PROMPT_LEN,), 0, cfg.vocab_size))
        reqs.append(Request(f"req-{i}", prompt, adapter_id=i % n_adapters,
                            max_new_tokens=GEN))
    return reqs


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _time_pair(fn_a, fn_b, iters: int = 5):
    """(best_a, best_b, median pairwise b/a ratio) after one warmup each.

    The warmups carry the jit compiles.  The two sides are timed
    INTERLEAVED (a, b, a, b, ...) and the gated speedup is the median of
    the per-pair ratios: a CPU-scheduler spike that lands in one phase
    hits both sides of that pair, not five iterations of one side -- this
    is what keeps the CI-gated ratio out of noise territory (the runs are
    tens of ms each, so iters=5 costs CI nothing)."""
    fn_a()
    fn_b()
    ta, tb = [], []
    for _ in range(iters):
        ta.append(_timed(fn_a))
        tb.append(_timed(fn_b))
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return min(ta), min(tb), ratios[len(ratios) // 2]


def _time(fn) -> float:
    """Best-of-5 wall seconds of fn() after one warmup call (ungated
    scaling rows)."""
    fn()
    return min(_timed(fn) for _ in range(5))


def decode_rows(n_adapters: int = 4, batch: int = BATCH):
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    from repro.train.serving import generate

    model, params, cfg = _build_model("none")
    adapters = init_adapters(model, n_adapters, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"tenant-{i}", tree)
    reqs = _requests(cfg, n_adapters, batch)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    tag = f"N{n_adapters}_B{batch}"

    engine = ServingEngine(model, params, pool, n_slots=batch)

    # N-sequential-batches baseline: the same traffic without per-row
    # routing -- one single-adapter generate() per adapter, back to back.
    by_adapter = {}
    for r in reqs:
        by_adapter.setdefault(r.adapter_id, []).append(r)

    def sequential():
        for aid, rs in sorted(by_adapter.items()):
            p = {"base": params["base"], "adapter": adapters[aid]}
            prompts = jnp.asarray(np.stack([r.prompt for r in rs]))
            generate(model, p, prompts, steps=rs[0].max_new_tokens
                     ).block_until_ready()

    dt_multi, dt_seq, ratio = _time_pair(lambda: engine.run(reqs),
                                         sequential)

    return [
        (f"serving/multi_adapter_decode/{tag}", dt_multi * 1e6,
         f"tok_s={total_tokens / dt_multi:.1f}"),
        (f"serving/sequential_baseline/{tag}", dt_seq * 1e6,
         f"tok_s={total_tokens / dt_seq:.1f}"),
        # the expect_ge threshold is parsed and enforced by
        # benchmarks/check_fusion.py in CI (measured ~3-4x on the CI smoke)
        (f"serving/speedup/{tag}/expect_ge_2.0", 0.0,
         f"multi_over_seq={ratio:.2f}"),
    ]


def scaling_rows():
    """tok/s of the mixed-adapter engine as the pool grows at fixed batch
    (the near-flat curve the adapter-pool design buys). Full runs only --
    the smoke tier keeps to the gated N=4 comparison."""
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    model, params, cfg = _build_model("none")
    rows = []
    for n in (1, 2, 4, 8):
        adapters = init_adapters(model, n, jax.random.PRNGKey(7))
        pool = AdapterPool(model)
        for i, tree in enumerate(adapters):
            pool.register(f"tenant-{i}", tree)
        reqs = _requests(cfg, n, BATCH)
        engine = ServingEngine(model, params, pool, n_slots=BATCH)
        dt = _time(lambda: engine.run(reqs))
        total = sum(r.max_new_tokens for r in reqs)
        rows.append((f"serving/pool_scaling/N{n}_B{BATCH}", dt * 1e6,
                     f"tok_s={total / dt:.1f}"))
    return rows


def fusion_plan_rows():
    """Per-linear serving plan; check_fusion fails the CI smoke run if any
    expected multi path reports 'unfused'."""
    from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
    from repro.models.linears import model_multi_fusion_plan
    cfg = ModelConfig(name="plan", num_layers=2, d_model=1024, num_heads=8,
                      num_kv_heads=8, d_ff=4096)
    acfg = AdapterConfig(kind="oftv2", block_size=32, fuse_linear=True)
    rows = []
    for qname, qcfg, expect in [
            ("nf4", QuantConfig(kind="nf4", block_size=64), "qoft_multi"),
            ("dense", QuantConfig(kind="none"), "oftv2_multi")]:
        for name, got in sorted(model_multi_fusion_plan(cfg, acfg,
                                                        qcfg).items()):
            rows.append((f"fusion_plan/serving/{qname}/{name}/"
                         f"expect_{expect}", 0.0, f"got={got}"))
    return rows


def run():
    rows = decode_rows(n_adapters=4, batch=BATCH)
    if not common.SMOKE:
        rows += scaling_rows()
    return rows + fusion_plan_rows()
