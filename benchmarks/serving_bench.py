"""Multi-tenant serving: mixed-adapter batched decode vs the N-sequential-
batches baseline, plus the serving fusion plan.

The claim being benchmarked (README "Multi-tenant serving"): with the
multi-adapter kernels, a batch mixing requests for N different adapters
decodes in ONE pass -- tok/s stays near-flat as N grows at fixed batch --
whereas without per-row routing the same traffic needs N sequential
single-adapter batches, each paying the full per-step launch cost.

Rows:
  serving/multi_adapter_decode/N{n}_B{b}  -- engine run, mixed adapters
  serving/sequential_baseline/N{n}_B{b}   -- N sequential generate() calls
  serving/speedup/N{n}_B{b}/expect_ge_2.0 -- multi_over_seq ratio; the
     check_fusion CI gate fails the run if it drops below the threshold
  fusion_plan/serving/{dense,nf4}/...     -- expected multi-kernel per
     linear; the same gate fails on any silent 'unfused' fallback.
  serving/load/{paged,slots}/N{a}_R{r}    -- open-loop Poisson traffic
     (mixed lengths, per-adapter skew, shared system prompt) against the
     paged v2 engine and the fixed-slot v1 engine; tok/s + p50/p99 ms
  serving/load/throughput/.../expect_ge_1.0 -- paged tok/s at saturation
     must not fall below fixed-slot (the ISSUE-6 acceptance gate)
  serving/load/p99/.../expect_ge_0.7      -- nor may its latency tail
     collapse while buying that throughput

Both paths are explicitly warmed up (compile excluded) even under --smoke:
the speedup row is a CI-checked acceptance number, not a vibe.  The load
generator alone is runnable as ``python -m benchmarks.serving_bench
--load [--smoke]``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common

PROMPT_LEN = 8
GEN = 16
BATCH = 4
SYS_LEN = 32        # shared system prompt length for the --load workload
ARRIVAL_RATE = 4.0  # Poisson arrivals per engine step: saturating at 8 slots


def _build_model(qkind: str):
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig)
    from repro.models import build
    cfg = ModelConfig(name="serve-bench", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                      d_ff=128, vocab_size=256, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5, fuse_linear=True),
                    quant=QuantConfig(kind="nf4", block_size=32)
                    if qkind == "nf4" else QuantConfig(kind="none"))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _requests(cfg, n_adapters: int, batch: int):
    from repro.serving import Request
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(batch):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (PROMPT_LEN,), 0, cfg.vocab_size))
        reqs.append(Request(f"req-{i}", prompt, adapter_id=i % n_adapters,
                            max_new_tokens=GEN))
    return reqs


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _time_pair(fn_a, fn_b, iters: int = 5):
    """(best_a, best_b, median pairwise b/a ratio) after one warmup each.

    The warmups carry the jit compiles.  The two sides are timed
    INTERLEAVED (a, b, a, b, ...) and the gated speedup is the median of
    the per-pair ratios: a CPU-scheduler spike that lands in one phase
    hits both sides of that pair, not five iterations of one side -- this
    is what keeps the CI-gated ratio out of noise territory (the runs are
    tens of ms each, so iters=5 costs CI nothing)."""
    fn_a()
    fn_b()
    ta, tb = [], []
    for _ in range(iters):
        ta.append(_timed(fn_a))
        tb.append(_timed(fn_b))
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return min(ta), min(tb), ratios[len(ratios) // 2]


def _time(fn) -> float:
    """Best-of-5 wall seconds of fn() after one warmup call (ungated
    scaling rows)."""
    fn()
    return min(_timed(fn) for _ in range(5))


def decode_rows(n_adapters: int = 4, batch: int = BATCH):
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    from repro.train.serving import generate

    model, params, cfg = _build_model("none")
    adapters = init_adapters(model, n_adapters, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"tenant-{i}", tree)
    reqs = _requests(cfg, n_adapters, batch)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    tag = f"N{n_adapters}_B{batch}"

    engine = ServingEngine(model, params, pool, n_slots=batch)

    # N-sequential-batches baseline: the same traffic without per-row
    # routing -- one single-adapter generate() per adapter, back to back.
    by_adapter = {}
    for r in reqs:
        by_adapter.setdefault(r.adapter_id, []).append(r)

    def sequential():
        for aid, rs in sorted(by_adapter.items()):
            p = {"base": params["base"], "adapter": adapters[aid]}
            prompts = jnp.asarray(np.stack([r.prompt for r in rs]))
            generate(model, p, prompts, steps=rs[0].max_new_tokens
                     ).block_until_ready()

    dt_multi, dt_seq, ratio = _time_pair(lambda: engine.run(reqs),
                                         sequential)

    return [
        (f"serving/multi_adapter_decode/{tag}", dt_multi * 1e6,
         f"tok_s={total_tokens / dt_multi:.1f}"),
        (f"serving/sequential_baseline/{tag}", dt_seq * 1e6,
         f"tok_s={total_tokens / dt_seq:.1f}"),
        # the expect_ge threshold is parsed and enforced by
        # benchmarks/check_fusion.py in CI (measured ~3-4x on the CI smoke)
        (f"serving/speedup/{tag}/expect_ge_2.0", 0.0,
         f"multi_over_seq={ratio:.2f}"),
    ]


def scaling_rows():
    """tok/s of the mixed-adapter engine as the pool grows at fixed batch
    (the near-flat curve the adapter-pool design buys). Full runs only --
    the smoke tier keeps to the gated N=4 comparison."""
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    model, params, cfg = _build_model("none")
    rows = []
    for n in (1, 2, 4, 8):
        adapters = init_adapters(model, n, jax.random.PRNGKey(7))
        pool = AdapterPool(model)
        for i, tree in enumerate(adapters):
            pool.register(f"tenant-{i}", tree)
        reqs = _requests(cfg, n, BATCH)
        engine = ServingEngine(model, params, pool, n_slots=BATCH)
        dt = _time(lambda: engine.run(reqs))
        total = sum(r.max_new_tokens for r in reqs)
        rows.append((f"serving/pool_scaling/N{n}_B{BATCH}", dt * 1e6,
                     f"tok_s={total / dt:.1f}"))
    return rows


def _load_workload(cfg, n_requests: int, n_adapters: int, seed: int = 0):
    """Poisson arrivals (measured in engine-step time so the schedule is
    machine-independent), mixed prompt/output length distributions,
    per-adapter traffic skew (adapter 0 takes ~half the traffic), and ONE
    shared system prompt so the paged engine's prefix cache has something
    to share.  Returns (requests, arrival_steps)."""
    import random

    from repro.serving import Request, SamplingParams
    rnd = random.Random(seed)
    sys_prompt = [rnd.randrange(cfg.vocab_size) for _ in range(SYS_LEN)]
    reqs, arrivals = [], []
    t = 0.0
    for i in range(n_requests):
        t += rnd.expovariate(ARRIVAL_RATE)
        aid = 0 if rnd.random() < 0.5 else rnd.randrange(n_adapters)
        tail = [rnd.randrange(cfg.vocab_size)
                for _ in range(rnd.choice((2, 4, 8, 16, 24)))]
        reqs.append(Request(
            f"load-{i}", np.asarray(sys_prompt + tail, np.int32),
            adapter_id=aid,
            sampling=SamplingParams(
                max_new_tokens=rnd.choice((4, 8, 12, 16)))))
        arrivals.append(t)
    return reqs, arrivals


def _drive_load(engine, reqs, arrivals):
    """Serve ``reqs`` on the incremental submit()/step() interface,
    releasing each at its arrival step.  Returns (wall seconds, {rid:
    GenerationResult}, peak number of requests in flight)."""
    results = {}
    inflight = peak = 0
    i, step = 0, 0
    t0 = time.perf_counter()
    while len(results) < len(reqs):
        while i < len(reqs) and arrivals[i] <= step:
            engine.submit(reqs[i])
            i += 1
            inflight += 1
            peak = max(peak, inflight)
        for res in engine.step():
            results[res.rid] = res
            inflight -= 1
        step += 1
    return time.perf_counter() - t0, results, peak


def _warm_engine(engine, cfg, prompt_lens):
    """Carry the jit compiles outside the timed window: one throwaway
    request per distinct prompt length in the workload (the slots path
    buckets prefill by padded length, so each bucket is its own compile),
    then one solo short request so the paged engine's pure-decode C=1
    shape compiles too.  Warmup prompts are random -- they do NOT
    pre-populate the paged prefix cache, so the timed run measures
    cold-cache sharing."""
    from repro.serving import Request, SamplingParams
    key = jax.random.PRNGKey(1234)
    reqs = [Request(f"warm-{n}", np.asarray(jax.random.randint(
                jax.random.fold_in(key, n), (n,), 0, cfg.vocab_size)),
                sampling=SamplingParams(max_new_tokens=2))
            for n in sorted(set(prompt_lens))]
    engine.run(reqs)
    engine.run([Request("warm-decode", np.asarray(jax.random.randint(
        key, (4,), 0, cfg.vocab_size)),
        sampling=SamplingParams(max_new_tokens=4))])


def load_rows(n_adapters: int = 4, n_requests: int | None = None):
    """The --load mode: saturating open-loop traffic against the paged
    engine vs the fixed-slot (v1) engine on the SAME workload + arrival
    schedule.  Emits per-mode latency/throughput rows plus two gated
    ratio rows (paged throughput >= slots; paged p99 not collapsing)."""
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    if n_requests is None:
        n_requests = 48 if common.SMOKE else 96
    model, params, cfg = _build_model("none")
    adapters = init_adapters(model, n_adapters, jax.random.PRNGKey(7))
    reqs, arrivals = _load_workload(cfg, n_requests, n_adapters)
    s_max = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    tag = f"N{n_adapters}_R{n_requests}"
    def fresh_engine(mode):
        pool = AdapterPool(model)
        for i, tree in enumerate(adapters):
            pool.register(f"tenant-{i}", tree)
        # page_size divides SYS_LEN: the shared system prompt is whole
        # blocks, so sharers adopt it zero-copy instead of CoW-copying a
        # partial tail block per request
        kw = {"page_size": 8, "prefill_chunk": 8} if mode == "paged" else {}
        return ServingEngine(model, params, pool, n_slots=8, s_max=s_max,
                             mode=mode, **kw)

    for mode in ("paged", "slots"):
        # one warmup engine per mode carries the compiles (the jit cache
        # is per model+fn, shared across engines)
        _warm_engine(fresh_engine(mode), cfg, [len(r.prompt) for r in reqs])

    # the gated numbers are MEDIANS of per-iteration interleaved ratios
    # (same reasoning as _time_pair: a scheduler spike hits one pair, not
    # one whole side).  Every iteration gets a FRESH engine -- reusing one
    # would hand later paged runs a pre-warmed prefix cache.
    rows, stats = [], {"paged": [], "slots": []}
    first = {}
    for _ in range(3):
        for mode in ("paged", "slots"):
            eng = fresh_engine(mode)
            wall, results, peak = _drive_load(eng, reqs, arrivals)
            toks = sum(r.n_generated for r in results.values())
            # percentiles straight off the engine's OWN latency/TTFT
            # histograms (repro.obs) -- the numbers a /metrics scrape of
            # this run would report, not a bench-side recomputation
            lat, ttft = eng.obs.latency, eng.obs.ttft
            stats[mode].append((toks / wall, lat.quantile(0.99)))
            if mode not in first:
                shared = sum(r.prefix_blocks_shared
                             for r in results.values())
                first[mode] = (wall, toks / wall, lat.quantile(0.5),
                               lat.quantile(0.99), ttft.quantile(0.5),
                               peak, shared)
    for mode in ("paged", "slots"):
        wall, tok_s, p50, p99, ttft50, peak, shared = first[mode]
        rows.append((
            f"serving/load/{mode}/{tag}", wall * 1e6,
            f"tok_s={tok_s:.1f};p50_ms={p50 * 1e3:.1f};"
            f"p99_ms={p99 * 1e3:.1f};ttft_p50_ms={ttft50 * 1e3:.1f};"
            f"peak_inflight={peak};shared_blocks={shared}"))
    med = lambda xs: sorted(xs)[len(xs) // 2]   # noqa: E731
    tput = med([p[0] / s[0] for p, s in zip(stats["paged"],
                                            stats["slots"])])
    p99r = med([s[1] / p[1] for p, s in zip(stats["paged"],
                                            stats["slots"])])
    rows.append((
        # acceptance gate: paged tok/s at saturation >= fixed-slot
        f"serving/load/throughput/{tag}/expect_ge_1.0", 0.0,
        f"ratio={tput:.2f}"))
    rows.append((
        # p99 gate: slots_p99 / paged_p99 -- paged must not trade its
        # throughput win for a latency-tail collapse (threshold below 1.0
        # on purpose: the tail is the noisiest statistic here)
        f"serving/load/p99/{tag}/expect_ge_0.7", 0.0,
        f"ratio={p99r:.2f}"))
    return rows


def fusion_plan_rows():
    """Per-linear serving plan; check_fusion fails the CI smoke run if any
    expected multi path reports 'unfused'."""
    from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
    from repro.models.linears import model_multi_fusion_plan
    cfg = ModelConfig(name="plan", num_layers=2, d_model=1024, num_heads=8,
                      num_kv_heads=8, d_ff=4096)
    acfg = AdapterConfig(kind="oftv2", block_size=32, fuse_linear=True)
    rows = []
    for qname, qcfg, expect in [
            ("nf4", QuantConfig(kind="nf4", block_size=64), "qoft_multi"),
            ("dense", QuantConfig(kind="none"), "oftv2_multi")]:
        for name, got in sorted(model_multi_fusion_plan(cfg, acfg,
                                                        qcfg).items()):
            rows.append((f"fusion_plan/serving/{qname}/{name}/"
                         f"expect_{expect}", 0.0, f"got={got}"))
    return rows


def run():
    rows = decode_rows(n_adapters=4, batch=BATCH)
    if not common.SMOKE:
        rows += scaling_rows()
    return rows + load_rows() + fusion_plan_rows()


def main() -> None:
    """``python -m benchmarks.serving_bench --load [--smoke]``: just the
    open-loop load generator (the full bench suite lives in run.py)."""
    import sys
    args = set(sys.argv[1:])
    if not args <= {"--load", "--smoke"} or "--load" not in args:
        print("usage: serving_bench.py --load [--smoke]", file=sys.stderr)
        sys.exit(2)
    if "--smoke" in args:
        common.SMOKE = True
    print("name,us_per_call,derived")
    common.emit(load_rows())


if __name__ == "__main__":
    main()
