"""Mesh-native fused path: smoke timing + the sharded fusion-plan gate.

``sharded/train_step/{dense,nf4}``: one hoisted train step through the
shard_map'd fused kernels on a (1, 1) mesh -- CI hosts have one device;
real meshes only change ``mesh_shape``.  This exercises the exact code
path of the 8-device tests (MeshContext -> shard_forward -> shard_map ->
Pallas) so bit-rot in the sharded path is caught by the smoke run in
minutes.

``fusion_plan/sharded/train_step/*``: the mode the SHARDED dispatcher
picks per linear on a production-shaped 2x4 (data, model) mesh, computed
without devices (models/linears.model_sharded_fusion_plan).  The existing
benchmarks/check_fusion.py CI gate fails the build if any row reports
'unfused' -- a fused -> unfused fallback under the mesh would replicate W
and silently forfeit the scaling story.
"""
from __future__ import annotations

import jax

from benchmarks.common import time_jit


def _step_rows():
    from repro.config.base import (AdapterConfig, ModelConfig,
                                   ParallelConfig, QuantConfig, RunConfig,
                                   TrainConfig)
    from repro.distributed.sharding import (fit_tree, make_constrain,
                                            make_shard_context)
    from repro.models import build
    from repro.models.spec import rules_variant
    from repro.train import state as state_lib
    from repro.train.step import make_train_step

    rows = []
    for qname, qkind in [("dense", "none"), ("nf4", "nf4")]:
        pcfg = ParallelConfig(mesh_shape=(1, 1),
                              mesh_axes=("data", "model"))
        cfg = ModelConfig(name="sh-bench", num_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=2, d_ff=256,
                          vocab_size=256, rope_theta=1e4)
        run_cfg = RunConfig(
            model=cfg,
            adapter=AdapterConfig(kind="oftv2", block_size=32,
                                  neumann_terms=5, fuse_linear=True),
            quant=QuantConfig(kind=qkind, block_size=32),
            parallel=pcfg,
            train=TrainConfig(global_batch=4, seq_len=64, warmup_steps=0))
        mesh = jax.make_mesh(pcfg.mesh_shape, pcfg.mesh_axes)
        rules = rules_variant(pcfg, "fused_tp")
        ctx = make_shard_context(mesh, rules, run_cfg)
        model = build(run_cfg, constrain=make_constrain(rules, mesh),
                      shard=ctx)
        params = fit_tree(model.init(jax.random.PRNGKey(0)),
                          model.param_specs(rules), mesh)
        st = state_lib.create(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 64), 0, 256)}
        with mesh:
            step = jax.jit(make_train_step(model, run_cfg))
            us = time_jit(lambda s, b: step(s, b)[1]["loss"], st, batch)
        rows.append((f"sharded/train_step/{qname}", us,
                     "mesh=1x1;d=128;b=32;shard_map_fused"))
    return rows


def plan_rows():
    """Sharded per-linear plan on a 2x4 mesh shape; check_fusion gates
    every fusion_plan/* row, so 'got=unfused' here fails CI."""
    from repro.config.base import (AdapterConfig, ModelConfig,
                                   ParallelConfig, QuantConfig)
    from repro.models.linears import model_sharded_fusion_plan
    pcfg = ParallelConfig(mesh_shape=(2, 4), mesh_axes=("data", "model"))
    cfg = ModelConfig(name="plan", num_layers=2, d_model=1024, num_heads=8,
                      num_kv_heads=8, d_ff=4096)
    acfg = AdapterConfig(kind="oftv2", block_size=32, fuse_linear=True)
    rows = []
    for qname, qcfg, expect in [
            ("nf4", QuantConfig(kind="nf4", block_size=64), "qoft_fused"),
            ("dense", QuantConfig(kind="none"), "oftv2_fused")]:
        plan = model_sharded_fusion_plan(cfg, acfg, qcfg, pcfg)
        for name, got in sorted(plan.items()):
            rows.append((f"fusion_plan/sharded/train_step/{qname}/{name}/"
                         f"expect_{expect}", 0.0, f"got={got}"))
    return rows


def run():
    return _step_rows() + plan_rows()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
