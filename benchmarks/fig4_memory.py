"""Paper Fig. 4: finetuning memory across Qwen2.5 scales (0.5B-72B) and
formats (bf16 / NF4 / AWQ) for LoRA vs OFTv2 adapters.

Memory model = frozen-weight storage (quant-dependent) + adapter params +
AdamW moments + grads (adapter only: PEFT). Measured at a tiny scale to
validate the model (storage_bytes of real quantized trees), analytic at the
paper's scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config.base import AdapterConfig, QuantConfig
from repro.core.adapter import adapter_param_count
from repro.quant.common import quantize_linear, storage_bytes

# Qwen2.5 family geometry [Qwen2.5 tech report]
QWEN_SCALES = {
    "qwen2.5-0.5b": dict(L=24, d=896, dff=4864, heads=14, kv=2, hd=64),
    "qwen2.5-1.5b": dict(L=28, d=1536, dff=8960, heads=12, kv=2, hd=128),
    "qwen2.5-7b": dict(L=28, d=3584, dff=18944, heads=28, kv=4, hd=128),
    "qwen2.5-32b": dict(L=64, d=5120, dff=27648, heads=40, kv=8, hd=128),
    "qwen2.5-72b": dict(L=80, d=8192, dff=29568, heads=64, kv=8, hd=128),
}
VOCAB = 152064

BYTES_PER_PARAM = {"bf16": 2.0, "nf4": 0.5 + 4.0 / 64,   # codes + absmax/64
                   "awq": 0.5 + 5.0 / 128, "int8": 1.0}


def linear_shapes(g):
    d, dff, h, kv, hd = g["d"], g["dff"], g["heads"], g["kv"], g["hd"]
    return {"q": (d, h * hd), "k": (d, kv * hd), "v": (d, kv * hd),
            "o": (h * hd, d), "gate": (d, dff), "up": (d, dff),
            "down": (dff, d)}


def base_params(g):
    per_layer = sum(a * b for a, b in linear_shapes(g).values()) + 2 * g["d"]
    return per_layer * g["L"] + 2 * VOCAB * g["d"]


def adapter_params(g, acfg):
    per_layer = sum(adapter_param_count(n, a, b, acfg)
                    for n, (a, b) in linear_shapes(g).items())
    return per_layer * g["L"]


def run():
    rows = []
    acfgs = {"lora_r16": AdapterConfig(kind="lora", rank=16),
             "oftv2_b32": AdapterConfig(kind="oftv2", block_size=32)}
    for scale, g in QWEN_SCALES.items():
        base = base_params(g)
        for fmt, bpp in BYTES_PER_PARAM.items():
            for aname, acfg in acfgs.items():
                ap = adapter_params(g, acfg)
                # frozen weights + adapter fp32 + adam (2x fp32) + grad fp32
                total = base * bpp + ap * 4 * 4
                rows.append((f"fig4/{scale}/{fmt}/{aname}", 0.0,
                             f"total_gb={total / 1e9:.2f};"
                             f"adapter_params={ap / 1e6:.2f}M"))
    # measured validation of the quant storage model at a tiny scale
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (2048, 2048)) * 0.02
    for fmt, qcfg in [("bf16", QuantConfig()),
                      ("nf4", QuantConfig(kind="nf4")),
                      ("awq", QuantConfig(kind="awq")),
                      ("int8", QuantConfig(kind="int8"))]:
        q = quantize_linear(w.astype(jnp.bfloat16) if fmt == "bf16" else w,
                            qcfg)
        got = storage_bytes(q) / w.size
        rows.append((f"fig4/measured_bytes_per_param/{fmt}", 0.0,
                     f"{got:.4f} (model {BYTES_PER_PARAM[fmt]:.4f})"))
    return rows


if __name__ == "__main__":
    emit(run())
