"""CI gate: adapter-method string dispatch is allowed ONLY inside
``src/repro/methods/``.

PR 4 retired the ~52 ``acfg.kind == "..."`` / ``acfg.is_oft`` dispatch
sites scattered across the framework in favor of the ``repro.methods``
registry.  This gate greps the source tree and fails the build if any of
them grow back -- the registry is worthless the day one branch bypasses
it.  (Quant-kind dispatch, ``qcfg.kind == "nf4"`` etc., is a different
axis and stays where it is.)

Usage: python -m benchmarks.check_dispatch   (no arguments; exits 1 on hits)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"
ALLOWED = SRC / "methods"

# (pattern, why it is banned)
PATTERNS = [
    (re.compile(r"\bacfg\.kind\s*(?:==|!=)"),
     "adapter-kind comparison -- query repro.methods instead"),
    (re.compile(r"\.is_oft\b"),
     "is_oft predicate -- retired; use the method's capability flags"),
    (re.compile(r"\badapter\s*(?:==|!=)\s*[\"']"),
     "adapter-kind literal comparison -- query repro.methods instead"),
    (re.compile(r"\bkind\s*(?:==|!=)\s*[\"'](?:oftv1|oftv2|lora|hoft)[\"']"),
     "adapter-kind literal comparison -- query repro.methods instead"),
    (re.compile(r"\b(?:acfg|adapter)\.kind\s+(?:not\s+)?in\s"),
     "adapter-kind membership test (the old is_oft shape) -- use the "
     "method's capability flags"),
    (re.compile(r"\b(?:acfg|adapter)\.kind\.startswith\b"),
     "adapter-kind prefix test -- use the method's capability flags"),
]


def check(root: Path = SRC) -> int:
    hits = []
    for path in sorted(root.rglob("*.py")):
        if ALLOWED in path.parents:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            for pat, why in PATTERNS:
                if pat.search(line):
                    hits.append((path.relative_to(root.parents[1]),
                                 lineno, line.strip(), why))
    for path, lineno, line, why in hits:
        print(f"check_dispatch: {path}:{lineno}: {line}\n    ^ {why}",
              file=sys.stderr)
    print(f"check_dispatch: scanned {root} (allowing {ALLOWED.name}/), "
          f"{len(hits)} banned dispatch site(s)")
    return 1 if hits else 0


if __name__ == "__main__":
    sys.exit(check())
