"""CI gate: adapter-method string dispatch is allowed ONLY inside
``src/repro/methods/``.

PR 4 retired the ~52 ``acfg.kind == "..."`` / ``acfg.is_oft`` dispatch
sites scattered across the framework in favor of the ``repro.methods``
registry.  This gate fails the build if any of them grow back -- the
registry is worthless the day one branch bypasses it.  (Quant-kind
dispatch, ``qcfg.kind == "nf4"`` etc., is a different axis and stays
where it is.)

Since ISSUE-9 this is a thin wrapper over the ``registry-dispatch`` AST
rule of ``repro.analysis``: the banned patterns are matched on parsed
syntax, so a docstring or comment QUOTING ``acfg.kind == ...`` no longer
fails the build (the regex predecessor's false positive), while actual
code sites are caught exactly as before.

Usage: python -m benchmarks.check_dispatch   (no arguments; exits 1 on hits)
"""
from __future__ import annotations

import sys
from pathlib import Path


def check(root: Path = None) -> int:
    """Scan ``src/repro`` under ``root`` (the repo root; default:
    auto-detected) with the registry-dispatch rule; 0 iff clean."""
    from repro.analysis import core, pyast
    core._load_shipped()
    rule = core.get("registry-dispatch")
    hits = []
    for module in pyast.iter_modules(root):
        hits.extend(rule.check(module))
    for f in hits:
        print(f"check_dispatch: {f.where}: {f.message}", file=sys.stderr)
    print(f"check_dispatch: scanned src/repro (allowing methods/), "
          f"{len(hits)} banned dispatch site(s)")
    return 1 if hits else 0


if __name__ == "__main__":
    sys.exit(check())
