"""Pallas kernel benchmarks: jnp reference path vs the kernel in interpret
mode (CPU container: interpret mode validates semantics; wall-clock wins
require real TPU -- the XLA path below is what production uses on CPU).

Fused-linear rows: measured XLA-unfused baselines + interpret-mode fused
correctness + an analytic HBM-traffic comparison (the quantity the fusion
actually buys; both paths are HBM-bound at these arithmetic intensities, so
traffic ratio ~= TPU speedup ceiling)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import skew
from repro.core.cayley import build_rotation
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.roofline.hw import V5E
from repro.roofline.kernels import linear_bwd_hbm_bytes, linear_hbm_bytes


def fused_rows():
    """Fused-vs-unfused comparison entries (BENCH_* trajectory metric)."""
    rows = []
    key = jax.random.PRNGKey(1)
    b, bs = 32, 64

    for t, d, n in [(2048, 1024, 1024), (8192, 4096, 4096)]:
        x = jax.random.normal(key, (t, d), jnp.float32)
        w = 0.02 * jax.random.normal(key, (d, n), jnp.float32)
        qp = skew.random_skew(key, (d // b,), b, scale=0.05)
        r = build_rotation(qp, b, 5)

        unfused = jax.jit(kref.oftv2_linear_ref)
        us = time_jit(unfused, x, r, w)
        rows.append((f"kernel/oftv2_linear/unfused_xla/{t}x{d}x{n}", us,
                     f"b={b}"))

        hbm_u = linear_hbm_bytes(t, d, n, b, fused=False)
        hbm_f = linear_hbm_bytes(t, d, n, b, fused=True)
        rows.append((
            f"kernel/oftv2_linear/fused_vs_unfused/{t}x{d}x{n}", 0.0,
            f"hbm_unfused={hbm_u:.3e};hbm_fused={hbm_f:.3e};"
            f"traffic_ratio={hbm_u / hbm_f:.2f}x;"
            f"hbm_bound_us_saved={(hbm_u - hbm_f) / V5E.hbm_bw * 1e6:.1f}"))

        from repro.config.base import QuantConfig
        from repro.quant import nf4
        q = nf4.quantize(w, QuantConfig(kind="nf4", block_size=bs,
                                        double_quant=False))
        unfused_q = jax.jit(lambda x, r, c, a: kref.qoft_linear_ref(
            x, r, c, a, bs))
        us = time_jit(unfused_q, x, r, q["nf4_codes"], q["absmax"])
        rows.append((f"kernel/qoft_linear/unfused_xla/{t}x{d}x{n}", us,
                     f"b={b};bs={bs}"))

        hbm_u = linear_hbm_bytes(t, d, n, b, fused=False, quant_bs=bs)
        hbm_f = linear_hbm_bytes(t, d, n, b, fused=True, quant_bs=bs)
        rows.append((
            f"kernel/qoft_linear/fused_vs_unfused/{t}x{d}x{n}", 0.0,
            f"hbm_unfused={hbm_u:.3e};hbm_fused={hbm_f:.3e};"
            f"traffic_ratio={hbm_u / hbm_f:.2f}x;"
            f"hbm_bound_us_saved={(hbm_u - hbm_f) / V5E.hbm_bw * 1e6:.1f}"))

    # interpret-mode correctness + one measured fused call (small size; CPU
    # interpret timing is a semantics check, not a perf claim)
    t, d, n = 256, 512, 256
    x = jax.random.normal(key, (t, d), jnp.float32)
    w = 0.02 * jax.random.normal(key, (d, n), jnp.float32)
    qp = skew.random_skew(key, (d // b,), b, scale=0.05)
    r = build_rotation(qp, b, 5)
    us = time_jit(kops.oftv2_linear_fused, x, r, w)
    err = float(jnp.max(jnp.abs(kops.oftv2_linear_fused(x, r, w)
                                - kref.oftv2_linear_ref(x, r, w))))
    rows.append((f"kernel/oftv2_linear/fused_interpret/{t}x{d}x{n}", us,
                 f"max_err={err:.2e}"))
    from repro.config.base import QuantConfig
    from repro.quant import nf4
    q = nf4.quantize(w, QuantConfig(kind="nf4", block_size=bs,
                                    double_quant=False))
    fused_q = jax.jit(lambda x, r, c, a: kops.qoft_linear_fused(x, r, c, a,
                                                                bs))
    us = time_jit(fused_q, x, r, q["nf4_codes"], q["absmax"])
    err = float(jnp.max(jnp.abs(
        fused_q(x, r, q["nf4_codes"], q["absmax"])
        - kref.qoft_linear_ref(x, r, q["nf4_codes"], q["absmax"], bs))))
    rows.append((f"kernel/qoft_linear/fused_interpret/{t}x{d}x{n}", us,
                 f"max_err={err:.2e}"))
    return rows


def bwd_rows():
    """Backward fused-vs-unfused entries, mirroring the forward rows: the
    unfused baseline is jax.vjp through the jnp oracle (what XLA runs
    without the fused bwd kernels), the fused numbers are the analytic HBM
    traffic of oftv2/qoft_linear_bwd plus an interpret-mode correctness
    check."""
    from repro.config.base import QuantConfig
    from repro.quant import nf4
    rows = []
    key = jax.random.PRNGKey(2)
    b, bs = 32, 64

    for t, d, n in [(2048, 1024, 1024), (8192, 4096, 4096)]:
        x = jax.random.normal(key, (t, d), jnp.float32)
        w = 0.02 * jax.random.normal(key, (d, n), jnp.float32)
        qp = skew.random_skew(key, (d // b,), b, scale=0.05)
        r = build_rotation(qp, b, 5)
        g = jax.random.normal(key, (t, n), jnp.float32)

        unfused = jax.jit(lambda x, r, w, g: jax.vjp(
            kref.oftv2_linear_ref, x, r, w)[1](g)[:2])
        us = time_jit(unfused, x, r, w, g)
        rows.append((f"kernel/oftv2_linear/bwd_unfused_xla/{t}x{d}x{n}", us,
                     f"b={b}"))
        hbm_u = linear_bwd_hbm_bytes(t, d, n, b, fused=False)
        hbm_f = linear_bwd_hbm_bytes(t, d, n, b, fused=True)
        rows.append((
            f"kernel/oftv2_linear/bwd_fused_vs_unfused/{t}x{d}x{n}", 0.0,
            f"hbm_unfused={hbm_u:.3e};hbm_fused={hbm_f:.3e};"
            f"traffic_ratio={hbm_u / hbm_f:.2f}x;"
            f"hbm_bound_us_saved={(hbm_u - hbm_f) / V5E.hbm_bw * 1e6:.1f}"))

        q = nf4.quantize(w, QuantConfig(kind="nf4", block_size=bs,
                                        double_quant=False))
        # codes/absmax as jit ARGUMENTS (not closure constants): closed-over
        # quant state makes XLA constant-fold the dequant jvp for ~40s at
        # the big shape, pure compile-time waste in the smoke run
        unfused_q = jax.jit(lambda x, r, c, a, g: jax.vjp(
            lambda x, r: kref.qoft_linear_ref(x, r, c, a, bs), x, r)[1](g))
        us = time_jit(unfused_q, x, r, q["nf4_codes"], q["absmax"], g)
        rows.append((f"kernel/qoft_linear/bwd_unfused_xla/{t}x{d}x{n}", us,
                     f"b={b};bs={bs}"))
        hbm_u = linear_bwd_hbm_bytes(t, d, n, b, fused=False, quant_bs=bs)
        hbm_f = linear_bwd_hbm_bytes(t, d, n, b, fused=True, quant_bs=bs)
        rows.append((
            f"kernel/qoft_linear/bwd_fused_vs_unfused/{t}x{d}x{n}", 0.0,
            f"hbm_unfused={hbm_u:.3e};hbm_fused={hbm_f:.3e};"
            f"traffic_ratio={hbm_u / hbm_f:.2f}x;"
            f"hbm_bound_us_saved={(hbm_u - hbm_f) / V5E.hbm_bw * 1e6:.1f}"))

    # interpret-mode correctness + one measured fused bwd call
    t, d, n = 256, 512, 256
    x = jax.random.normal(key, (t, d), jnp.float32)
    w = 0.02 * jax.random.normal(key, (d, n), jnp.float32)
    qp = skew.random_skew(key, (d // b,), b, scale=0.05)
    r = build_rotation(qp, b, 5)
    g = jax.random.normal(key, (t, n), jnp.float32)
    fused = jax.jit(lambda g, x, r, w: kops._oftv2_bwd_raw(g, x, r, w))
    us = time_jit(fused, g, x, r, w)
    dx, dr = fused(g, x, r, w)
    dx_r, dr_r = kref.oftv2_linear_bwd_ref(g, x, r, w)
    err = max(float(jnp.max(jnp.abs(dx - dx_r))),
              float(jnp.max(jnp.abs(dr - dr_r))))
    rows.append((f"kernel/oftv2_linear/bwd_fused_interpret/{t}x{d}x{n}", us,
                 f"max_err={err:.2e}"))
    q = nf4.quantize(w, QuantConfig(kind="nf4", block_size=bs,
                                    double_quant=False))
    fused_q = jax.jit(lambda g, x, r: kops._qoft_bwd_raw(
        g, x, r, q["nf4_codes"], q["absmax"], bs))
    us = time_jit(fused_q, g, x, r)
    dx, dr = fused_q(g, x, r)
    dx_r, dr_r = kref.qoft_linear_bwd_ref(g, x, r, q["nf4_codes"],
                                          q["absmax"], bs)
    err = max(float(jnp.max(jnp.abs(dx - dx_r))),
              float(jnp.max(jnp.abs(dr - dr_r))))
    rows.append((f"kernel/qoft_linear/bwd_fused_interpret/{t}x{d}x{n}", us,
                 f"max_err={err:.2e}"))
    return rows


def train_step_rows():
    """Whole-train-step effect of building R once per step vs once per
    linear per microbatch (microbatches=4, tiny model, CPU-XLA wall clock:
    directionally meaningful since both paths run the same XLA backend)."""
    from repro.config.base import (AdapterConfig, ModelConfig,
                                   ParallelConfig, QuantConfig, RunConfig,
                                   TrainConfig)
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import SyntheticSpec
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step

    run = RunConfig(
        model=ModelConfig(name="bench", num_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=2, d_ff=256,
                          vocab_size=128, rope_theta=1e4),
        adapter=AdapterConfig(kind="oftv2", block_size=32, neumann_terms=5),
        quant=QuantConfig(kind="none"),
        parallel=ParallelConfig(microbatches=4),
        train=TrainConfig(global_batch=8, seq_len=64))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    batch = ShardedLoader(SyntheticSpec(vocab_size=128, seq_len=64,
                                        noise=0.05),
                          global_batch=8, seed=0).next_batch()
    batch = jax.tree_util.tree_map(jnp.asarray, batch)

    rows = []
    out = {}
    for label, hoist in [("r_once_per_step", True),
                         ("r_per_microbatch", False)]:
        step = jax.jit(make_train_step(model, run, hoist_rotations=hoist))
        st = state_lib.create(params)
        us = time_jit(step, st, batch)
        out[label] = us
        rows.append((f"train_step/{label}/microbatches=4", us,
                     "d=128;layers=2;b=32"))
    rows.append(("train_step/r_reuse_speedup/microbatches=4", 0.0,
                 f"x{out['r_per_microbatch'] / max(out['r_once_per_step'], 1e-9):.2f};"
                 "builds_per_step:1_vs_per_linear_per_microbatch"))
    return rows


def fusion_plan_rows():
    """Emit the per-linear fusion plan for representative configs; CI's
    check_fusion gate fails the smoke run if a path expected to fuse
    reports 'unfused' (benchmarks/check_fusion.py)."""
    from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
    from repro.models.linears import model_fusion_plan
    cfg = ModelConfig(name="plan", num_layers=2, d_model=1024, num_heads=8,
                      num_kv_heads=8, d_ff=4096)
    acfg = AdapterConfig(kind="oftv2", block_size=32, fuse_linear=True)
    rows = []
    for qname, qcfg, expect in [
            ("nf4", QuantConfig(kind="nf4", block_size=64), "qoft_fused"),
            ("dense", QuantConfig(kind="none"), "oftv2_fused")]:
        for name, got in sorted(model_fusion_plan(cfg, acfg, qcfg).items()):
            rows.append((f"fusion_plan/{qname}/{name}/expect_{expect}", 0.0,
                         f"got={got}"))
    return rows


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # block_oft_apply
    for t, d, b in [(2048, 1024, 32), (8192, 4096, 32)]:
        x = jax.random.normal(key, (t, d), jnp.float32)
        qp = skew.random_skew(key, (d // b,), b, scale=0.05)
        r = build_rotation(qp, b, 5)
        ref = jax.jit(kref.block_oft_apply_ref)
        us = time_jit(ref, x, r)
        rows.append((f"kernel/block_oft_apply/ref/{t}x{d}", us,
                     f"xla_jnp;b={b}"))
    # cayley_neumann build
    for r_blocks, b in [(128, 32), (512, 32), (64, 64)]:
        qp = skew.random_skew(key, (r_blocks,), b, scale=0.05)
        ref = jax.jit(lambda q: kref.cayley_neumann_ref(q, b, 5))
        us = time_jit(ref, qp)
        rows.append((f"kernel/cayley_neumann/ref/{r_blocks}x{b}", us,
                     "xla_jnp;k=5"))
    # nf4 dequant
    from repro.config.base import QuantConfig
    from repro.quant import nf4
    qcfg = QuantConfig(kind="nf4", block_size=64, double_quant=False)
    for d_in, d_out in [(1024, 1024), (4096, 4096)]:
        w = 0.02 * jax.random.normal(key, (d_in, d_out))
        q = nf4.quantize(w, qcfg)
        ref = jax.jit(lambda c, a: kref.nf4_dequant_ref(c, a, 64,
                                                        jnp.float32))
        us = time_jit(ref, q["nf4_codes"], q["absmax"])
        rows.append((f"kernel/nf4_dequant/ref/{d_in}x{d_out}", us,
                     "xla_jnp"))

    # interpret-mode correctness spot check (timing not meaningful on CPU)
    x = jax.random.normal(key, (256, 512), jnp.float32)
    qp = skew.random_skew(key, (16,), 32, scale=0.05)
    r = build_rotation(qp, 32, 5)
    err = float(jnp.max(jnp.abs(kops.block_oft_apply(x, r)
                                - kref.block_oft_apply_ref(x, r))))
    rows.append(("kernel/block_oft_apply/interpret_max_err", 0.0,
                 f"{err:.2e}"))
    return (rows + fused_rows() + bwd_rows() + train_step_rows()
            + fusion_plan_rows())


if __name__ == "__main__":
    emit(run())
