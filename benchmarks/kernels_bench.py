"""Pallas kernel benchmarks: jnp reference path vs the kernel in interpret
mode (CPU container: interpret mode validates semantics; wall-clock wins
require real TPU -- the XLA path below is what production uses on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.core import skew
from repro.core.cayley import build_rotation
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # block_oft_apply
    for t, d, b in [(2048, 1024, 32), (8192, 4096, 32)]:
        x = jax.random.normal(key, (t, d), jnp.float32)
        qp = skew.random_skew(key, (d // b,), b, scale=0.05)
        r = build_rotation(qp, b, 5)
        ref = jax.jit(kref.block_oft_apply_ref)
        us = time_jit(ref, x, r)
        rows.append((f"kernel/block_oft_apply/ref/{t}x{d}", us,
                     f"xla_jnp;b={b}"))
    # cayley_neumann build
    for r_blocks, b in [(128, 32), (512, 32), (64, 64)]:
        qp = skew.random_skew(key, (r_blocks,), b, scale=0.05)
        ref = jax.jit(lambda q: kref.cayley_neumann_ref(q, b, 5))
        us = time_jit(ref, qp)
        rows.append((f"kernel/cayley_neumann/ref/{r_blocks}x{b}", us,
                     "xla_jnp;k=5"))
    # nf4 dequant
    from repro.config.base import QuantConfig
    from repro.quant import nf4
    qcfg = QuantConfig(kind="nf4", block_size=64, double_quant=False)
    for d_in, d_out in [(1024, 1024), (4096, 4096)]:
        w = 0.02 * jax.random.normal(key, (d_in, d_out))
        q = nf4.quantize(w, qcfg)
        ref = jax.jit(lambda c, a: kref.nf4_dequant_ref(c, a, 64,
                                                        jnp.float32))
        us = time_jit(ref, q["nf4_codes"], q["absmax"])
        rows.append((f"kernel/nf4_dequant/ref/{d_in}x{d_out}", us,
                     "xla_jnp"))

    # interpret-mode correctness spot check (timing not meaningful on CPU)
    x = jax.random.normal(key, (256, 512), jnp.float32)
    qp = skew.random_skew(key, (16,), 32, scale=0.05)
    r = build_rotation(qp, 32, 5)
    err = float(jnp.max(jnp.abs(kops.block_oft_apply(x, r)
                                - kref.block_oft_apply_ref(x, r))))
    rows.append(("kernel/block_oft_apply/interpret_max_err", 0.0,
                 f"{err:.2e}"))
    return rows


if __name__ == "__main__":
    emit(run())
