"""Shared benchmark utilities: wall-clock timing of jitted callables and a
tiny result-reporting contract (name, us_per_call, derived)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]

# Flipped by ``benchmarks/run.py --smoke``: every benchmark executes exactly
# one timed step (no warmup beyond the compile call) so CI can catch
# benchmark bit-rot in minutes without caring about the numbers.
SMOKE = False


def time_jit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of fn(*args) after jit warmup."""
    if SMOKE:
        iters, warmup = 1, 0
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
