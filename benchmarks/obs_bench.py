"""Telemetry overhead benchmark (ISSUE-8 acceptance gate).

The obs layer's contract is "always-on costs nothing you can measure":
every per-step mutation is a host-side counter bump or histogram insert,
and the jitted computation is untouched either way (tests/test_obs.py
pins the jaxprs equal).  This benchmark prices the claim on the two hot
paths that pay it every iteration -- the fused train step and the paged
serving tick.

Estimator.  A naive enabled-vs-disabled A/B of whole steps cannot
resolve the quantity under test: the true telemetry delta is a fraction
of a percent of a multi-millisecond step, while wall-clock drift on a
shared CPU moves phase means by several percent in either direction
between runs (measured while building this bench -- interleaving and
order-alternation do not save the gate from flapping).  So the overhead
is measured where it is actually measurable, then compared against the
real step time:

  1. run the real workload once and read the engine's / loop's OWN
     registry deltas to learn the exact per-iteration op mix (ticks,
     token records, admissions, finishes -- no modeling);
  2. replay exactly that op mix thousands of times, enabled vs
     disabled, where the sub-microsecond per-op costs average cleanly
     (noise ~ 1/sqrt(N));
  3. gate on ``ratio = (T - delta) / T`` with ``T`` the measured
     enabled wall time and ``delta`` the replayed telemetry cost --
     the disabled/enabled ratio this implies.

Rows (CI-gated by benchmarks/check_fusion.py's generic ``expect_ge``
hook): ``obs/overhead/{train_step,serving_tick}/expect_ge_0.98`` with
``ratio=`` in the derived column -- ratio >= 0.98 means enabling
telemetry costs under ~2%.  The replay loops always run in full, even
under ``run.py --smoke``: the ratio is the gated quantity.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import obs

REPLAYS = 3000


def _wall(fn, iters: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _replay_delta(fn, iters: int = REPLAYS) -> float:
    """Mean extra seconds per fn() with collectors enabled vs disabled.
    fn is pure telemetry (no jax work), so each call is microseconds and
    the mean over thousands of calls is stable."""
    was_enabled = obs.enabled()
    try:
        per = []
        for enabled in (True, False):
            obs.enable() if enabled else obs.disable()
            for _ in range(50):
                fn()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            per.append((time.perf_counter() - t0) / iters)
    finally:
        obs.enable() if was_enabled else obs.disable()
    return max(per[0] - per[1], 0.0)


def _build_train():
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig, TrainConfig)
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import SyntheticSpec
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step
    cfg = ModelConfig(name="obs-bench", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                      d_ff=128, vocab_size=256, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="none"),
                    train=TrainConfig(global_batch=2, seq_len=32, steps=1))
    model = build(run)
    state = state_lib.create(model.init(jax.random.PRNGKey(0)))
    step_fn = jax.jit(make_train_step(model, run))
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=32, kind="lm")
    loader = ShardedLoader(spec, global_batch=2, process_index=0,
                           process_count=1, seed=0)
    batch = jax.tree_util.tree_map(jnp.asarray, loader.next_batch())
    return step_fn, state, batch


def train_step_rows():
    step_fn, state, batch = _build_train()
    n_tok = int(np.size(batch["tokens"])) if "tokens" in batch else 0

    def one_step():
        # exactly what train/loop.py wraps around the jitted step
        with obs.span("train.step", step=0):
            t0 = time.perf_counter()
            _, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
        obs.record_train_step(dt, float(metrics["loss"]),
                              float(metrics["grad_norm"]),
                              float(metrics["lr"]), n_tok)

    t_step = _wall(one_step)

    def replay():
        with obs.span("train.step", step=0):
            pass
        obs.record_train_step(0.003, 6.9, 0.2, 1e-3, n_tok)

    delta = _replay_delta(replay)
    ratio = (t_step - delta) / t_step
    return [
        ("obs/overhead/train_step_enabled", t_step * 1e6, ""),
        ("obs/overhead/train_step/expect_ge_0.98", delta * 1e6,
         f"ratio={ratio:.4f}"),
    ]


def serving_tick_rows():
    from benchmarks.serving_bench import _build_model, _requests
    from repro.serving import AdapterPool, ServingEngine, init_adapters
    model, params, cfg = _build_model("none")
    n_adapters = 2
    pool = AdapterPool(model)
    for i, tree in enumerate(init_adapters(model, n_adapters,
                                           jax.random.PRNGKey(7))):
        pool.register(f"tenant-{i}", tree)
    engine = ServingEngine(model, params, pool, n_slots=4)
    reqs = _requests(cfg, n_adapters, 4)
    o = engine.obs

    engine.run(reqs)                           # jit warmup
    # one measured drain + its registry deltas = the exact op mix the
    # telemetry layer executed for it (engine's own counters, no model)
    before = (o.ticks.value, o.tokens.value, o.latency.count)
    t_drain = _wall(lambda: engine.run(reqs))
    engine.run(reqs)  # discard: make the counted drain a steady-state one
    mark = (o.ticks.value, o.tokens.value, o.latency.count)
    engine.run(reqs)
    ticks = int(o.ticks.value - mark[0])
    tokens = int(o.tokens.value - mark[1])
    finishes = int(o.latency.count - mark[2])
    assert ticks > 0 and before[0] < mark[0]
    recs_per_tick = max(tokens // ticks, 1)

    def replay_drain():
        for _ in range(finishes):
            o.submitted.inc()
        for t in range(ticks):
            with obs.span("engine.step", engine=o.engine_id, tick=t):
                pass
            o.ticks.inc()
            o.tick_seconds.observe(0.001)
            o.inflight.set(4)
            o.pending.set(0)
            o.requeued.set(0)
            o.tick_utilization.set(1.0)
            for g in o.pool.values():
                g.set(3)
            o.prefill_rows.inc(1)
            o.decode_rows.inc(3)
            for _ in range(recs_per_tick):
                o.tokens.inc()
        for _ in range(finishes):
            o.ttft.observe(0.01)
            o.queue_wait.observe(0.001)
            o.latency.observe(0.02)
            o.finished("length")

    delta = _replay_delta(replay_drain, iters=max(REPLAYS // ticks, 200))
    ratio = (t_drain - delta) / t_drain
    return [
        ("obs/overhead/serving_drain_enabled", t_drain * 1e6,
         f"ticks={ticks};tokens={tokens}"),
        ("obs/overhead/serving_tick/expect_ge_0.98", delta * 1e6,
         f"ratio={ratio:.4f}"),
    ]


def run():
    rows = train_step_rows()
    rows += serving_tick_rows()
    return rows


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        common.SMOKE = True
    print("name,us_per_call,derived")
    common.emit(run())
