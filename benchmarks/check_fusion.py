"""CI gate on the emitted fusion-plan + serving-speedup report.

``kernels_bench.fusion_plan_rows`` (and ``serving_bench`` for the
multi-adapter kernels) emit one ``fusion_plan/.../expect_X`` row per
adapted linear per representative config, with the mode the dispatcher
ACTUALLY picked in the derived column (``got=Y``); ``.../expect_ge_T``
ratio rows self-describe their thresholds.  This script reads the
benchmark JSON artifact (``run.py --json``) and fails on any silent
fused->unfused fallback or below-threshold ratio.

Since ISSUE-9 the detectors live in ``repro.analysis`` (the
``fusion-plan`` and ``ratio-threshold`` bench-layer rules, also run by
``python -m repro.analysis --bench``); this wrapper keeps the historical
CLI and exit codes.

Usage: python -m benchmarks.check_fusion bench-smoke.json
"""
from __future__ import annotations

import json
import sys


def check(rows) -> int:
    from repro.analysis import core
    core._load_shipped()
    report = core.run_layer("bench", [core.BenchRows(rows)])
    for f in report.findings:
        print(f"check_fusion: {f.where}: {f.message}", file=sys.stderr)
    n_plan = sum(1 for r in rows if r["name"].startswith("fusion_plan/"))
    n_ratio = sum(1 for r in rows if "/expect_ge_" in r["name"])
    print(f"check_fusion: {n_plan} fusion-plan rows and {n_ratio} ratio "
          f"rows checked, {len(report.findings)} finding(s)")
    return 1 if report.findings else 0


def main() -> None:
    if len(sys.argv) != 2:
        print("usage: check_fusion.py <bench.json>", file=sys.stderr)
        sys.exit(2)
    with open(sys.argv[1]) as f:
        rows = json.load(f)
    sys.exit(check(rows))


if __name__ == "__main__":
    main()
