"""CI gate on the emitted fusion-plan + serving-speedup report.

``kernels_bench.fusion_plan_rows`` (and ``serving_bench`` for the
multi-adapter kernels) emit one ``fusion_plan/.../expect_X`` row per
adapted linear per representative config, with the mode the dispatcher
ACTUALLY picked in the derived column (``got=Y``).  This script reads the
benchmark JSON artifact (``run.py --json``) and fails if any
expected-fused path silently fell back to the unfused oracle -- a perf
regression the test suite can't see, since unfused is numerically
identical.

It also enforces every ``.../expect_ge_T`` ratio row:
``serving/speedup/...`` (multi-adapter batched decode >= T times the
N-sequential-batches baseline, the ISSUE-3 acceptance number) and
``serving/load/...`` (ISSUE-6: paged-engine saturation throughput >= the
fixed-slot scheduler, and its p99 latency not collapsing, under open-loop
Poisson traffic with shared system prompts).

Usage: python -m benchmarks.check_fusion bench-smoke.json
"""
from __future__ import annotations

import json
import sys


def check(rows) -> int:
    plan = [r for r in rows if r["name"].startswith("fusion_plan/")]
    if not plan:
        print("check_fusion: no fusion_plan/* rows in the report -- the "
              "benchmark no longer emits the plan", file=sys.stderr)
        return 1
    bad = []
    for r in plan:
        expect = r["name"].rsplit("/expect_", 1)[-1]
        got = dict(kv.split("=", 1) for kv in r["derived"].split(";"))["got"]
        if got != expect:
            bad.append((r["name"], got))
    for name, got in bad:
        print(f"check_fusion: {name} fell back to '{got}'", file=sys.stderr)

    # every ratio row self-describes its gate: .../expect_ge_T with the
    # measured value in the derived column (key `ratio`, or the legacy
    # `multi_over_seq` spelling on the serving/speedup rows)
    speedups = [r for r in rows if "/expect_ge_" in r["name"]]
    slow = []
    for r in speedups:
        threshold = float(r["name"].rsplit("/expect_ge_", 1)[-1])
        kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
        ratio = float(kv.get("ratio", kv.get("multi_over_seq")))
        if ratio < threshold:
            slow.append((r["name"], ratio, threshold))
    for name, ratio, threshold in slow:
        print(f"check_fusion: {name} measured {ratio:.2f}x "
              f"(< {threshold}x)", file=sys.stderr)
    print(f"check_fusion: {len(plan)} fusion-plan rows checked, "
          f"{len(bad)} unexpected fallbacks; {len(speedups)} serving "
          f"speedup rows checked, {len(slow)} below threshold")
    return 1 if (bad or slow) else 0


def main() -> None:
    if len(sys.argv) != 2:
        print("usage: check_fusion.py <bench.json>", file=sys.stderr)
        sys.exit(2)
    with open(sys.argv[1]) as f:
        rows = json.load(f)
    sys.exit(check(rows))


if __name__ == "__main__":
    main()
