"""Paper Fig. 1: OFT (weight-centric, exact Cayley) vs OFTv2 (input-centric,
Cayley-Neumann): training step time + adapter-side memory.

CPU-measured at a reduced scale (d=1024, the trend is what matters) plus the
analytic accounting at Qwen2.5-7B scale that the paper's figure reports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.config.base import AdapterConfig
from repro.core import adapter as ad
from repro.core import oft, skew


def measured_rows(d=1024, n=1024, tokens=2048):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (tokens, d), jnp.float32)
    w = jax.random.normal(key, (d, n), jnp.float32) / 32
    params = {"q_packed": 0.02 * jax.random.normal(key, (d // 32, 496))}

    rows = []
    variants = {
        "fig1/oftv1_exact_cayley": AdapterConfig(kind="oftv1", block_size=32,
                                                 neumann_terms=0),
        "fig1/oftv1_cnp": AdapterConfig(kind="oftv1", block_size=32,
                                        neumann_terms=5),
        "fig1/oftv2_cnp": AdapterConfig(kind="oftv2", block_size=32,
                                        neumann_terms=5),
        # one-kernel rotate+matmul (interpret-mode Pallas on CPU: validates
        # the trajectory; the HBM win shows in the analytic rows below)
        "fig1/oftv2_cnp_fused": AdapterConfig(kind="oftv2", block_size=32,
                                              neumann_terms=5,
                                              fuse_linear=True),
    }
    for name, acfg in variants.items():
        def step(p, x, w, acfg=acfg):
            def loss(p):
                y = ad.adapted_linear(x, {"w": w}, p, acfg,
                                      __import__("repro.config.base",
                                                 fromlist=["QuantConfig"]
                                                 ).QuantConfig())
                return jnp.sum(jnp.square(y))
            l, g = jax.value_and_grad(loss)(p)
            return l, g
        jitted = jax.jit(step)
        us = time_jit(jitted, params, x, w)
        rows.append((name, us, f"d={d};n={n};tokens={tokens}"))
    return rows


def analytic_rows():
    """Adapter-path FLOPs at Qwen2.5-7B scale (d=3584, d_ff=18944),
    tokens = 16 seqs x 2048 -- the cubic-vs-quadratic gap of paper §3.2."""
    rows = []
    d, n, tokens, b = 3584, 3584, 16 * 2048, 32
    f_v1 = oft.oft_flops_per_step(d, n, tokens, b, input_centric=False)
    f_v2 = oft.oft_flops_per_step(d, n, tokens, b, input_centric=True)
    rows.append(("fig1/analytic_v1_weight_transform_flops", 0.0,
                 f"{f_v1:.3e}"))
    rows.append(("fig1/analytic_v2_input_apply_flops", 0.0, f"{f_v2:.3e}"))
    # v1 additionally materializes a d x n bf16 weight copy (+ grad buffer)
    # per adapted linear per step; v2 stores packed Q only.
    v1_bytes = 2 * d * n * 2
    v2_bytes = oft.oft_param_count(d, b) * 4
    rows.append(("fig1/analytic_v1_extra_bytes_per_linear", 0.0,
                 f"{v1_bytes:.3e}"))
    rows.append(("fig1/analytic_v2_adapter_bytes_per_linear", 0.0,
                 f"{v2_bytes:.3e}"))
    rows.append(("fig1/analytic_memory_ratio", 0.0,
                 f"{v1_bytes / v2_bytes:.1f}x"))
    # fused-vs-unfused HBM traffic for one adapted linear at the same scale
    # (the kernel-fusion contribution on top of the paper's v1->v2 win)
    from repro.roofline.kernels import linear_hbm_bytes
    for tag, qbs in [("oftv2", 0), ("qoft_nf4", 64)]:
        hbm_u = linear_hbm_bytes(tokens, d, n, b, fused=False, quant_bs=qbs)
        hbm_f = linear_hbm_bytes(tokens, d, n, b, fused=True, quant_bs=qbs)
        rows.append((f"fig1/analytic_{tag}_fused_hbm_traffic", 0.0,
                     f"unfused={hbm_u:.3e};fused={hbm_f:.3e};"
                     f"ratio={hbm_u / hbm_f:.2f}x"))
    return rows


def run():
    return measured_rows() + analytic_rows()


if __name__ == "__main__":
    emit(run())
