"""Paper Tables 1-2: training step time, LoRA vs OFTv2 (bf16) and QLoRA vs
QOFT (NF4), measured on CPU at a reduced model scale (2 layers, d=256).
The paper's observation to reproduce: OFTv2 is within a small factor of
LoRA in full precision and at parity (or faster) in the quantized setting
where dequant dominates."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.models import build
from repro.train import state as state_lib
from repro.train.step import make_train_step


def step_time(adapter: str, quant: str, d=256, layers=2, seq=128, batch=4):
    cfg = ModelConfig(name="bench", num_layers=layers, d_model=d,
                      num_heads=8, num_kv_heads=4, d_ff=4 * d,
                      vocab_size=2048, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind=adapter, block_size=32,
                                          neumann_terms=5, rank=16),
                    quant=QuantConfig(kind=quant),
                    train=TrainConfig(learning_rate=1e-3, steps=100,
                                      warmup_steps=0))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    st = state_lib.create(params)
    batch_d = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                            (batch, seq), 0, 2048)}
    fn = jax.jit(make_train_step(model, run))
    return time_jit(fn, st, batch_d, iters=5, warmup=2)


def run():
    rows = []
    for name, adapter, quant in [
            ("table1/lora_bf16", "lora", "none"),
            ("table1/oftv2_bf16", "oftv2", "none"),
            ("table1/oftv1_bf16", "oftv1", "none"),
            ("table2/qlora_nf4", "lora", "nf4"),
            ("table2/qoft_nf4", "oftv2", "nf4")]:
        us = step_time(adapter, quant)
        rows.append((name, us, "train_step;d=256;L=2;seq=128;b=4"))
    return rows


if __name__ == "__main__":
    emit(run())
