"""Roofline report: reads artifacts/dryrun/*.json and prints the per-cell
table that EXPERIMENTS.md §Roofline embeds (single-pod cells) plus the
multi-pod dry-run summary for §Dry-run."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str):
    recs = []
    for p in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return recs


def fmt_bytes(b):
    return f"{b / 1e9:.2f}GB"


def roofline_table(mesh="single"):
    rows = []
    header = ("arch", "shape", "compute_s", "memory_s", "collective_s",
              "bottleneck", "useful_frac", "temp_mem", "args_mem")
    rows.append(",".join(header))
    for r in load(mesh):
        if r.get("skipped"):
            rows.append(f"{r['arch']},{r['shape']},SKIP({r['skipped']}),,,,,,")
            continue
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        rows.append(",".join([
            r["arch"], r["shape"], f"{t['compute_s']:.3e}",
            f"{t['memory_s']:.3e}", f"{t['collective_s']:.3e}",
            t["bottleneck"],
            f"{r['model_flops']['useful_fraction']:.3f}",
            fmt_bytes(mem.get("temp_size_in_bytes", 0)),
            fmt_bytes(mem.get("argument_size_in_bytes", 0)),
        ]))
    return rows


def run():
    out = []
    for mesh in ("single", "multi"):
        recs = [r for r in load(mesh) if not r.get("skipped")]
        out.append((f"roofline/{mesh}_cells_compiled", 0.0,
                    f"{len(recs)}"))
    return out


def main():
    for line in roofline_table("single"):
        print(line)
    print()
    for name, _, derived in run():
        print(f"{name},{derived}")


if __name__ == "__main__":
    main()
