"""Paper Tables 3/4/5 quality proxy: finetune the same frozen base on the
deterministic synthetic LM task with each adapter at matched budget and
report final loss (lower=better).  Reproduces the paper's *relative* claims
(OFTv2/QOFT in the same quality band as (or better than) LoRA/QLoRA with
~half the trainable parameters) -- absolute ROUGE/GSM8K need the real
datasets, unavailable offline (DESIGN.md §7)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.models import build
from repro.train.loop import run_training


def finetune(adapter: str, quant: str, steps=60, rank=8, block=16):
    cfg = ModelConfig(name="q", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    # paper hyperparameters (Appx A): OFT uses a 4x higher LR than LoRA
    lr = 4e-3 if adapter != "oftv2" else 1.6e-2
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind=adapter, block_size=block,
                                          neumann_terms=5, rank=rank,
                                          alpha=2.0 * rank),
                    quant=QuantConfig(kind=quant, block_size=32),
                    train=TrainConfig(global_batch=8, seq_len=32,
                                      steps=steps, learning_rate=lr,
                                      warmup_steps=5, ckpt_every=0,
                                      log_every=0,
                                      ckpt_dir="/tmp/bench_quality"))
    model = build(run)
    loader = ShardedLoader(SyntheticSpec(vocab_size=64, seq_len=32,
                                         noise=0.05),
                           global_batch=8, seed=0)
    out = run_training(model, run, loader, log=lambda s: None)
    final = float(np.mean(out["losses"][-10:]))
    n_adapter = model.param_counts()["adapter"]
    return final, n_adapter


def run():
    rows = []
    for name, adapter, quant in [
            ("table3/lora_bf16", "lora", "none"),
            ("table3/oftv2_bf16", "oftv2", "none"),
            ("table45/qlora_nf4", "lora", "nf4"),
            ("table45/qoft_nf4", "oftv2", "nf4"),
            ("table45/baseline_frozen", "none", "nf4")]:
        loss, n = finetune(adapter, quant)
        rows.append((name, 0.0, f"final_loss={loss:.4f};"
                                f"trainable={n}"))
    return rows


if __name__ == "__main__":
    emit(run())
