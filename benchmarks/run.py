"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/README convention).

``--smoke``: execute every benchmark for exactly one step (interpret-mode
Pallas on CPU) -- numbers are meaningless but bit-rot (import errors, shape
breaks, renamed APIs) is caught in CI in minutes."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import common
    unknown = [a for a in sys.argv[1:] if a != "--smoke"]
    if unknown:
        # a typo'd --smoke silently running the full multi-minute suite is
        # exactly the kind of CI bit-rot this driver exists to catch
        print(f"unknown argument(s): {unknown}; usage: run.py [--smoke]",
              file=sys.stderr)
        sys.exit(2)
    if "--smoke" in sys.argv:
        common.SMOKE = True
    from benchmarks import (fig1_oft_vs_oftv2, fig4_memory, kernels_bench,
                            requant_error, roofline_report, table12_speed,
                            table345_quality)
    from benchmarks.common import emit

    modules = [
        ("fig1 (OFT vs OFTv2 time/memory)", fig1_oft_vs_oftv2),
        ("fig4 (memory across scales/formats)", fig4_memory),
        ("table1/2 (step time vs LoRA/QLoRA)", table12_speed),
        ("table3/4/5 (quality proxy at matched budget)", table345_quality),
        ("§4 requantization error", requant_error),
        ("kernels", kernels_bench),
        ("roofline artifacts", roofline_report),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, mod in modules:
        print(f"# --- {title} ---")
        try:
            emit(mod.run())
        except Exception:                                   # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
