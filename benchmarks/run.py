"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/README convention).

``--smoke``: execute every benchmark for exactly one step (interpret-mode
Pallas on CPU) -- numbers are meaningless but bit-rot (import errors, shape
breaks, renamed APIs) is caught in CI in minutes.

``--json PATH``: additionally dump all rows as a JSON list of
``{"name", "us_per_call", "derived"}`` objects -- CI uploads this as a
workflow artifact and gates on it (benchmarks/check_fusion.py)."""
from __future__ import annotations

import json
import sys
import traceback


def _parse_args(argv):
    smoke = False
    json_path = None
    unknown = []
    it = iter(argv)
    for a in it:
        if a == "--smoke":
            smoke = True
        elif a == "--json":
            json_path = next(it, None)
            if json_path is None or json_path.startswith("-"):
                # a flag in path position means the path was omitted --
                # don't eat e.g. --smoke and run the full suite in CI
                print("--json requires a path", file=sys.stderr)
                sys.exit(2)
        else:
            unknown.append(a)
    if unknown:
        # a typo'd --smoke silently running the full multi-minute suite is
        # exactly the kind of CI bit-rot this driver exists to catch
        print(f"unknown argument(s): {unknown}; "
              "usage: run.py [--smoke] [--json PATH]", file=sys.stderr)
        sys.exit(2)
    return smoke, json_path


def main() -> None:
    from benchmarks import common
    smoke, json_path = _parse_args(sys.argv[1:])
    if smoke:
        common.SMOKE = True
    from benchmarks import (fig1_oft_vs_oftv2, fig4_memory, kernels_bench,
                            methods_bench, obs_bench, requant_error,
                            resilience_bench, roofline_report,
                            serving_bench, sharded_bench, table12_speed,
                            table345_quality)
    from benchmarks.common import emit

    modules = [
        ("fig1 (OFT vs OFTv2 time/memory)", fig1_oft_vs_oftv2),
        ("fig4 (memory across scales/formats)", fig4_memory),
        ("table1/2 (step time vs LoRA/QLoRA)", table12_speed),
        ("table3/4/5 (quality proxy at matched budget)", table345_quality),
        ("§4 requantization error", requant_error),
        ("kernels", kernels_bench),
        ("adapter methods (registry sweep)", methods_bench),
        ("multi-tenant serving", serving_bench),
        ("mesh-sharded fused path", sharded_bench),
        ("resilience (recovery + degradation)", resilience_bench),
        ("roofline artifacts", roofline_report),
        ("telemetry overhead", obs_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for title, mod in modules:
        print(f"# --- {title} ---")
        try:
            rows = mod.run()
            emit(rows)
            all_rows.extend(rows)
        except Exception:                                   # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if json_path:
        with open(json_path, "w") as f:
            json.dump([{"name": n, "us_per_call": us, "derived": d}
                       for n, us, d in all_rows], f, indent=1)
        print(f"# wrote {len(all_rows)} rows to {json_path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
