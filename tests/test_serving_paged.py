"""Serving v2 integration: the paged data plane (block pool + chunked
prefill + prefix sharing) must decode token-for-token what the PR-3
fixed-slot path and the single-run ``generate`` oracle produce, and the
submit()/step()/drain() API must report faithful per-request results.

The bitwise contract chain: ``generate`` wraps a single-adapter slots
engine; the multi-adapter slots engine is the PR-3 data plane (bucketed
batch-1 prefill + rectangular caches); the paged engine shares neither
prefill nor cache layout with them -- agreement is a real check, not a
tautology."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig, \
    RunConfig
from repro.models import build


def _serving_model(qkind="none"):
    cfg = ModelConfig(name=f"pg_{qkind}", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind=qkind, block_size=32))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def _pooled(model, n_adapters=2):
    from repro.serving import AdapterPool, init_adapters
    adapters = init_adapters(model, n_adapters, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"t{i}", tree)
    return pool, adapters


def _prompts(cfg, lengths, seed=3):
    return [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(seed), i), (n,), 0,
        cfg.vocab_size)) for i, n in enumerate(lengths)]


# ---------------------------------------------------------------------------
# paged == slots == generate (the satellite regression: bucketed and paged
# prefill agree token-for-token; no bucketing artifacts in the paged path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qkind", ["none", "nf4"])
def test_paged_equals_bucketed_equals_generate(qkind):
    from repro.serving import Request, SamplingParams, ServingEngine
    from repro.train.serving import generate
    model, params, cfg = _serving_model(qkind)
    pool, adapters = _pooled(model)
    # lengths off the 8-bucket: the slots path pads to multiples of 8 and
    # invalidates the tail; the paged path allocates exact-length blocks
    lengths, gen = [3, 6, 11, 9], 5
    prompts = _prompts(cfg, lengths)
    reqs = [Request(f"r{i}", prompts[i], adapter_id=i % 2,
                    sampling=SamplingParams(max_new_tokens=gen))
            for i in range(4)]
    paged = ServingEngine(model, params, pool, n_slots=4, mode="paged",
                          page_size=4, prefill_chunk=8).run(reqs)
    slots = ServingEngine(model, params, pool, n_slots=4,
                          mode="slots").run(reqs)
    for i in range(4):
        np.testing.assert_array_equal(paged[f"r{i}"], slots[f"r{i}"])
        single = {"base": params["base"], "adapter": adapters[i % 2]}
        full = generate(model, single, jnp.asarray(prompts[i])[None],
                        sampling=SamplingParams(max_new_tokens=gen))
        np.testing.assert_array_equal(paged[f"r{i}"],
                                      np.asarray(full)[0, lengths[i]:])


def test_chunked_prefill_long_prompt_interleaves_and_matches():
    """A prompt much longer than the chunk is prefilled across many ticks
    while a short request decodes in between -- and both still produce
    exactly their single-run tokens."""
    from repro.serving import Request, SamplingParams, ServingEngine
    from repro.train.serving import generate
    model, params, cfg = _serving_model()
    pool, adapters = _pooled(model)
    long_p, short_p = _prompts(cfg, [37, 4])
    gen = 4
    eng = ServingEngine(model, params, pool, n_slots=2, mode="paged",
                        page_size=4, prefill_chunk=8)
    eng.submit(Request("long", long_p, adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=gen)))
    eng.submit(Request("short", short_p, adapter_id=1,
                       sampling=SamplingParams(max_new_tokens=gen)))
    # the short request finishes while the long one is still prefilling
    # (37 tokens / chunk 8 = 5 prefill ticks; short needs 1 + 3 ticks)
    ticks_to_short = 0
    done = {}
    while eng.has_work():
        for res in eng.step():
            done[res.rid] = res
        ticks_to_short += 1
        if "short" in done:
            break
    assert "long" not in done     # chunked prefill did not stall the batch
    while eng.has_work():
        for res in eng.step():
            done[res.rid] = res
    for rid, prompt, aid in [("long", long_p, 0), ("short", short_p, 1)]:
        single = {"base": params["base"], "adapter": adapters[aid]}
        full = generate(model, single, jnp.asarray(prompt)[None],
                        sampling=SamplingParams(max_new_tokens=gen))
        np.testing.assert_array_equal(done[rid].tokens,
                                      np.asarray(full)[0, len(prompt):])


def test_prefix_sharing_same_adapter_exact_and_counted():
    """Requests repeating a system prompt under the SAME adapter skip its
    prefill (prefix_blocks_shared > 0) and still decode exactly; a
    different adapter must NOT reuse those blocks (k/v are
    adapter-rotated)."""
    from repro.serving import Request, SamplingParams, ServingEngine
    from repro.train.serving import generate
    model, params, cfg = _serving_model()
    pool, adapters = _pooled(model)
    sys_p = list(range(1, 13))
    eng = ServingEngine(model, params, pool, n_slots=2, mode="paged",
                        page_size=4, prefill_chunk=4)
    eng.submit(Request("warm", sys_p + [50, 51], adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.drain()
    eng.submit(Request("same", sys_p + [60], adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.submit(Request("other", sys_p + [60], adapter_id=1,
                       sampling=SamplingParams(max_new_tokens=3)))
    res = eng.drain()
    assert res["same"].prefix_blocks_shared >= 3
    assert res["other"].prefix_blocks_shared == 0
    for rid, aid in [("same", 0), ("other", 1)]:
        single = {"base": params["base"], "adapter": adapters[aid]}
        full = generate(model, single, jnp.asarray(sys_p + [60])[None],
                        sampling=SamplingParams(max_new_tokens=3))
        np.testing.assert_array_equal(res[rid].tokens,
                                      np.asarray(full)[0, 13:])
    eng._state["kv"].audit()


def test_partial_block_cow_divergence_exact():
    """Prompts sharing a partial tail block diverge after the copy-on-
    write -- both decode exactly their single-run tokens."""
    from repro.serving import Request, SamplingParams, ServingEngine
    from repro.train.serving import generate
    model, params, cfg = _serving_model()
    pool, adapters = _pooled(model)
    p1 = list(range(1, 9)) + [20, 21, 22]
    p2 = list(range(1, 9)) + [20, 21, 99]     # diverges inside the block
    eng = ServingEngine(model, params, pool, n_slots=2, mode="paged",
                        page_size=8, prefill_chunk=8)
    eng.submit(Request("x", p1, adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.drain()
    eng.submit(Request("y", p2, adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=2)))
    ry = eng.drain()["y"]
    assert eng._state["kv"].stats["cow_copies"] == 1
    single = {"base": params["base"], "adapter": adapters[0]}
    full = generate(model, single, jnp.asarray(p2)[None],
                    sampling=SamplingParams(max_new_tokens=2))
    np.testing.assert_array_equal(ry.tokens, np.asarray(full)[0, len(p2):])


def test_block_pressure_queues_requests_and_completes():
    """More concurrent demand than KV blocks: the admission gate queues
    requests instead of exhausting the pool mid-flight, and everyone
    still finishes with exact tokens."""
    from repro.serving import Request, SamplingParams, ServingEngine
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    prompts = _prompts(cfg, [8] * 6)
    reqs = [Request(f"r{i}", prompts[i], adapter_id=i % 2,
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(6)]
    # 6 slots but only enough blocks for ~2 requests at a time
    tight = ServingEngine(model, params, pool, n_slots=6, mode="paged",
                          page_size=4, num_blocks=8, prefill_chunk=8,
                          s_max=12)
    roomy = ServingEngine(model, params, pool, n_slots=6, mode="paged",
                          page_size=4, prefill_chunk=8, s_max=12)
    out_t = tight.run(reqs)
    out_r = roomy.run(reqs)
    for i in range(6):
        np.testing.assert_array_equal(out_t[f"r{i}"], out_r[f"r{i}"])
    tight._state["kv"].audit()


# ---------------------------------------------------------------------------
# the v2 API surface
# ---------------------------------------------------------------------------
def test_submit_step_drain_lifecycle_and_timing():
    from repro.serving import (FINISH_LENGTH, GenerationResult, Request,
                               SamplingParams, ServingEngine)
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = ServingEngine(model, params, pool, n_slots=2)
    assert not eng.has_work()
    assert eng.step() == []                  # idle step is a no-op
    prompt = _prompts(cfg, [5])[0]
    eng.submit(Request("r0", prompt, adapter_id=0,
                       sampling=SamplingParams(max_new_tokens=3)))
    assert eng.has_work()
    results = eng.drain()
    assert not eng.has_work()
    res = results["r0"]
    assert isinstance(res, GenerationResult)
    assert res.finish_reason == FINISH_LENGTH
    assert res.prompt_len == 5 and res.n_generated == 3
    assert res.tokens.dtype == np.int32
    assert res.submitted_at <= res.first_token_at <= res.finished_at
    assert res.ttft > 0 and res.latency >= res.ttft


def test_eos_stops_early_with_finish_stop():
    from repro.serving import (FINISH_STOP, Request, SamplingParams,
                               ServingEngine)
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    prompt = _prompts(cfg, [6])[0]
    eng = ServingEngine(model, params, pool, n_slots=1)
    probe = eng.run([Request("probe", prompt, adapter_id=0,
                             sampling=SamplingParams(max_new_tokens=8))])
    second = int(probe["probe"][1])          # greedy is deterministic
    eng2 = ServingEngine(model, params, pool, n_slots=1)
    eng2.submit(Request("r0", prompt, adapter_id=0,
                        sampling=SamplingParams(max_new_tokens=8,
                                                eos_id=second)))
    res = eng2.drain()["r0"]
    assert res.finish_reason == FINISH_STOP
    assert res.n_generated == 2 and int(res.tokens[-1]) == second


def test_run_compat_wrapper_and_validation():
    """run() keeps the v1 surface: dict of raw token arrays, batch-level
    duplicate/adapter validation with the v1 messages."""
    from repro.serving import Request, ServingEngine
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = ServingEngine(model, params, pool, n_slots=2)
    assert eng.run([]) == {}
    with pytest.raises(ValueError, match="adapter_id 5 outside"):
        eng.run([Request("bad", [1, 2], adapter_id=5)])
    with pytest.raises(ValueError, match="duplicate request ids"):
        eng.run([Request("r0", [1, 2]), Request("r0", [3, 4])])
    out = eng.run([Request("r0", [1, 2], max_new_tokens=2)])
    assert isinstance(out["r0"], np.ndarray) and len(out["r0"]) == 2
    # rid is reusable after its result was drained
    out2 = eng.run([Request("r0", [1, 2], max_new_tokens=2)])
    np.testing.assert_array_equal(out["r0"], out2["r0"])


def test_single_adapter_engine_pool_none():
    """pool=None serves the params as-is (what generate() wraps): paged
    and slots modes agree without any routing."""
    from repro.serving import Request, SamplingParams, ServingEngine
    model, params, cfg = _serving_model()
    prompts = _prompts(cfg, [5, 9])
    reqs = [Request(f"r{i}", prompts[i],
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(2)]
    paged = ServingEngine(model, params, pool=None, n_slots=2,
                          mode="paged", page_size=4).run(reqs)
    slots = ServingEngine(model, params, pool=None, n_slots=2,
                          mode="slots").run(reqs)
    for rid in paged:
        np.testing.assert_array_equal(paged[rid], slots[rid])
    with pytest.raises(ValueError, match="without\nan adapter pool|without "
                       "an adapter pool"):
        ServingEngine(model, params, pool=None, n_slots=1).submit(
            Request("x", [1], adapter_id=3))


def test_request_validation_and_legacy_kwargs():
    from repro.serving import Request, SamplingParams
    with pytest.raises(ValueError, match="empty prompt"):
        Request("r0", [])
    with pytest.raises(ValueError, match="max_new_tokens"):
        SamplingParams(max_new_tokens=0)
    with pytest.raises(ValueError, match="not both"):
        Request("r0", [1], sampling=SamplingParams(), max_new_tokens=4)
    legacy = Request("r0", [1, 2], adapter_id=1, max_new_tokens=7, eos_id=9)
    assert legacy.max_new_tokens == 7 and legacy.eos_id == 9
    assert legacy.sampling.max_new_tokens == 7


def test_deprecated_import_path_and_generate_signature():
    """The two deprecated spellings still work, loudly."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        from repro.serving.scheduler import Request as OldRequest
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    from repro.serving.api import Request as NewRequest
    assert OldRequest is NewRequest

    from repro.train.serving import generate
    model, params, cfg = _serving_model()
    prompt = jnp.zeros((1, 4), jnp.int32)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = generate(model, params, prompt, steps=3)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    from repro.serving import SamplingParams
    new = generate(model, params, prompt,
                   sampling=SamplingParams(max_new_tokens=3))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))
    assert new.shape == (1, 7)
    with pytest.raises(TypeError, match="not both"):
        generate(model, params, prompt, steps=3,
                 sampling=SamplingParams(max_new_tokens=3))
    with pytest.raises(TypeError, match="requires sampling"):
        generate(model, params, prompt)
