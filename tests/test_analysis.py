"""ISSUE-9: the unified static contract checker (``repro.analysis``).

What is pinned down:
  * every registered rule is LIVE: its seeded known-bad fixture produces
    findings (a silently-dead detector fails its own selftest);
  * the AST rules flag code only -- the docstring/comment lines of the
    registry-dispatch fixture, which QUOTE banned patterns, must not
    flag (the regex predecessor's false positive, fixed by construction);
  * the real tree is clean under the AST layer;
  * the walkers themselves: jaxpr recursion into pjit/scan bodies with
    pallas interiors excluded, value-sensitive structural fingerprints
    (and their top-literal masking), HLO text parsing, axis_env traces;
  * the trace layer measures real jit caches, not a mock;
  * the README rule table and the CLI stay in sync with the registry;
  * the benchmark gate wrappers keep their historical APIs/exit codes.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import core, hlo, jaxprs, pyast

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# every rule is proven live by its own seeded fixture
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rule", core.all_rules(), ids=lambda r: r.id)
def test_rule_selftest_fixture_produces_findings(rule):
    findings = core.selftest(rule)
    assert all(f.rule == rule.id for f in findings)
    assert all(f.severity in core.SEVERITIES for f in findings)
    assert all(f.where and f.message for f in findings)


def test_registry_is_complete_and_unique():
    rules = core.all_rules()
    ids = [r.id for r in rules]
    assert len(set(ids)) == len(ids)
    assert {r.layer for r in rules} == set(core.LAYERS), (
        "some layer ships no rules -- the CLI would silently cover "
        "nothing there")
    # the ISSUE-9 rule set, by name
    for rid in ("no-dense-w-in-hbm", "collective-budget",
                "hlo-collective-budget", "no-baked-scalar", "no-retrace",
                "no-host-sync", "registry-dispatch", "documented-metrics",
                "no-wallclock-in-kernels"):
        assert core.get(rid).id == rid


def test_duplicate_rule_id_is_rejected():
    class Dup(core.Rule):
        id = "no-host-sync"
        layer = "jaxpr"

    with pytest.raises(ValueError, match="already registered"):
        core.register(Dup)


# ---------------------------------------------------------------------------
# AST layer: docstrings/comments are exempt; the real tree is clean
# ---------------------------------------------------------------------------
def test_dispatch_rule_ignores_docstrings_and_comments():
    """The fixture's first lines QUOTE banned patterns inside a docstring
    and a comment; only the real code lines below may flag."""
    rule = core.get("registry-dispatch")
    module = rule.fixture()
    flagged = {int(f.where.rsplit(":", 1)[1]) for f in rule.check(module)}
    doc_lines = {1, 3}                  # docstring + comment quoting bans
    assert not flagged & doc_lines, (
        f"docstring/comment lines flagged: {sorted(flagged & doc_lines)}")
    assert flagged, "fixture's genuine violations were missed"


def test_dispatch_rule_allows_methods_package_and_non_repro_paths():
    rule = core.get("registry-dispatch")
    bad = 'def f(acfg):\n    return acfg.kind == "oftv2"\n'
    assert rule.check(pyast.parse_source(
        bad, relpath="src/repro/methods/newmethod.py")) == []
    assert rule.check(pyast.parse_source(
        bad, relpath="benchmarks/foo.py")) == []
    assert rule.check(pyast.parse_source(
        bad, relpath="src/repro/serving/x.py"))


def test_dispatch_rule_allows_none_kind_and_quant_kind():
    """`self.kind != "none"` (has-adapter predicate) and quant-kind
    dispatch stay legal -- the historical regex drew the same line."""
    rule = core.get("registry-dispatch")
    ok = ('def f(self, qcfg):\n'
          '    return self.kind != "none" and qcfg.kind == "none"\n')
    assert rule.check(pyast.parse_source(
        ok, relpath="src/repro/config/x.py")) == []


def test_ast_layer_clean_on_real_tree():
    report = core.run_layer("ast", pyast.iter_modules(ROOT))
    assert report.checked["ast"] > 50
    assert report.findings == [], "\n".join(map(str, report.findings))


def test_documented_metrics_rule_accepts_documented_name():
    from repro.obs import schema
    rule = core.get("documented-metrics")
    name = next(iter(schema.SPECS))
    src = f'from repro import obs\nobs.metric("{name}").inc()\n'
    assert rule.check(pyast.parse_source(
        src, relpath="src/repro/serving/x.py")) == []


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------
def test_iter_eqns_recurses_into_nested_bodies_with_path():
    def inner(x):
        return jax.lax.scan(lambda c, t: (c + t, c), x, jnp.ones((3,)))[0]

    jx = jaxprs.trace(jax.jit(inner), jnp.float32(0.0))
    names = jaxprs.primitive_names(jx)
    assert "scan" in names and "add" in names
    paths = {path for eqn, path in jaxprs.iter_eqns(jx)
             if eqn.primitive.name == "add"}
    assert any("scan" in p for p in paths), paths


def test_walker_skips_pallas_interiors_but_sees_outvars():
    from repro.kernels import ops as kops
    x = jnp.ones((8, 64))
    r = jnp.tile(jnp.eye(16), (4, 1, 1))
    w = jnp.ones((64, 32))
    jx = jaxprs.trace(kops.oftv2_linear_fused, x, r, w)
    shaped = jaxprs.float_outvar_shapes(jx)
    prims = {prim for _, prim, _ in shaped}
    assert "pallas_call" in prims          # the kernel's HBM result
    assert (8, 32) in [s for s, p, _ in shaped if p == "pallas_call"]
    # nothing from inside the kernel body (its eqns are not walked)
    for _, _, path in shaped:
        assert "pallas_call" not in path


def test_structural_fingerprint_catches_baked_literal():
    def at(i):
        return lambda p: p.at[i].set(0.0)

    a = jaxprs.structural_fingerprint(jaxprs.trace(at(1), jnp.zeros((4,))))
    b = jaxprs.structural_fingerprint(jaxprs.trace(at(2), jnp.zeros((4,))))
    assert a != b
    assert "!=" in jaxprs.first_divergence(a, b)


def test_mask_top_literals_hides_only_depth0_values():
    """An eager call site's host ints (top-level consts/literals) are
    masked; the same value baked INSIDE a jit boundary still diverges."""
    jitted = jax.jit(lambda p, i: p.at[i].set(0.0))

    def eager(i):
        return lambda p: jitted(p, jnp.int32(i))      # traced operand

    def baked(i):
        return lambda p: jax.jit(lambda q: q.at[i].set(0.0))(p)

    fp = [jaxprs.structural_fingerprint(
        jaxprs.trace(eager(i), jnp.zeros((4,))), mask_top_literals=True)
        for i in (1, 2)]
    assert fp[0] == fp[1]
    fp = [jaxprs.structural_fingerprint(
        jaxprs.trace(baked(i), jnp.zeros((4,))), mask_top_literals=True)
        for i in (1, 2)]
    assert fp[0] != fp[1]


def test_axis_env_trace_sees_collectives():
    jx = jaxprs.trace(lambda x: jax.lax.psum(x, "model"), jnp.ones((4,)),
                      axis_env=[("model", 2)])
    assert "psum" in jaxprs.primitive_names(jx)


# ---------------------------------------------------------------------------
# HLO walker
# ---------------------------------------------------------------------------
def test_parse_hlo_opcodes_and_result_shapes():
    text = "\n".join([
        "HloModule m",
        "ENTRY %main (p0: f32[8,8]) -> f32[8,64] {",
        "  %p0 = f32[8,8]{1,0} parameter(0)",
        "  ROOT %ag = f32[8,64]{1,0} all-gather(f32[8,8]{1,0} %p0), "
        "dimensions={1}",
        "}",
    ])
    ops = hlo.parse_hlo(text)
    ag = [op for op in ops if op.opcode == "all-gather"]
    assert len(ag) == 1 and (8, 64) in ag[0].result_shapes
    assert [op.opcode for op in hlo.collectives(ops)] == ["all-gather"]


def test_hlo_rule_tolerates_small_gathers_flags_w_gathers():
    rule = core.get("hlo-collective-budget")
    findings = core.selftest(rule)
    msgs = " ".join(f.message for f in findings)
    assert "all-gather" in msgs and "all-to-all" in msgs
    # the tiny adapter-state gather in the fixture did NOT flag
    assert "(8, 4)" not in msgs


def test_compile_text_single_device_has_no_collectives():
    txt = hlo.compile_text(lambda x: x * 2.0, jnp.ones((4,)))
    assert hlo.collectives(hlo.parse_hlo(txt)) == []


# ---------------------------------------------------------------------------
# trace layer measures real caches
# ---------------------------------------------------------------------------
def test_no_retrace_passes_stable_and_flags_unstable():
    from repro.analysis import rules_trace
    rule = core.get("no-retrace")
    stable = rules_trace.measure_jit(
        "stable", lambda x: x + 1.0, [(jnp.ones((4,)),)] * 3, budget=1)
    assert rule.check(stable) == []
    unstable = rules_trace.measure_jit(
        "unstable", lambda x: x + 1.0,
        [(jnp.ones((n,)),) for n in (3, 4, 5)], budget=1)
    assert len(rule.check(unstable)) == 1


# ---------------------------------------------------------------------------
# checks API (what the other test files call)
# ---------------------------------------------------------------------------
def test_assert_helpers_raise_with_findings():
    with pytest.raises(AssertionError, match="no-dense-w-in-hbm"):
        analysis.assert_no_dense_w(
            lambda c: c.astype(jnp.float32) * 2.0,
            (jnp.zeros((64, 48), jnp.int8),), {(64, 48)})
    with pytest.raises(AssertionError, match="no-host-sync"):
        analysis.assert_no_host_sync(
            lambda x: (jax.debug.print("{x}", x=x), x + 1)[1],
            (jnp.ones(3),))
    # clean programs pass
    analysis.assert_no_host_sync(lambda x: x + 1, (jnp.ones(3),))
    analysis.assert_traces_once(lambda x: x * 2, [(jnp.ones(3),)] * 2)


def test_collective_budget_defaults_from_method_registry():
    """The budget is the registry's shard_collectives -- the satellite
    generalizing the psum-only gate (a BOFT-style method widens its own
    budget by declaring it)."""
    from repro import methods
    assert methods.get("oftv2").shard_collectives == ("psum",)
    assert methods.AdapterMethod.shard_collectives == ()

    def reduces(x):
        return jax.lax.psum(x, "model")

    def gathers(x):
        return jax.lax.all_gather(x, "model")

    args = (jnp.ones((4,)),)
    trace_kw = dict(axis_env=[("model", 2)])
    prog_ok = core.Program("ok", [jaxprs.trace(reduces, *args, **trace_kw)],
                           meta={"allowed_collectives":
                                 methods.get("oftv2").shard_collectives,
                                 "model_shards": 2})
    assert core.get("collective-budget").check(prog_ok) == []
    prog_bad = core.Program(
        "bad", [jaxprs.trace(gathers, *args, **trace_kw)],
        meta={"allowed_collectives":
              methods.get("oftv2").shard_collectives, "model_shards": 2})
    assert core.get("collective-budget").check(prog_bad)


def test_budget_resolves_adapter_kind_through_registry():
    """The rules resolve `adapter_kind` metadata themselves (the
    production fixtures no longer pre-resolve the budget), so the jaxpr
    and HLO layers cannot disagree about a method's budget."""
    from repro.analysis.rules_jaxpr import resolve_budget
    assert resolve_budget({"allowed_collectives": ("psum",)}) == (
        frozenset({"psum"}), None)
    assert resolve_budget({}) == (None, None)
    assert resolve_budget({"adapter_kind": "oftv2"}) == (
        frozenset({"psum"}), None)
    allowed, reason = resolve_budget({"adapter_kind": "boft"})
    assert allowed == frozenset({"psum", "all_gather"}) and reason is None


def test_budget_unresolvable_kind_is_clean_finding_not_crash():
    """ISSUE-10 satellite: an unregistered kind (or one without the
    `shards` capability, like kind="none") used to escape as the
    registry's ValueError and kill the whole analyzer run; now each
    budget rule reports it as an ordinary severity-error Finding."""
    trace_kw = dict(axis_env=[("model", 2)])
    jx = jaxprs.trace(lambda x: jax.lax.psum(x, "model"), jnp.ones((4,)),
                      **trace_kw)
    rule = core.get("collective-budget")
    for kind, frag in (("principal-subspace", "cannot resolve"),
                       ("none", "no `shards` capability"),
                       ("goft", "no `shards` capability")):
        findings = rule.check(core.Program(
            f"p/{kind}", [jx], meta={"adapter_kind": kind,
                                     "model_shards": 2}))
        assert len(findings) == 1 and findings[0].severity == core.ERROR
        assert frag in findings[0].message, findings[0]
    hlo_rule = core.get("hlo-collective-budget")
    findings = hlo_rule.check(core.Program(
        "p/hlo", [], hlo="HloModule m\n",
        meta={"adapter_kind": "principal-subspace"}))
    assert len(findings) == 1 and "cannot resolve" in findings[0].message


def test_checks_api_surfaces_bad_kind_as_assertion():
    """The one-line test wrappers go through the same resolution: a bad
    `kind` raises AssertionError WITH the finding, never ValueError."""
    from repro.config.base import ModelConfig
    cfg = ModelConfig(name="t", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=1, d_ff=32, vocab_size=32)
    with pytest.raises(AssertionError, match="cannot resolve"):
        analysis.assert_collective_budget(lambda x: x * 2.0,
                                          (jnp.ones((4,)),), 1,
                                          kind="principal-subspace")
    with pytest.raises(AssertionError, match="no `shards` capability"):
        analysis.assert_no_w_gathers_hlo(lambda x: x * 2.0,
                                         (jnp.ones((4,)),), cfg,
                                         kind="none")
    # explicit allowed= still bypasses resolution entirely
    analysis.assert_collective_budget(lambda x: x * 2.0, (jnp.ones((4,)),),
                                      1, kind="principal-subspace",
                                      allowed=())


# ---------------------------------------------------------------------------
# wrappers keep their historical CLIs / exit codes
# ---------------------------------------------------------------------------
def test_check_dispatch_wrapper_clean_tree():
    sys.path.insert(0, str(ROOT))
    from benchmarks.check_dispatch import check
    assert check() == 0


def test_check_fusion_wrapper_exit_codes():
    sys.path.insert(0, str(ROOT))
    from benchmarks.check_fusion import check
    good = [{"name": "fusion_plan/layer/q/expect_qoft_fused",
             "derived": "got=qoft_fused"},
            {"name": "serving/speedup/n4/expect_ge_2.0",
             "derived": "multi_over_seq=3.10"}]
    assert check(good) == 0
    bad = [{"name": "fusion_plan/layer/q/expect_qoft_fused",
            "derived": "got=unfused"}]
    assert check(bad) == 1
    assert check([{"name": "other", "derived": ""}]) == 1   # plan missing


def test_check_metrics_wrapper_roundtrip(tmp_path):
    sys.path.insert(0, str(ROOT))
    from benchmarks.check_metrics import check, load_samples
    from repro.obs import schema
    snap = {"metrics": [{"name": n, "samples": [1.0]}
                        for n in schema.SPECS]}
    d = tmp_path / "m"
    d.mkdir()
    (d / "metrics.jsonl").write_text(json.dumps(snap) + "\n")
    assert load_samples(str(d)) == {n: 1 for n in schema.SPECS}
    assert check([str(d)]) == 0
    # drop one smoke_required family's samples -> gate fails
    smoke = next(n for n, s in schema.SPECS.items() if s.smoke_required)
    snap["metrics"] = [{"name": n, "samples": [] if n == smoke else [1.0]}
                       for n in schema.SPECS]
    (d / "metrics.jsonl").write_text(json.dumps(snap) + "\n")
    assert check([str(d)]) == 1


# ---------------------------------------------------------------------------
# docs + CLI
# ---------------------------------------------------------------------------
def test_rules_table_is_embedded_in_readme():
    """The README rule table is GENERATED (rules_table_md); this keeps
    the embed from rotting, like the capability-matrix embed."""
    assert core.rules_table_md() in (ROOT / "README.md").read_text(), (
        "README rule table is stale -- regenerate with `PYTHONPATH=src "
        "python -m repro.analysis --list-rules` and paste")


def test_cli_list_rules_and_ast_only():
    env_path = f"{ROOT / 'src'}"
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        capture_output=True, text=True, cwd=ROOT,
        env={**__import__('os').environ, "PYTHONPATH": env_path})
    assert out.returncode == 0
    assert out.stdout.strip() == core.rules_table_md()
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--ast-only", "--rules",
         "registry-dispatch,no-wallclock-in-kernels"],
        capture_output=True, text=True, cwd=ROOT,
        env={**__import__('os').environ, "PYTHONPATH": env_path})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "checked ast=" in out.stdout


def test_report_merge_json_and_severity_gate(tmp_path):
    r1 = core.Report([core.Finding("a", core.ERROR, "w", "m")], {"ast": 3},
                     ["note"])
    r2 = core.Report([core.Finding("b", core.WARNING, "w2", "m2")],
                     {"ast": 1, "jaxpr": 2}, [])
    r1.merge(r2)
    assert r1.checked == {"ast": 4, "jaxpr": 2}
    assert len(r1.errors) == 1
    path = tmp_path / "f.json"
    r1.write_json(str(path))
    data = json.loads(path.read_text())
    assert data["errors"] == 1 and len(data["findings"]) == 2
    assert "note" in data["skipped"]
