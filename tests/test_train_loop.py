"""End-to-end training-loop tests: loss decreases, exact resume after
preemption, adapter-vs-LoRA parity on the synthetic task, OFTv1 == OFTv2
training trajectories."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import (AdapterConfig, ModelConfig, ParallelConfig,
                               QuantConfig, RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.distributed.fault import PreemptionGuard
from repro.models import build
from repro.train.loop import run_training
from repro.train.step import make_train_step
from repro.train import state as state_lib


def small_run(tmp, adapter="oftv2", quant="none", steps=30, micro=1,
              comp="none"):
    cfg = ModelConfig(name="loop", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    return RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=adapter, block_size=16, neumann_terms=4,
                              rank=8, alpha=16.0),
        quant=QuantConfig(kind=quant, block_size=32),
        parallel=ParallelConfig(microbatches=micro,
                                gradient_compression=comp),
        train=TrainConfig(global_batch=8, seq_len=32, steps=steps,
                          learning_rate=4e-3, warmup_steps=5,
                          ckpt_every=10, ckpt_keep=2, log_every=0,
                          ckpt_dir=str(tmp)))


def loader_for(run):
    return ShardedLoader(SyntheticSpec(vocab_size=run.model.vocab_size,
                                       seq_len=run.train.seq_len,
                                       noise=0.05),
                         global_batch=run.train.global_batch, seed=0)


def test_loss_decreases_oftv2(tmp_path):
    run = small_run(tmp_path / "a", steps=40)
    model = build(run)
    out = run_training(model, run, loader_for(run), log=lambda s: None)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_resume_is_exact(tmp_path):
    run = small_run(tmp_path / "b", steps=20)
    model = build(run)
    # full run
    out_full = run_training(model, run, loader_for(run), log=lambda s: None)
    # interrupted right after the step-10 checkpoint, resumed fresh
    run2 = small_run(tmp_path / "c", steps=20)
    model2 = build(run2)
    mgr = CheckpointManager(run2.train.ckpt_dir, keep=2, async_save=False)
    run_training(model2, run2, loader_for(run2), manager=mgr,
                 log=lambda s: None, stop_after=10)
    out_resumed = run_training(model2, run2, loader_for(run2), manager=mgr,
                               log=lambda s: None)
    np.testing.assert_allclose(out_resumed["losses"],
                               out_full["losses"][10:], rtol=1e-5, atol=1e-6)


def test_preemption_flushes_checkpoint(tmp_path):
    run = small_run(tmp_path / "d", steps=100)
    model = build(run)
    guard = PreemptionGuard(install=False)
    mgr = CheckpointManager(run.train.ckpt_dir, keep=2, async_save=False)
    guard.trigger()
    out = run_training(model, run, loader_for(run), manager=mgr, guard=guard,
                       log=lambda s: None)
    assert out["preempted"] and mgr.latest_step() == 1


@pytest.mark.slow
def test_microbatched_step_matches_single(tmp_path):
    run1 = small_run(tmp_path / "e", steps=1, micro=1)
    run4 = small_run(tmp_path / "f", steps=1, micro=4)
    model = build(run1)
    params = model.init(jax.random.PRNGKey(0))
    st1 = state_lib.create(params)
    st4 = state_lib.create(params)
    batch = loader_for(run1).next_batch()
    batch = jax.tree_util.tree_map(jnp.asarray, batch)
    s1, m1 = make_train_step(model, run1)(st1, batch)
    s4, m4 = make_train_step(build(run4), run4)(st4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    a1 = jax.tree_util.tree_leaves(s1.adapter)
    a4 = jax.tree_util.tree_leaves(s4.adapter)
    for x, y in zip(a1, a4):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-6)


def test_compressed_training_still_converges(tmp_path):
    run = small_run(tmp_path / "g", steps=40, comp="int8")
    model = build(run)
    out = run_training(model, run, loader_for(run), log=lambda s: None)
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) - 0.1


def test_qoft_training_decreases_loss(tmp_path):
    run = small_run(tmp_path / "h", adapter="oftv2", quant="nf4", steps=40)
    model = build(run)
    out = run_training(model, run, loader_for(run), log=lambda s: None)
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) - 0.1


@pytest.mark.slow
def test_oftv2_matches_lora_quality_band(tmp_path):
    """Paper's Table 3/4 proxy: at matched budget OFTv2 lands in the same
    loss band as LoRA on the synthetic task."""
    run_o = small_run(tmp_path / "i", adapter="oftv2", steps=60)
    run_l = small_run(tmp_path / "j", adapter="lora", steps=60)
    out_o = run_training(build(run_o), run_o, loader_for(run_o),
                         log=lambda s: None)
    out_l = run_training(build(run_l), run_l, loader_for(run_l),
                         log=lambda s: None)
    lo = np.mean(out_o["losses"][-10:])
    ll = np.mean(out_l["losses"][-10:])
    assert abs(lo - ll) < 0.5, (lo, ll)
