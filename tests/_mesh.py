"""Shared multi-device test harness: run a code snippet in a subprocess
with N fake CPU devices, so the forced device count never leaks into the
rest of the suite (jax locks the device count at first init).

``run_py`` sets ``--xla_force_host_platform_device_count=N`` by PROPER
token filtering of any pre-existing XLA_FLAGS: every
``--xla_force_host_platform_device_count=...`` token is removed (whatever
its value) and the rest of the flags pass through verbatim.  The old
string-replace of the literal ``=512`` corrupted any other preset value
(``=5120`` became ``0``) and left stale forced counts in place.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_device_count_flags(existing: str, devices: int) -> str:
    """XLA_FLAGS value forcing ``devices`` host devices, preserving every
    unrelated token of ``existing``."""
    kept = [t for t in existing.split()
            if not t.startswith(_FORCE_FLAG + "=") and t != _FORCE_FLAG]
    return " ".join([f"{_FORCE_FLAG}={devices}"] + kept)


def run_py(code: str, devices: int = 8, timeout: int = 900,
           extra_env: dict = None) -> str:
    """Run ``code`` (dedented) in a fresh interpreter with ``devices`` fake
    CPU devices and the repo's src/ on PYTHONPATH; assert exit 0 and return
    stdout.  ``extra_env`` overlays the environment (e.g. a checkpoint dir
    handed to a chaos/elastic-resume snippet)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = force_device_count_flags(env.get("XLA_FLAGS", ""),
                                                devices)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    if extra_env:
        env.update({k: str(v) for k, v in extra_env.items()})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
