"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
fault-tolerance machinery, gradient compression."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import TrainConfig
from repro.data.loader import ShardedLoader
from repro.data.packing import pack_documents
from repro.data.synthetic import SyntheticCorpus, SyntheticSpec
from repro.distributed.fault import Heartbeat, StragglerMonitor
from repro.optim import adamw, clipping, compression, schedule

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- adamw ----
def test_adamw_quadratic_convergence():
    tc = TrainConfig(learning_rate=0.1, steps=200, warmup_steps=0,
                     schedule="constant", weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = adamw.update(g, state, params, jnp.asarray(0.1), tc)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_matches_reference_step():
    """One-step closed form: zero state, grad g -> delta = lr * sign-ish."""
    tc = TrainConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([1.0])}
    st = adamw.init(p)
    g = {"w": jnp.array([0.5])}
    newp, _ = adamw.update(g, st, p, jnp.asarray(0.01), tc)
    # mhat = g, vhat = g^2 -> delta = g/(|g|+eps) ~= 1
    np.testing.assert_allclose(float(newp["w"][0]), 1.0 - 0.01, atol=1e-5)


def test_weight_decay_applied():
    tc = TrainConfig(weight_decay=0.1)
    p = {"w": jnp.array([2.0])}
    st = adamw.init(p)
    g = {"w": jnp.array([0.0])}
    newp, _ = adamw.update(g, st, p, jnp.asarray(0.5), tc)
    np.testing.assert_allclose(float(newp["w"][0]), 2.0 - 0.5 * 0.1 * 2.0,
                               atol=1e-6)


def test_schedule_shapes():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, steps=110,
                     schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(schedule.learning_rate(jnp.asarray(s), tc))
           for s in range(110)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1e-3, rtol=1e-5)
    assert lrs[-1] < 2e-4 and lrs[-1] >= 0.99e-4
    assert all(b <= a + 1e-12 for a, b in zip(lrs[10:], lrs[11:]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clipping.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 6.0)
    np.testing.assert_allclose(float(clipping.global_norm(clipped)), 1.0,
                               rtol=1e-5)


# ---------------------------------------------------------- compression ----
def test_compression_error_feedback_unbiased():
    """With error feedback, the *accumulated* compressed gradient tracks the
    accumulated true gradient (bounded drift)."""
    g = {"w": 0.01 * jax.random.normal(KEY, (256,))}
    err = compression.init_error_state(g)
    total_true = jnp.zeros((256,))
    total_comp = jnp.zeros((256,))
    for i in range(50):
        gi = {"w": 0.01 * jax.random.normal(jax.random.fold_in(KEY, i),
                                            (256,))}
        comp, err = compression.compress_decompress(gi, err)
        total_true += gi["w"]
        total_comp += comp["w"]
    drift = float(jnp.max(jnp.abs(total_true - total_comp)))
    onestep = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert drift < 3 * onestep   # error feedback: drift stays ~1 quant step


def test_compression_ratio_is_4x():
    g = jnp.ones((1024,), jnp.float32)
    q, s = compression.quantize_leaf(g)
    assert q.dtype == jnp.int8 and q.nbytes == g.nbytes // 4


# ------------------------------------------------------------------ data ---
def test_loader_determinism_and_resume():
    spec = SyntheticSpec(vocab_size=64, seq_len=16)
    l1 = ShardedLoader(spec, global_batch=4, seed=7)
    batches = [l1.next_batch() for _ in range(3)]
    # resume from cursor after batch 1
    l2 = ShardedLoader(spec, global_batch=4, seed=7)
    l2.restore({"cursor": 4})
    np.testing.assert_array_equal(l2.next_batch()["tokens"],
                                  batches[1]["tokens"])


def test_loader_multihost_slicing():
    spec = SyntheticSpec(vocab_size=64, seq_len=8)
    full = ShardedLoader(spec, global_batch=8, seed=3).next_batch()["tokens"]
    parts = []
    for pi in range(4):
        l = ShardedLoader(spec, global_batch=8, seed=3, process_index=pi,
                          process_count=4)
        parts.append(l.next_batch()["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_corpus_is_learnable():
    """Markov stream: bigram statistics are far from uniform."""
    spec = SyntheticSpec(vocab_size=32, seq_len=512, noise=0.05)
    c = SyntheticCorpus(spec, seed=0)
    toks = c.sample(0)["tokens"]
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    # successors per token should be concentrated (<= branching + noise)
    sizes = [len(set(v)) for v in pairs.values() if len(v) >= 8]
    assert sizes and np.median(sizes) <= spec.branching + 2


def test_packing():
    docs = [np.arange(5), np.arange(3), np.arange(7), np.arange(2)]
    out = pack_documents(docs, seq_len=8, pad_id=0)
    assert out["tokens"].shape[1] == 8
    assert out["segment_ids"].max() >= 2          # something got packed
    assert out["loss_mask"].sum() == sum(len(d) - 1 for d in docs)


# ----------------------------------------------------------- checkpoint ----
def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    for s in [10, 20, 30]:
        mgr.save(s, tree, metadata={"data_cursor": s * 100})
    assert mgr.steps() == [20, 30]
    restored, meta = mgr.restore(like=tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert meta["data_cursor"] == 3000


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, async_save=True)
    mgr.save(1, {"x": jnp.ones((8,))}, metadata={"data_cursor": 0})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    mgr.save(1, {"x": jnp.ones((8,))}, metadata={})
    with pytest.raises(ValueError):
        mgr.restore(like={"y": jnp.ones((8,))})


# ----------------------------------------------------------------- fault ---
def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=2)
    flags = [mon.record(i, 0.1) for i in range(10)]
    assert not any(flags)
    assert mon.record(10, 1.0) is True
    assert mon.record(11, 0.1) is False   # baseline not poisoned


def test_heartbeat_staleness(tmp_path):
    hb = Heartbeat(str(tmp_path), "host0")
    hb.beat()
    assert Heartbeat.stale_hosts(str(tmp_path), timeout=100.0) == []
    assert Heartbeat.stale_hosts(str(tmp_path), timeout=-1.0) == ["host0"]


def test_heartbeat_read_during_write_never_misreads(tmp_path):
    """A beat() racing stale_hosts() must never surface as a dead host:
    the write goes to a temp file and lands via atomic os.replace, so a
    reader sees either the old timestamp or the new one -- never a
    truncated/empty file (which parses as epoch 0 = very stale)."""
    import threading

    hb = Heartbeat(str(tmp_path), "host0")
    hb.beat()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            hb.beat()

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(300):
            assert Heartbeat.stale_hosts(str(tmp_path), timeout=60.0) == []
    finally:
        stop.set()
        t.join()


def test_preemption_guard_installs_and_restores_handlers():
    import signal

    from repro.distributed.fault import PreemptionGuard

    before = {s: signal.getsignal(s) for s in PreemptionGuard.SIGNALS}
    guard = PreemptionGuard(install=True)
    try:
        assert guard.installed
        for s in PreemptionGuard.SIGNALS:
            assert signal.getsignal(s) == guard._handler
        signal.raise_signal(signal.SIGTERM)
        assert guard.requested
    finally:
        guard.uninstall()
    assert not guard.installed
    for s in PreemptionGuard.SIGNALS:
        assert signal.getsignal(s) == before[s]
    # context-manager spelling does the same round trip
    with PreemptionGuard() as g:
        assert g.installed
        assert signal.getsignal(signal.SIGINT) == g._handler
    for s in PreemptionGuard.SIGNALS:
        assert signal.getsignal(s) == before[s]
