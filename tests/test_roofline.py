"""Roofline machinery tests: HLO shape/byte parsing, collective wire-traffic
model, term computation, analytic corrections."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ModelConfig
from repro.roofline import analysis as ra
from repro.roofline.hw import V5E

HLO_SAMPLE = """
HloModule jit_step
%fused (x: bf16[128,4096]) -> bf16[128,4096] { ... }
%ag = bf16[16,4096,128]{2,1,0} all-gather(%p0), replica_groups=[32,16]<=[512], dimensions={0}
%ar.1 = f32[1024]{0} all-reduce(%p1), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
%rs = bf16[2048]{0} reduce-scatter(%p2), replica_groups=[4,8]<=[32], dimensions={0}
%a2a = bf16[64,64]{1,0} all-to-all(%p3), replica_groups=[2,16]<=[32]
%cp = bf16[8,8]{1,0} collective-permute(%p4), source_target_pairs={{0,1}}
%agd = bf16[4,4]{1,0} all-gather-done(%x)
"""


def test_shape_bytes():
    assert ra.shape_bytes("bf16[128,4096]") == 128 * 4096 * 2
    assert ra.shape_bytes("f32[10]") == 40
    assert ra.shape_bytes("(bf16[2,2], f32[4])") == 8 + 16
    assert ra.shape_bytes("pred[]") == 0 or ra.shape_bytes("pred[1]") == 1


def test_parse_collectives_kinds_and_groups():
    total, per_kind = ra.parse_collectives(HLO_SAMPLE, total_devices=512)
    assert set(per_kind) == {"all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"}
    # all-gather: result 16*4096*128*2 bytes, group 16 -> B*(15/16)
    b_ag = 16 * 4096 * 128 * 2
    np.testing.assert_allclose(per_kind["all-gather"]["wire_bytes"],
                               b_ag * 15 / 16)
    # all-reduce: explicit groups of 4 -> 2*B*(3/4)
    np.testing.assert_allclose(per_kind["all-reduce"]["wire_bytes"],
                               2 * 4096 * 3 / 4)
    # reduce-scatter: result B, group 8 -> B*(8-1)
    np.testing.assert_allclose(per_kind["reduce-scatter"]["wire_bytes"],
                               2048 * 2 * 7)
    # -done ops are not double counted
    assert per_kind["all-gather"]["count"] == 1


def test_wire_model_group1_is_free():
    assert ra._wire_bytes("all-reduce", 100, 1) == 0.0


def test_roofline_terms_bottleneck():
    t = ra.roofline_terms(197e12, 819e7, 50e7)   # 1s compute, 0.01s others
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-6
    t2 = ra.roofline_terms(1.0, 819e9, 1.0)
    assert t2["bottleneck"] == "memory"


def test_model_flops_moe_active_only():
    dense = ModelConfig(name="d", num_layers=4, d_model=256, num_heads=4,
                        num_kv_heads=4, d_ff=1024, vocab_size=1000)
    moe = ModelConfig(name="m", family="moe", num_layers=4, d_model=256,
                      num_heads=4, num_kv_heads=4, d_ff=1024,
                      vocab_size=1000, num_experts=8, top_k=2)
    f_dense = ra.model_flops(dense, 1000, "train")
    f_moe_active = ra.model_flops(moe, 1000, "train")
    moe_all = moe.param_count(active_only=False)
    moe_act = moe.param_count(active_only=True)
    assert moe_all > moe_act                 # 8 experts vs 2 active
    assert f_moe_active < 6 * moe_all * 1000
    assert ra.model_flops(dense, 1000, "decode") == pytest.approx(
        f_dense / 3)


def test_attention_correction_scaling():
    cfg = ModelConfig(name="a", num_layers=2, d_model=512, num_heads=8,
                      num_kv_heads=2, d_ff=1024, vocab_size=1000,
                      attn_chunk=128)
    c1 = ra.attention_correction(cfg, 1024, 32, "prefill", 4, 2)
    c2 = ra.attention_correction(cfg, 2048, 32, "prefill", 4, 2)
    # causal attention: flops ~ S^2
    assert c2["flops"] == pytest.approx(4 * c1["flops"], rel=1e-6)
    # train multiplies by remat factor 4
    ct = ra.attention_correction(cfg, 1024, 32, "train", 4, 2)
    assert ct["flops"] == pytest.approx(4 * c1["flops"], rel=1e-6)
    # SWA caps the pair count
    import dataclasses
    cfg_w = dataclasses.replace(cfg, sliding_window=128)
    cw = ra.attention_correction(cfg_w, 2048, 32, "prefill", 4, 2)
    assert cw["flops"] < c2["flops"] / 3
    # ssm has no attention
    cfg_s = ModelConfig(name="s", family="ssm", num_layers=2, d_model=64,
                        num_heads=0, num_kv_heads=0, d_ff=0,
                        vocab_size=100, ssm_state=16)
    assert ra.attention_correction(cfg_s, 1024, 8, "train", 2, 2)["flops"] \
        == 0.0
