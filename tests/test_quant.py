"""NF4 / AWQ / int8 quantization tests + QOFT forward integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config.base import AdapterConfig, QuantConfig
from repro.core import adapter as ad
from repro.core import skew
from repro.quant import awq, int8, nf4
from repro.quant.common import dequantize_linear, quantize_linear, storage_bytes


def _w(key, d_in=128, d_out=64, scale=0.05):
    return scale * jax.random.normal(key, (d_in, d_out))


# ------------------------------------------------------------------ NF4 ----
@pytest.mark.parametrize("double", [True, False])
def test_nf4_roundtrip_error_bounded(double):
    qcfg = QuantConfig(kind="nf4", block_size=64, double_quant=double)
    w = _w(jax.random.PRNGKey(0))
    q = nf4.quantize(w, qcfg)
    back = nf4.dequantize(q, qcfg, jnp.float32)
    assert back.shape == w.shape
    # NF4 max relative error within a block is bounded by half the largest
    # code gap (0.304/2 = 0.152) x absmax (+ small double-quant noise)
    blocks = np.asarray(w).reshape(-1, 64, w.shape[1])
    absmax = np.abs(blocks).max(axis=1)
    err = np.abs(np.asarray(back - w)).reshape(-1, 64, w.shape[1])
    tol = 0.153 * absmax[:, None, :] + (0.02 * absmax[:, None, :] if double else 0) + 1e-6
    assert np.all(err <= tol)


def test_nf4_codebook_values_exact():
    """Weights exactly on the NF4 grid quantize losslessly."""
    qcfg = QuantConfig(kind="nf4", block_size=16, double_quant=False)
    vals = jnp.asarray(nf4.NF4_TABLE)
    w = jnp.tile(vals[:, None], (4, 8)) * 0.3   # absmax=0.3 per block
    q = nf4.quantize(w, qcfg)
    back = nf4.dequantize(q, qcfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1e-6)


def test_nf4_zero_block_safe():
    qcfg = QuantConfig(kind="nf4", block_size=32, double_quant=False)
    w = jnp.zeros((64, 8))
    back = nf4.dequantize(nf4.quantize(w, qcfg), qcfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), 0.0, atol=0)


def test_nf4_compression_ratio():
    qcfg = QuantConfig(kind="nf4", block_size=64, double_quant=True)
    w = _w(jax.random.PRNGKey(1), 1024, 1024)
    q = quantize_linear(w, qcfg)
    ratio = w.size * 4 / storage_bytes(q)
    assert ratio > 7.0  # ~8x vs fp32 (0.5 byte/param + scales)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 10.0))
def test_property_nf4_scale_equivariance(seed, scale):
    """NF4 is absmax-normalized per block => quantization commutes with
    positive per-tensor scaling."""
    qcfg = QuantConfig(kind="nf4", block_size=32, double_quant=False)
    w = _w(jax.random.PRNGKey(seed), 64, 16, 1.0)
    b1 = nf4.dequantize(nf4.quantize(w, qcfg), qcfg, jnp.float32)
    b2 = nf4.dequantize(nf4.quantize(w * scale, qcfg), qcfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(b2), np.asarray(b1) * scale,
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------------ AWQ ----
def test_awq_roundtrip():
    qcfg = QuantConfig(kind="awq", group_size=32)
    w = _w(jax.random.PRNGKey(2), 128, 32)
    q = awq.quantize(w, qcfg)
    back = awq.dequantize(q, qcfg, jnp.float32)
    # int4 asymmetric: error <= scale/2 per element
    scales = np.asarray(q["awq_scale"])
    err = np.abs(np.asarray(back - w)).reshape(-1, 32, 32)
    assert np.all(err <= 0.51 * scales[:, None, :] + 1e-6)


def test_awq_activation_aware_reduces_salient_error():
    """Salient channels (big act scale) should see smaller weight error."""
    qcfg = QuantConfig(kind="awq", group_size=64)
    key = jax.random.PRNGKey(3)
    w = _w(key, 128, 64, scale=0.1)
    s = jnp.ones((128,)).at[:8].set(4.0)   # first 8 channels salient
    q_plain = awq.quantize(w, qcfg)
    q_aware = awq.quantize(w, qcfg, act_scales=s)
    e_plain = np.abs(np.asarray(awq.dequantize(q_plain, qcfg, jnp.float32) - w))
    e_aware = np.abs(np.asarray(awq.dequantize(q_aware, qcfg, jnp.float32) - w))
    assert e_aware[:8].mean() < e_plain[:8].mean() * 1.05


# ----------------------------------------------------------------- int8 ----
def test_int8_roundtrip():
    qcfg = QuantConfig(kind="int8")
    w = _w(jax.random.PRNGKey(4), 64, 32)
    back = int8.dequantize(int8.quantize(w, qcfg), qcfg, jnp.float32)
    scales = np.abs(np.asarray(w)).max(axis=0) / 127.0
    assert np.all(np.abs(np.asarray(back - w)) <= 0.51 * scales[None, :] + 1e-8)


# ------------------------------------------------------- QOFT / QLoRA ------
@pytest.mark.parametrize("qkind", ["nf4", "awq", "int8"])
@pytest.mark.parametrize("akind", ["oftv2", "lora"])
def test_quantized_adapted_linear(qkind, akind):
    """QOFT (and QLoRA) forward: adapter on top of any quant scheme --
    the paper's quantization-agnostic claim, exercised for 3 formats."""
    acfg = AdapterConfig(kind=akind, block_size=16, neumann_terms=4, rank=4)
    qcfg = QuantConfig(kind=qkind, block_size=32, group_size=32)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (4, 128))
    w = _w(key, 128, 64)
    qstate = quantize_linear(w, qcfg)
    adp = ad.adapter_init(key, "q", 128, 64, acfg)
    y = ad.adapted_linear(x, qstate, adp, acfg, qcfg)
    # fresh adapter == identity => equals plain quantized linear
    y_ref = x @ dequantize_linear(qstate, qcfg, x.dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-5)


def test_qoft_grads_only_touch_adapter():
    acfg = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4)
    qcfg = QuantConfig(kind="nf4", block_size=32, double_quant=False)
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (4, 64))
    qstate = quantize_linear(_w(key, 64, 32), qcfg)
    adp = {"q_packed": skew.random_skew(key, (4,), 16, scale=0.05)}

    def loss(a):
        return jnp.sum(jnp.square(ad.adapted_linear(x, qstate, a, acfg, qcfg)))

    g = jax.grad(loss)(adp)
    assert g["q_packed"].shape == adp["q_packed"].shape
    assert float(jnp.max(jnp.abs(g["q_packed"]))) > 0
