"""Fused backward subsystem: the oftv2/qoft_linear_bwd Pallas kernels vs
the jnp oracles, the no-dense-W guarantee of the quantized backward, and
the once-per-train-step rotation hoisting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import (AdapterConfig, ModelConfig, ParallelConfig,
                               QuantConfig, RunConfig, TrainConfig)
from repro.core import skew
from repro.core.cayley import build_rotation
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant import nf4

pytestmark = pytest.mark.kernels


# ---------------------------------------------------- kernel vs oracle ----
BWD_SHAPES = [
    # (lead, d_in, d_out, b): odd token counts exercise the zero-padding,
    # d_out=33 / d_in=96 force the n/k full-dim tile fallbacks
    ((37,), 64, 48, 16), ((3, 7), 128, 96, 32), ((260,), 96, 33, 8),
    ((1,), 64, 64, 64), ((512,), 256, 128, 32),
]


def _inputs(lead, d, n, b, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, lead + (d,), jnp.float32)
    w = (jax.random.normal(key, (d, n), jnp.float32) / np.sqrt(d))
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5)
    g = jax.random.normal(jax.random.fold_in(key, 1), lead + (n,),
                          jnp.float32)
    return x, r, w, g


@pytest.mark.parametrize("lead,d,n,b", BWD_SHAPES)
def test_oftv2_bwd_kernel_matches_ref(lead, d, n, b):
    x, r, w, g = _inputs(lead, d, n, b)
    dx, dr = kops._oftv2_bwd_raw(g, x, r, w)
    dx_r, dr_r = kref.oftv2_linear_bwd_ref(g, x, r, w)
    assert dx.shape == x.shape and dr.shape == r.shape
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(dr_r), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("lead,d,n,b", BWD_SHAPES)
def test_oftv2_fused_grads_match_oracle(lead, d, n, b):
    x, r, w, _ = _inputs(lead, d, n, b)

    def f_k(x, r, w):
        return jnp.sum(jnp.sin(kops.oftv2_linear_fused(x, r, w)))

    def f_r(x, r, w):
        return jnp.sum(jnp.sin(kref.oftv2_linear_ref(x, r, w)))

    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, r, w)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, r, w)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("lead,d,n,b,bs", [
    ((29,), 128, 64, 16, 64), ((3, 11), 256, 96, 32, 32),
    ((41,), 64, 33, 16, 16), ((7,), 512, 128, 32, 64),
])
def test_qoft_bwd_kernel_matches_ref(lead, d, n, b, bs):
    x, r, w, g = _inputs(lead, d, n, b, seed=1)
    q = nf4.quantize(0.1 * w, QuantConfig(kind="nf4", block_size=bs,
                                          double_quant=False))
    dx, dr = kops._qoft_bwd_raw(g, x, r, q["nf4_codes"], q["absmax"], bs)
    dx_r, dr_r = kref.qoft_linear_bwd_ref(g, x, r, q["nf4_codes"],
                                          q["absmax"], bs)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(dr_r), rtol=2e-5,
                               atol=2e-5)

    def f_k(x, r):
        return jnp.sum(jnp.sin(kops.qoft_linear_fused(
            x, r, q["nf4_codes"], q["absmax"], bs)))

    def f_r(x, r):
        return jnp.sum(jnp.sin(kref.qoft_linear_ref(
            x, r, q["nf4_codes"], q["absmax"], bs)))

    gk = jax.grad(f_k, argnums=(0, 1))(x, r)
    gr = jax.grad(f_r, argnums=(0, 1))(x, r)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-4)


def test_frozen_base_dw_is_structurally_zero():
    """train_w=False (the adapted-linear path): dW is exactly zero and the
    backward jaxpr contains no (T, K) x (T, N) contraction feeding it."""
    x, r, w, _ = _inputs((21,), 64, 40, 16)
    dw = jax.grad(lambda w_: jnp.sum(
        kops.oftv2_linear_fused(x, r, w_, False)))(w)
    assert float(jnp.max(jnp.abs(dw))) == 0.0
    # and the dx/dr grads are unaffected by the skip
    g_frozen = jax.grad(lambda x_, r_: jnp.sum(
        kops.oftv2_linear_fused(x_, r_, w, False)), argnums=(0, 1))(x, r)
    g_train = jax.grad(lambda x_, r_: jnp.sum(
        kops.oftv2_linear_fused(x_, r_, w, True)), argnums=(0, 1))(x, r)
    for a, b_ in zip(g_frozen, g_train):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-6)


def test_fused_bwd_neumann0_exact_cayley_fallback():
    """Fused fwd+bwd grads vs unfused, with the exact-Cayley (solve) R
    build: the kernel path composes with the neumann_terms=0 oracle
    fallback of cayley_neumann."""
    from repro.core import adapter as ad
    from repro.quant.common import quantize_linear
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 9, 128))
    w = 0.05 * jax.random.normal(key, (128, 96))
    adp = {"q_packed": skew.random_skew(key, (8,), 16, scale=0.1)}
    qcfg = QuantConfig(kind="nf4", block_size=32, double_quant=False)
    qstate = quantize_linear(w, qcfg)
    acfg_u = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=0)
    acfg_f = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=0,
                           fuse_linear=True)

    def loss(p, acfg):
        return jnp.sum(jnp.square(ad.adapted_linear(x, qstate, p, acfg,
                                                    qcfg)))

    g_u = jax.grad(loss)(adp, acfg_u)["q_packed"]
    g_f = jax.grad(loss)(adp, acfg_f)["q_packed"]
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_u), rtol=1e-4,
                               atol=1e-4)


# ------------------------------------------------- no dense W in the bwd ----
def test_qoft_bwd_never_materializes_dense_weight():
    """Acceptance: the QOFT backward performs zero full-weight dequants to
    HBM -- no (d_in, d_out) float array exists anywhere in the fwd+bwd
    jaxpr outside kernel-internal VMEM tiles.  The walker is the shared
    ``repro.analysis`` jaxpr walker -- the same detector the CI
    ``no-dense-w-in-hbm`` rule runs (this file used to carry its own
    copy)."""
    from repro import analysis
    d, n, b, bs = 128, 96, 16, 32
    x, r, w, _ = _inputs((16,), d, n, b, seed=2)
    q = nf4.quantize(0.1 * w, QuantConfig(kind="nf4", block_size=bs,
                                          double_quant=False))

    def loss(x, r):
        return jnp.sum(kops.qoft_linear_fused(x, r, q["nf4_codes"],
                                              q["absmax"], bs))

    analysis.assert_no_dense_w(jax.grad(loss, argnums=(0, 1)), (x, r),
                               {(d, n)}, name="qoft_fused_grad")

    # detector sanity: an explicit full dequant IS caught
    dq_jaxpr = jax.make_jaxpr(
        lambda c, a: kops.nf4_dequant(c, a, bs))(q["nf4_codes"], q["absmax"])
    assert (d, n) in analysis.float_shapes(dq_jaxpr)


# ------------------------------------------ rotation hoisting / reuse ----
def _tiny_run(micro, quant="none", fuse=False, adapter="oftv2"):
    cfg = ModelConfig(name="bwd", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    return RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=adapter, block_size=16, neumann_terms=4,
                              fuse_linear=fuse),
        quant=QuantConfig(kind=quant, block_size=32),
        parallel=ParallelConfig(microbatches=micro),
        train=TrainConfig(global_batch=8, seq_len=32))


def _batch(run):
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import SyntheticSpec
    b = ShardedLoader(SyntheticSpec(vocab_size=run.model.vocab_size,
                                    seq_len=run.train.seq_len, noise=0.05),
                      global_batch=run.train.global_batch,
                      seed=0).next_batch()
    return jax.tree_util.tree_map(jnp.asarray, b)


@pytest.mark.parametrize("micro", [1, 4])
def test_build_r_traces_once_per_train_step(micro, monkeypatch):
    """Acceptance: regardless of microbatch count, the rotation build runs
    ONCE per train step -- hoisted out of the grad-accum scan."""
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step
    from repro.core import oft

    run = _tiny_run(micro)
    model = build(run)
    st = state_lib.create(model.init(jax.random.PRNGKey(0)))
    batch = _batch(run)

    calls = []
    orig = oft.build_r
    monkeypatch.setattr(oft, "build_r",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    jax.make_jaxpr(make_train_step(model, run))(st, batch)
    assert len(calls) == 1, f"build_r traced {len(calls)}x (micro={micro})"


@pytest.mark.parametrize("quant,fuse", [("none", False), ("none", True),
                                        ("nf4", True)])
def test_hoisted_step_matches_unhoisted(quant, fuse):
    """R-built-once-per-step is a pure reassociation: loss and updated
    adapter params match the per-linear-build path."""
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step

    run = _tiny_run(4, quant=quant, fuse=fuse)
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(run)
    s_h, m_h = make_train_step(model, run, hoist_rotations=True)(
        state_lib.create(params), batch)
    s_u, m_u = make_train_step(model, run, hoist_rotations=False)(
        state_lib.create(params), batch)
    np.testing.assert_allclose(float(m_h["loss"]), float(m_u["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s_h.adapter),
                    jax.tree_util.tree_leaves(s_u.adapter)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6)


def test_with_rotations_matches_per_leaf_build():
    """The concatenated single-call build == per-leaf build_r."""
    from repro.core import oft, rotations
    acfg = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4)
    key = jax.random.PRNGKey(7)
    tree = {
        "attn": {"q": {"q_packed": skew.random_skew(key, (2, 4), 16,
                                                    scale=0.1)},
                 "o": {"q_packed": skew.random_skew(key, (2, 8), 16,
                                                    scale=0.1)}},
        "mlp": {"up": {"q_packed": skew.random_skew(key, (3,), 16,
                                                    scale=0.1)}},
    }
    assert rotations.should_hoist(tree, acfg)
    aug = rotations.with_rotations(tree, acfg)
    for path, leaf in rotations._oft_leaves(aug):
        want = oft.build_r({"q_packed": leaf["q_packed"].reshape(
            -1, leaf["q_packed"].shape[-1])}, acfg)
        got = leaf["r_blocks"].reshape(want.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
    stripped = rotations.strip_rotations(aug)
    assert (jax.tree_util.tree_structure(stripped)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(stripped),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not rotations.should_hoist({}, acfg)
    assert not rotations.should_hoist(tree, AdapterConfig(kind="lora"))
