"""ISSUE-7 chaos harness: deterministic fault injection into the training
loop, restart supervision, and elastic mesh-reshape resume.

Every fault is a scheduled value (FaultSchedule), so recovery is asserted
the strongest way available: LOSS-TRAJECTORY PARITY -- the faulted run's
losses, stitched across preemptions/restarts, must equal the uninterrupted
run's, step for step."""
import dataclasses
import textwrap

import numpy as np
import pytest

from _mesh import run_py
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.chaos import (DeviceLost, FaultEvent, FaultSchedule,
                                     SaveCrashed, corrupt_checkpoint,
                                     run_with_restarts)
from repro.distributed.fault import PreemptionGuard
from repro.models import build
from repro.train.loop import run_training
from test_train_loop import loader_for, small_run


def with_ckpt_every(run, every):
    return dataclasses.replace(
        run, train=dataclasses.replace(run.train, ckpt_every=every))


def quiet(s):
    pass


# ----------------------------------------------------------- schedule unit
def test_from_seed_is_deterministic():
    rates = {"preempt": 0.2, "straggler": 0.3}
    a = FaultSchedule.from_seed(7, 50, rates)
    b = FaultSchedule.from_seed(7, 50, rates)
    assert a.events == b.events and len(a) > 0
    c = FaultSchedule.from_seed(8, 50, rates)
    assert a.events != c.events


def test_parse_spec():
    s = FaultSchedule.parse("preempt@3, straggler@5:0.1 ,corrupt_latest@7")
    assert s.events == [FaultEvent(3, "preempt"),
                        FaultEvent(5, "straggler", 0.1),
                        FaultEvent(7, "corrupt_latest")]
    with pytest.raises(ValueError):
        FaultSchedule.parse("preempt3")
    with pytest.raises(ValueError):
        FaultSchedule.parse("meteor@3")


def test_events_fire_exactly_once():
    s = FaultSchedule([FaultEvent(2, "preempt"),
                       FaultEvent(2, "straggler", 0.5)])
    g = PreemptionGuard(install=False)
    s.on_step(2, guard=g)
    assert g.requested
    assert s.straggler_delay(2) == 0.5
    g2 = PreemptionGuard(install=False)
    s.on_step(2, guard=g2)           # replayed step after a restart
    assert not g2.requested
    assert s.straggler_delay(2) == 0.0
    assert s.pending() == [] and len(s.fired()) == 2


def test_run_with_restarts_budget():
    calls = []

    def attempt():
        calls.append(1)
        raise DeviceLost("again")

    with pytest.raises(DeviceLost):
        run_with_restarts(attempt, max_restarts=2)
    assert len(calls) == 3           # initial try + 2 restarts


def test_chaos_cli_corrupts(tmp_path):
    from repro.distributed import chaos as chaos_mod
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, {"w": np.ones((4, 4), np.float32)}, metadata={"step": 1})
    chaos_mod.main(["corrupt", str(tmp_path)])
    assert not mgr.verify(1)


# ----------------------------------------------------- trajectory parity
def test_preempt_then_resume_matches_uninterrupted(tmp_path):
    run_f = small_run(tmp_path / "full", steps=16)
    full = run_training(build(run_f), run_f, loader_for(run_f),
                        log=quiet)["losses"]

    run_c = small_run(tmp_path / "chaos", steps=16)
    model = build(run_c)
    mgr = CheckpointManager(run_c.train.ckpt_dir, keep=3, async_save=False)
    chaos = FaultSchedule([FaultEvent(6, "preempt")])
    out1 = run_training(model, run_c, loader_for(run_c), manager=mgr,
                        guard=PreemptionGuard(install=False), chaos=chaos,
                        log=quiet)
    assert out1["preempted"] and out1["last_step"] == 7
    out2 = run_training(model, run_c, loader_for(run_c), manager=mgr,
                        guard=PreemptionGuard(install=False), log=quiet)
    stitched = out1["losses"] + out2["losses"]
    np.testing.assert_allclose(stitched, full, rtol=1e-5, atol=1e-6)


def test_straggler_delay_is_flagged(tmp_path):
    # late step + big delay: the EWMA seeds at the first (compile-heavy)
    # step's wall time and needs ~20 decays before 2x-threshold detection
    run = small_run(tmp_path / "s", steps=30)
    chaos = FaultSchedule([FaultEvent(26, "straggler", 1.0)])
    out = run_training(build(run), run, loader_for(run), chaos=chaos,
                       log=quiet)
    assert out["stragglers"] >= 1


def test_save_crash_restart_matches_uninterrupted(tmp_path):
    run_f = small_run(tmp_path / "full", steps=16)
    full = run_training(build(run_f), run_f, loader_for(run_f),
                        log=quiet)["losses"]

    run_c = with_ckpt_every(small_run(tmp_path / "chaos", steps=16), 5)
    model = build(run_c)
    chaos = FaultSchedule([FaultEvent(9, "save_crash", 1)])

    def attempt():
        mgr = CheckpointManager(run_c.train.ckpt_dir, keep=3,
                                async_save=False)
        return run_training(model, run_c, loader_for(run_c), manager=mgr,
                            guard=PreemptionGuard(install=False),
                            chaos=chaos, log=quiet)

    out, restarts = run_with_restarts(attempt, log=quiet)
    assert restarts == 1
    # the step-10 save died; the restart resumed from step 5's checkpoint
    np.testing.assert_allclose(out["losses"], full[5:], rtol=1e-5,
                               atol=1e-6)


def test_corrupt_latest_falls_back_and_matches(tmp_path):
    run_f = small_run(tmp_path / "full", steps=16)
    full = run_training(build(run_f), run_f, loader_for(run_f),
                        log=quiet)["losses"]

    run_c = with_ckpt_every(small_run(tmp_path / "chaos", steps=16), 4)
    model = build(run_c)
    mgr = CheckpointManager(run_c.train.ckpt_dir, keep=4, async_save=False)
    run_training(model, run_c, loader_for(run_c), manager=mgr,
                 guard=PreemptionGuard(install=False), log=quiet,
                 stop_after=10)
    assert mgr.latest_step() == 8
    corrupt_checkpoint(run_c.train.ckpt_dir)     # step_8 now fails checksums
    out = run_training(model, run_c, loader_for(run_c), manager=mgr,
                       guard=PreemptionGuard(install=False), log=quiet)
    # resumed from step 4 (the newest VALID step), not 8, and not step 0
    assert len(out["losses"]) == 12
    np.testing.assert_allclose(out["losses"], full[4:], rtol=1e-5,
                               atol=1e-6)


def test_device_loss_restart_matches_uninterrupted(tmp_path):
    run_f = small_run(tmp_path / "full", steps=16)
    full = run_training(build(run_f), run_f, loader_for(run_f),
                        log=quiet)["losses"]

    run_c = small_run(tmp_path / "chaos", steps=16)   # ckpt_every=10
    model = build(run_c)
    chaos = FaultSchedule([FaultEvent(12, "device_loss")])

    def attempt():
        mgr = CheckpointManager(run_c.train.ckpt_dir, keep=3,
                                async_save=False)
        return run_training(model, run_c, loader_for(run_c), manager=mgr,
                            guard=PreemptionGuard(install=False),
                            chaos=chaos, log=quiet)

    out, restarts = run_with_restarts(attempt, log=quiet)
    assert restarts == 1
    np.testing.assert_allclose(out["losses"], full[10:], rtol=1e-5,
                               atol=1e-6)


def test_seeded_chaos_run_completes(tmp_path):
    """A randomized (but fully seeded) schedule mixing every recoverable
    fault kind drives the loop + supervisor to completion."""
    run_c = with_ckpt_every(small_run(tmp_path / "c", steps=14), 3)
    model = build(run_c)
    chaos = FaultSchedule([FaultEvent(4, "straggler", 0.05),
                           FaultEvent(7, "save_crash", 0),
                           FaultEvent(10, "corrupt_latest"),
                           FaultEvent(11, "device_loss")])

    def attempt():
        mgr = CheckpointManager(run_c.train.ckpt_dir, keep=4,
                                async_save=False)
        return run_training(model, run_c, loader_for(run_c), manager=mgr,
                            guard=PreemptionGuard(install=False),
                            chaos=chaos, log=quiet)

    out, restarts = run_with_restarts(attempt, log=quiet)
    assert out["last_step"] == 14 and restarts >= 1
    assert chaos.pending() == []


# ------------------------------------------------- elastic mesh reshape
_ELASTIC = """
import shutil, tempfile
import jax, numpy as np
from repro.config.base import *
from repro.checkpoint.manager import CheckpointManager
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.distributed.chaos import FaultEvent, FaultSchedule
from repro.distributed.fault import PreemptionGuard
from repro.distributed.sharding import (fit_tree, make_constrain,
                                        make_shard_context)
from repro.models import build
from repro.models.spec import rules_variant
from repro.train.loop import run_training

QUANT = "__QUANT__"
BASE_P = ParallelConfig(mesh_shape=(2, 4), mesh_axes=("data", "model"))
CFG = ModelConfig(name="elastic", num_layers=2, d_model=64, num_heads=8,
                  num_kv_heads=2, d_ff=256, vocab_size=256,
                  rope_theta=1e4).with_mesh_padding(BASE_P.model_axis_size)

def run_for(shape, ckpt_dir):
    pcfg = ParallelConfig(mesh_shape=shape, mesh_axes=("data", "model")) \\
        if shape else ParallelConfig()
    return RunConfig(
        model=CFG,
        adapter=AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4,
                              fuse_linear=True),
        quant=QuantConfig(kind=QUANT, block_size=16),
        parallel=pcfg,
        train=TrainConfig(global_batch=8, seq_len=32, steps=8,
                          learning_rate=1e-3, warmup_steps=0, ckpt_every=4,
                          ckpt_keep=3, log_every=0, ckpt_dir=ckpt_dir))

def train(run, shape, chaos=None):
    loader = ShardedLoader(SyntheticSpec(vocab_size=CFG.vocab_size,
                                         seq_len=32, noise=0.05),
                           global_batch=8, seed=0)
    guard = PreemptionGuard(install=False)
    if shape is None:
        model = build(run)
        return run_training(model, run, loader, guard=guard,
                            log=lambda s: None, chaos=chaos)
    mesh = jax.make_mesh(shape, ("data", "model"))
    rules = rules_variant(run.parallel, "fused_tp")
    ctx = make_shard_context(mesh, rules, run)
    model = build(run, constrain=make_constrain(rules, mesh), shard=ctx)
    specs = model.param_specs(rules)

    def place(state):
        placed = fit_tree({"base": state.base, "adapter": state.adapter},
                          specs, mesh)
        return state._replace(base=placed["base"],
                              adapter=placed["adapter"])

    with mesh:
        return run_training(model, run, loader, guard=guard,
                            log=lambda s: None, chaos=chaos,
                            place_state=place)

full_dir = tempfile.mkdtemp()
full = train(run_for((2, 4), full_dir), (2, 4))["losses"]

# an INJECTED preemption on the (2,4) mesh flushes the step-4 checkpoint
ck = tempfile.mkdtemp()
out = train(run_for((2, 4), ck), (2, 4),
            chaos=FaultSchedule([FaultEvent(3, "preempt")]))
assert out["preempted"] and out["last_step"] == 4
for shape in ((4, 2), (8, 1), None):               # ...resume anywhere
    # each resume gets its own copy of the step-4 checkpoint (a completed
    # resume writes step 8, which would leave nothing for the next shape)
    d = tempfile.mkdtemp()
    shutil.rmtree(d); shutil.copytree(ck, d)
    out = train(run_for(shape, d), shape)
    assert len(out["losses"]) == 4, (shape, len(out["losses"]))
    np.testing.assert_allclose(out["losses"], full[4:], rtol=5e-4,
                               atol=1e-5)
    print("reshape-ok", shape)
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_reshape_resume_dense():
    out = run_py(textwrap.dedent(_ELASTIC.replace("__QUANT__", "none")),
                 devices=8)
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_elastic_reshape_resume_nf4():
    """QOFT: quantized base + hoisted rotations survive the reshape."""
    out = run_py(textwrap.dedent(_ELASTIC.replace("__QUANT__", "nf4")),
                 devices=8)
    assert "ELASTIC_OK" in out
