"""Unit + property tests for the paper's core math: packed skew params,
Cayley / Cayley-Neumann, OFTv1 == OFTv2 equivalence, LoRA, merging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config.base import AdapterConfig, QuantConfig
from repro.core import adapter as ad
from repro.core import cayley, lora, merging, oft, skew

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------- skew ----
@pytest.mark.parametrize("b", [2, 4, 8, 16, 32])
def test_pack_unpack_roundtrip(b):
    key = jax.random.PRNGKey(0)
    q_packed = skew.random_skew(key, (3,), b)
    q = skew.unpack_skew(q_packed, b)
    # skew-symmetry
    np.testing.assert_allclose(q, -np.swapaxes(q, -1, -2), atol=0)
    assert np.all(np.diagonal(q, axis1=-2, axis2=-1) == 0)
    np.testing.assert_allclose(skew.pack_skew(q), q_packed, atol=0)


def test_pack_dim():
    assert skew.pack_dim(32) == 496
    assert skew.pack_dim(2) == 1


# -------------------------------------------------------------- cayley ----
@pytest.mark.parametrize("b", [4, 16, 32])
def test_cayley_exact_orthogonal(b):
    q_packed = skew.random_skew(jax.random.PRNGKey(1), (5,), b, scale=0.3)
    r = cayley.cayley_exact(skew.unpack_skew(q_packed, b))
    err = cayley.orthogonality_error(r)
    assert float(err) < 1e-5
    # rotation: det == +1
    det = np.linalg.det(np.asarray(r, dtype=np.float64))
    np.testing.assert_allclose(det, 1.0, atol=1e-4)


def test_neumann_converges_geometrically():
    b = 16
    q = skew.unpack_skew(skew.random_skew(jax.random.PRNGKey(2), (1,), b,
                                          scale=0.02), b)
    exact = cayley.cayley_exact(q)
    errs = []
    for k in [1, 2, 3, 4, 5, 6]:
        approx = cayley.cayley_neumann(q, k)
        errs.append(float(jnp.max(jnp.abs(approx - exact))))
    # strictly decreasing, roughly geometric
    for e0, e1 in zip(errs, errs[1:]):
        assert e1 < e0
    assert errs[-1] < 1e-5


def test_neumann_near_orthogonal_small_q():
    b = 32
    q_packed = skew.random_skew(jax.random.PRNGKey(3), (4,), b, scale=0.01)
    r = cayley.build_rotation(q_packed, b, neumann_terms=5)
    assert float(cayley.orthogonality_error(r)) < 1e-4


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bexp=st.integers(1, 5),
       scale=st.floats(1e-3, 0.05))
def test_neumann_orthogonality_decays_monotonically(seed, bexp, scale):
    """Property (ISSUE-5 satellite): the orthogonality residual
    ||R^T R - I|| of the k-term Cayley-Neumann build decays monotonically
    in ``neumann_terms`` over random skew params / block sizes, up to the
    float32 noise floor.  The decay is monotone in strides of TWO: odd
    powers of a skew Q are themselves skew and cancel in the symmetric
    residual, so err(k) ~ ||Q||^{k+1} with alternating constants --
    comparing k to k+2 isolates the true geometric decay.  Generalizes the
    fixed-shape spot checks above."""
    b = 2 ** bexp                       # block sizes 2..32
    blocks = 1 + seed % 4
    q_packed = skew.random_skew(jax.random.PRNGKey(seed), (blocks,), b,
                                scale=scale)
    errs = [float(cayley.orthogonality_error(
        cayley.build_rotation(q_packed, b, neumann_terms=k)))
        for k in range(1, 7)]
    floor = 1e-6
    for e0, e2 in zip(errs, errs[2:]):
        assert e2 <= e0 + floor, (errs, b, blocks, scale)
    assert errs[-1] <= max(0.05 * errs[0], floor), (errs, b, scale)
    assert errs[-2] <= max(0.05 * errs[0], floor), (errs, b, scale)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bexp=st.integers(1, 4),
       scale=st.floats(1e-3, 0.2))
def test_merge_preserves_column_norms_property(seed, bexp, scale):
    """Property: merging an exact-Cayley OFT adapter (neumann_terms=0,
    exactly orthogonal R) into W preserves every column norm to float
    tolerance -- the paper's requantization argument.  The k-truncated
    merge drifts by at most the truncated R's own orthogonality residual
    (|.|norm ratio <= ||R^T R - I||_2 <= b * max-abs), a self-consistent
    bound with no fitted constants."""
    b = 2 ** bexp
    d_in, d_out = 4 * b, 24
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d_in, d_out)) / np.sqrt(d_in)
    params = {"q_packed": skew.random_skew(jax.random.fold_in(key, 1),
                                           (d_in // b,), b, scale=scale)}
    exact = AdapterConfig(kind="oftv2", block_size=b, neumann_terms=0)
    merged = oft.oft_merge(w, params, exact)
    drift = float(merging.column_norm_drift(w, merged))
    assert drift < 1e-5, (drift, b, scale)
    trunc = AdapterConfig(kind="oftv2", block_size=b, neumann_terms=6)
    merged_t = oft.oft_merge(w, params, trunc)
    drift_t = float(merging.column_norm_drift(w, merged_t))
    res = float(cayley.orthogonality_error(
        cayley.build_rotation(params["q_packed"], b, neumann_terms=6)))
    assert drift_t <= b * res + 1e-5, (drift_t, res, b, scale)


def test_zero_init_gives_identity():
    params = oft.oft_init(64, 16)
    r = cayley.build_rotation(params["q_packed"], 16, 5)
    np.testing.assert_allclose(np.asarray(r), np.broadcast_to(np.eye(16), r.shape),
                               atol=0)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.001, 0.2), seed=st.integers(0, 2**16))
def test_property_norm_preservation(scale, seed):
    """Hyperspherical-energy invariance surrogate: exact Cayley preserves
    l2 norms of every input vector (the paper's core geometric argument)."""
    b = 8
    key = jax.random.PRNGKey(seed)
    q = skew.unpack_skew(skew.random_skew(key, (2,), b, scale=scale), b)
    r = cayley.cayley_exact(q)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 7, b))
    y = jnp.einsum("nsb,nbc->nsc", x, r)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=2e-4)


# ------------------------------------------------------- v1 == v2 ----------
@pytest.mark.parametrize("d_in,d_out,b", [(64, 48, 16), (128, 128, 32),
                                          (96, 160, 8)])
@pytest.mark.parametrize("neumann", [0, 5])
def test_oftv1_equals_oftv2(d_in, d_out, b, neumann):
    """The paper's central identity: input-centric == weight-centric."""
    acfg = AdapterConfig(kind="oftv2", block_size=b, neumann_terms=neumann)
    key = jax.random.PRNGKey(7)
    kx, kw, kq = jax.random.split(key, 3)
    x = jax.random.normal(kx, (3, 5, d_in))
    w = jax.random.normal(kw, (d_in, d_out)) / np.sqrt(d_in)
    params = {"q_packed": skew.random_skew(kq, (d_in // b,), b, scale=0.1)}
    y2 = oft.oftv2_transform_input(x, params, acfg) @ w
    y1 = x @ oft.oftv1_transform_weight(w, params, acfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)


def test_oft_grads_match_between_formulations():
    d_in, d_out, b = 64, 32, 16
    acfg = AdapterConfig(kind="oftv2", block_size=b, neumann_terms=4)
    key = jax.random.PRNGKey(11)
    kx, kw, kq = jax.random.split(key, 3)
    x = jax.random.normal(kx, (8, d_in))
    w = jax.random.normal(kw, (d_in, d_out)) / 8.0
    params = {"q_packed": skew.random_skew(kq, (d_in // b,), b, scale=0.05)}

    def loss_v2(p):
        return jnp.sum(jnp.square(oft.oftv2_transform_input(x, p, acfg) @ w))

    def loss_v1(p):
        return jnp.sum(jnp.square(x @ oft.oftv1_transform_weight(w, p, acfg)))

    g2 = jax.grad(loss_v2)(params)["q_packed"]
    g1 = jax.grad(loss_v1)(params)["q_packed"]
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-4)


def test_identity_adapter_is_noop():
    acfg = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    params = oft.oft_init(64, 16)
    np.testing.assert_allclose(np.asarray(oft.oftv2_transform_input(x, params, acfg)),
                               np.asarray(x), atol=0)


# ------------------------------------------------------------- lora --------
def test_lora_starts_as_identity_and_learns():
    acfg = AdapterConfig(kind="lora", rank=4, alpha=8.0)
    key = jax.random.PRNGKey(0)
    params = lora.lora_init(key, 32, 16, 4)
    x = jax.random.normal(key, (6, 32))
    np.testing.assert_allclose(np.asarray(lora.lora_delta(x, params, acfg)), 0.0,
                               atol=0)
    params["lora_b"] = jnp.ones_like(params["lora_b"])
    assert float(jnp.max(jnp.abs(lora.lora_delta(x, params, acfg)))) > 0


# --------------------------------------------------------- adapted linear --
@pytest.mark.parametrize("kind", ["none", "oftv1", "oftv2", "lora"])
def test_adapted_linear_all_kinds(kind):
    acfg = AdapterConfig(kind=kind, block_size=16, neumann_terms=3, rank=4)
    qcfg = QuantConfig(kind="none")
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 9, 64))
    w = jax.random.normal(key, (64, 48)) / 8.0
    adp = ad.adapter_init(key, "q", 64, 48, acfg)
    y = ad.adapted_linear(x, {"w": w}, adp, acfg, qcfg)
    assert y.shape == (2, 9, 48)
    assert np.all(np.isfinite(np.asarray(y)))
    if kind != "none":
        # fresh adapters are identity => output == plain linear
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5,
                                   atol=1e-5)


def test_param_count_helpers():
    acfg_oft = AdapterConfig(kind="oftv2", block_size=32)
    acfg_lora = AdapterConfig(kind="lora", rank=16)
    assert ad.adapter_param_count("q", 4096, 4096, acfg_oft) == 128 * 496
    assert ad.adapter_param_count("q", 4096, 4096, acfg_lora) == 16 * 8192
    assert ad.adapter_param_count("zz", 4096, 4096, acfg_lora) == 0


# ------------------------------------------------------------ merging ------
def test_merge_oft_preserves_column_norms():
    acfg = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=0)
    key = jax.random.PRNGKey(5)
    w = jax.random.normal(key, (64, 96)) / 8.0
    adp = {"q_packed": skew.random_skew(key, (4,), 16, scale=0.2)}
    merged = ad.merge_adapter(w, adp, acfg)
    assert float(merging.column_norm_drift(w, merged)) < 1e-5


def test_merged_oft_equals_runtime_forward():
    acfg = AdapterConfig(kind="oftv2", block_size=8, neumann_terms=6)
    key = jax.random.PRNGKey(6)
    w = jax.random.normal(key, (32, 24)) / 6.0
    adp = {"q_packed": skew.random_skew(key, (4,), 8, scale=0.1)}
    x = jax.random.normal(key, (5, 32))
    y_runtime = oft.oftv2_transform_input(x, adp, acfg) @ w
    y_merged = x @ ad.merge_adapter(w, adp, acfg)
    np.testing.assert_allclose(np.asarray(y_runtime), np.asarray(y_merged),
                               rtol=1e-4, atol=1e-5)


def test_qoft_requant_beats_qlora_worstcase():
    """Paper §4: QLoRA's worst-case dynamic-range shift is ||AB||_inf; QOFT's
    is bounded by the rotation (no additive drift)."""
    key = jax.random.PRNGKey(9)
    kw, ka, kq = jax.random.split(key, 3)
    w = jax.random.normal(kw, (128, 64)) * 0.02
    acfg_o = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=0)
    acfg_l = AdapterConfig(kind="lora", rank=8, alpha=16.0)
    oft_p = {"q_packed": skew.random_skew(kq, (8,), 16, scale=0.15)}
    lora_p = lora.lora_init(ka, 128, 64, 8)
    # give LoRA a realistic trained B
    lora_p["lora_b"] = 0.02 * jax.random.normal(kq, lora_p["lora_b"].shape)
    m_oft = ad.merge_adapter(w, oft_p, acfg_o)
    m_lora = ad.merge_adapter(w, lora_p, acfg_l)
    assert float(merging.column_norm_drift(w, m_oft)) < 1e-5
    assert float(merging.column_norm_drift(w, m_lora)) > 1e-4
    bound = float(merging.lora_worstcase_range_shift(lora_p, acfg_l))
    shift = float(merging.dynamic_range_shift(w, m_lora))
    assert shift <= bound + 1e-6


def test_flops_accounting_v1_cubic_vs_v2_quadratic():
    d, n, tokens, b = 4096, 4096, 8192, 32
    f1 = oft.oft_flops_per_step(d, n, tokens, b, input_centric=False)
    f2 = oft.oft_flops_per_step(d, n, tokens, b, input_centric=True)
    # v1's weight transform dominates v2's per-token apply only when
    # tokens < d_out; at training batch sizes v2 costs more raw adapter
    # flops but removes the d x n weight materialization + its backward.
    assert f1 != f2
    # doubling d_out doubles v1 cost, leaves v2 unchanged
    assert oft.oft_flops_per_step(d, 2 * n, tokens, b, False) > 1.9 * (
        f1 - oft.num_blocks(d, b) * 5 * 2 * b ** 3)
    assert oft.oft_flops_per_step(d, 2 * n, tokens, b, True) == f2
