"""ISSUE-10: BOFT (butterfly) and GOFT (Givens) adapter methods -- the
multi-stage rotate-in-VMEM kernels, their registry entries, and BOFT's
budgeted cross-shard exchange.

What is pinned down:
  * property (hypothesis): the composed butterfly is orthogonal to
    machine precision at EVERY depth (exact Cayley blocks conjugated by
    involutive permutations); at depth >= 2 it genuinely mixes features
    across blocks (the thing OFTv2 cannot do); GOFT's trig-free Givens
    composition stays quasi-orthogonal with a residual that grows only
    with accumulated rounding as passes stack up;
  * fused == unfused == jnp oracle, forward AND grads, for both methods,
    including odd / misaligned token counts and output widths;
  * config-time validation is uniform across init / param_count /
    param_defs (the HOFT even-reflections pattern, extended): BOFT's
    power-of-two block count, stage bounds, even-block constraint, and
    GOFT's even-d / pass bounds all raise loud ValueErrors from every
    entry hook;
  * the ISSUE-10 acceptance gate, on 8 fake devices: BOFT's sharded
    fused train step passes `collective-budget` AND
    `hlo-collective-budget` with its DECLARED all_gather exchange, and
    both rules fail when the declaration is stripped (the first
    non-psum consumer of the generalized budget is detectable, not
    grandfathered in); sharded step parity against single device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _mesh import run_py
from repro import methods
from repro.config.base import AdapterConfig
from repro.core import boft as boft_lib
from repro.core import goft as goft_lib
from repro.core import skew
from repro.core.cayley import orthogonality_error
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _boft_cfg(block_size=16, stages=0, neumann=0, fused=False):
    return AdapterConfig(kind="boft", block_size=block_size,
                         neumann_terms=neumann, butterfly_stages=stages,
                         fuse_linear=fused)


def _goft_cfg(passes=4, fused=False):
    return AdapterConfig(kind="goft", givens_passes=passes,
                         fuse_linear=fused)


def _boft_rot(key, d, cfg, scale=0.2):
    r = boft_lib.num_blocks(d, cfg)
    s = boft_lib.num_stages(d, cfg)
    q = scale * jax.random.normal(key, (s, r, skew.pack_dim(cfg.block_size)))
    return boft_lib.build_stage_rotations({"boft_q": q}, cfg)


# ---------------------------------------------------------------------------
# properties: orthogonality at any depth, cross-block reach, GOFT residual
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), stages=st.integers(1, 4),
       scale=st.floats(0.05, 0.8))
def test_butterfly_orthogonal_to_machine_precision_at_any_depth(
        seed, stages, scale):
    """Exact-Cayley blocks (neumann_terms=0) conjugated by involutive
    permutations: the COMPOSED d x d butterfly satisfies B^T B = I to
    fp32 rounding at every depth 1..log2(r)+1 -- depth adds reach, not
    error growth beyond accumulated rounding."""
    d, cfg = 64, _boft_cfg(block_size=8, stages=stages, neumann=0)
    rot = _boft_rot(jax.random.PRNGKey(seed), d, cfg, scale)
    b_full = boft_lib.boft_apply(jnp.eye(d, dtype=jnp.float32), rot)
    assert float(orthogonality_error(b_full)) < 1e-5


def test_butterfly_mixes_across_blocks_where_oftv2_cannot():
    """At depth >= 2 the butterfly matrix has genuine off-block-diagonal
    energy: features in different OFTv2 blocks influence each other."""
    d, cfg = 64, _boft_cfg(block_size=16, stages=0, neumann=0)
    rot = _boft_rot(jax.random.PRNGKey(3), d, cfg)
    b_full = np.asarray(
        boft_lib.boft_apply(jnp.eye(d, dtype=jnp.float32), rot))
    b = cfg.block_size
    off = b_full.copy()
    for i in range(d // b):
        off[i * b:(i + 1) * b, i * b:(i + 1) * b] = 0.0
    assert np.abs(off).max() > 0.01, "butterfly never left its block"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), passes=st.integers(1, 32),
       scale=st.floats(0.05, 2.0))
def test_goft_quasi_orthogonality_residual_stays_bounded(seed, passes,
                                                         scale):
    """Every trig-free plane rotation has c^2 + s^2 = 1 exactly in exact
    arithmetic; composing up to d passes accumulates only rounding, so
    the residual stays at fp32 noise even for large thetas."""
    d = 32
    thetas = scale * jax.random.normal(jax.random.PRNGKey(seed),
                                       (passes, d // 2))
    g_full = goft_lib.goft_apply(jnp.eye(d, dtype=jnp.float32), thetas)
    assert float(orthogonality_error(g_full)) < 2e-5


def test_identity_at_init_and_merge_noop():
    """Zero params => exact identity transform for both methods, so a
    merged weight equals the base weight bit-for-bit in fp32."""
    d, n = 64, 48
    w = jax.random.normal(jax.random.PRNGKey(0), (d, n), jnp.float32)
    bcfg, gcfg = _boft_cfg(neumann=0), _goft_cfg()
    bp = boft_lib.boft_init(d, bcfg)
    gp = goft_lib.goft_init(d, gcfg)
    np.testing.assert_array_equal(
        np.asarray(boft_lib.boft_merge(w, bp, bcfg)), np.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(goft_lib.goft_merge(w, gp, gcfg)), np.asarray(w))


# ---------------------------------------------------------------------------
# fused == unfused == oracle (fwd + grads), odd / misaligned shapes
# ---------------------------------------------------------------------------
LEADS = [(24,), (13,), (7, 3), (1,)]


@pytest.mark.parametrize("lead", LEADS, ids=[str(s) for s in LEADS])
@pytest.mark.parametrize("d,n", [(64, 48), (64, 33), (128, 16)])
def test_boft_fused_matches_oracle_fwd_and_grad(lead, d, n):
    cfg = _boft_cfg(neumann=0)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, lead + (d,), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (d, n),
                          jnp.float32) / np.sqrt(d)
    rot = _boft_rot(jax.random.PRNGKey(3), d, cfg)

    def loss(fn):
        return lambda x, r, w: jnp.sum(jnp.sin(fn(x, r, w)))

    y = kops.boft_linear_fused(x, rot, w)
    y_ref = kref.boft_linear_ref(x, rot, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    y_unfused = boft_lib.boft_apply(x, rot) @ w
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_unfused),
                               rtol=2e-4, atol=2e-5)
    g = jax.grad(loss(kops.boft_linear_fused), argnums=(0, 1, 2))(x, rot, w)
    g_ref = jax.grad(loss(kref.boft_linear_ref), argnums=(0, 1, 2))(
        x, rot, w)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("lead", LEADS, ids=[str(s) for s in LEADS])
@pytest.mark.parametrize("d,n,passes", [(64, 48, 4), (64, 33, 7),
                                        (32, 16, 32)])
def test_goft_fused_matches_oracle_fwd_and_grad(lead, d, n, passes):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, lead + (d,), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (d, n),
                          jnp.float32) / np.sqrt(d)
    thetas = 0.3 * jax.random.normal(jax.random.PRNGKey(6), (passes, d // 2))

    def loss(fn):
        return lambda x, t, w: jnp.sum(jnp.sin(fn(x, t, w)))

    y = kops.goft_linear_fused(x, thetas, w)
    y_ref = kref.goft_linear_ref(x, thetas, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    y_unfused = goft_lib.goft_apply(x, thetas) @ w
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_unfused),
                               rtol=2e-4, atol=2e-5)
    g = jax.grad(loss(kops.goft_linear_fused), argnums=(0, 1, 2))(
        x, thetas, w)
    g_ref = jax.grad(loss(kref.goft_linear_ref), argnums=(0, 1, 2))(
        x, thetas, w)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# config-time validation, uniform across every registry entry hook
# ---------------------------------------------------------------------------
BOFT_BAD = [
    (40, _boft_cfg(), "not divisible"),
    (48, _boft_cfg(), "power-of-two multiple"),
    (64, _boft_cfg(stages=5), "out of range"),
    (60, AdapterConfig(kind="boft", block_size=15, butterfly_stages=2),
     "must be even"),
]
GOFT_BAD = [
    (33, _goft_cfg(), "must be even"),
    (64, _goft_cfg(passes=0), "out of range"),
    (64, _goft_cfg(passes=65), "out of range"),
]


@pytest.mark.parametrize("kind,d_in,cfg,match",
                         [("boft",) + c for c in BOFT_BAD]
                         + [("goft",) + c for c in GOFT_BAD])
@pytest.mark.parametrize("hook", ["init", "param_count", "param_defs"])
def test_bad_configs_fail_loudly_from_every_hook(kind, d_in, cfg, match,
                                                 hook):
    """A config that cannot build must raise the SAME ValueError whether
    the caller inits params, counts them, or asks for shape defs -- no
    hook may silently produce shapes for an impossible config."""
    method = methods.get(kind)
    call = {
        "init": lambda: method.init(jax.random.PRNGKey(0), "q", d_in, 64,
                                    cfg),
        "param_count": lambda: method.param_count("q", d_in, 64, cfg),
        "param_defs": lambda: method.param_defs("q", d_in, 64, cfg),
    }[hook]
    with pytest.raises(ValueError, match=match):
        call()


def test_auto_depth_is_full_butterfly():
    """butterfly_stages=0 selects the full log-depth factorization."""
    assert boft_lib.num_stages(64, _boft_cfg(block_size=16)) == 3
    assert boft_lib.num_stages(64, _boft_cfg(block_size=8)) == 4
    assert boft_lib.stage_strides(4) == (0, 1, 2, 4)


# ---------------------------------------------------------------------------
# the acceptance gate: declared exchange passes, stripped one fails
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_boft_budget_declared_vs_stripped_and_parity():
    """On a 2x4 mesh (8 fake devices): the sharded fused BOFT train step
    passes BOTH budget rules with the method's declared
    ("psum", "all_gather") -- and stripping the declaration (a psum-only
    override) makes BOTH rules fail: the jaxpr layer on the gather
    primitives, the HLO layer on a gathered activation whose trailing
    shape collides with a W shape (seq_len=64 == d_model arranges the
    collision on purpose).  Plus loss/grad parity against single device:
    the exchange buys a CORRECT butterfly across shards, not just a
    budget waiver."""
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.config.base import *
    from repro.models import build
    from repro.models.spec import rules_variant
    from repro.distributed.sharding import (batch_spec, fit_tree,
                                            make_constrain,
                                            make_shard_context)
    from repro.train import state as state_lib
    from repro.train.step import make_train_step
    from repro.analysis import (assert_collective_budget,
                                assert_no_w_gathers_hlo)

    pcfg = ParallelConfig(mesh_shape=(2, 4), mesh_axes=("data", "model"))
    cfg = ModelConfig(name="boft-shard", num_layers=2, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=256,
                      vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="boft", block_size=16, neumann_terms=4,
                              fuse_linear=True),
        quant=QuantConfig(kind="none", block_size=16),
        parallel=pcfg,
        train=TrainConfig(global_batch=8, seq_len=64, learning_rate=1e-3,
                          steps=5, warmup_steps=0))

    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                          0, cfg.vocab_size)}
    mesh = jax.make_mesh(pcfg.mesh_shape, pcfg.mesh_axes)
    rules = rules_variant(pcfg, "fused_tp")
    ctx = make_shard_context(mesh, rules, run)
    model = build(run, constrain=make_constrain(rules, mesh), shard=ctx)
    params_sh = fit_tree(params, model.param_specs(rules), mesh)
    batch_sh = {"tokens": jax.device_put(
        batch["tokens"], NamedSharding(mesh, batch_spec(pcfg, 2)))}
    st_ref = state_lib.create(params)
    st = state_lib.create(params_sh)
    step_fn = make_train_step(model, run)

    with mesh:
        # declared budget (resolved from the registry): both layers pass
        assert_collective_budget(step_fn, (st, batch_sh), 4, kind="boft")
        assert_no_w_gathers_hlo(step_fn, (st, batch_sh), cfg, kind="boft")
        # declaration stripped -> both layers FAIL on the same program
        try:
            assert_collective_budget(step_fn, (st, batch_sh), 4,
                                     allowed=("psum",))
            raise SystemExit("jaxpr budget rule missed the all_gather")
        except AssertionError as e:
            assert "all_gather" in str(e), e
        try:
            assert_no_w_gathers_hlo(step_fn, (st, batch_sh), cfg,
                                    allowed=("psum",))
            raise SystemExit("HLO budget rule missed the W-shaped gather")
        except AssertionError as e:
            assert "all-gather of weight-shaped" in str(e), e

    # parity: the budgeted exchange computes the same butterfly
    step_ref = jax.jit(make_train_step(model_ref, run))
    with mesh:
        step = jax.jit(step_fn)
    for i in range(5):
        st_ref, m_ref = step_ref(st_ref, batch)
        with mesh:
            st, m = step(st, batch_sh)
        np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                                   rtol=2e-4, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(st_ref.adapter),
                    jax.tree_util.tree_leaves(st.adapter)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-5)
    print("BOFT-SHARD-OK")
    """)
