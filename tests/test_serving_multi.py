"""Multi-tenant serving: the multi-adapter kernels against per-adapter
single-kernel runs (row-for-row, bitwise), the adapter pool's stacked
rotation build, the continuous-batching scheduler, and the engine's
end-to-end guarantee -- a mixed-adapter batched decode produces exactly
the tokens of N separate single-adapter runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig)
from repro.core import skew
from repro.core.cayley import build_rotation
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant import nf4

pytestmark = pytest.mark.kernels


def _multi_inputs(n_adapters, lead, d, n, b, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, lead + (d,), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, n),
                          jnp.float32) / np.sqrt(d)
    qp = skew.random_skew(key, (n_adapters, d // b), b, scale=0.1)
    r_stack = build_rotation(qp, b, 5)
    ids = jax.random.randint(jax.random.fold_in(key, 2), lead[:1], 0,
                             n_adapters)
    return x, r_stack, ids, w


# ------------------------------------------------- oftv2_linear_multi -----
MULTI_SHAPES = [
    # odd token counts / narrow d_out exercise token padding and the n/k
    # tile fallbacks, exactly like the single-kernel sweeps
    (3, (37,), 64, 48, 16), (4, (3, 7), 128, 96, 32), (2, (260,), 96, 33, 8),
    (5, (1,), 64, 64, 64), (2, (512,), 256, 128, 32),
]


@pytest.mark.parametrize("a,lead,d,n,b", MULTI_SHAPES)
def test_oftv2_linear_multi_matches_ref(a, lead, d, n, b):
    x, r_stack, ids, w = _multi_inputs(a, lead, d, n, b)
    got = kops.oftv2_linear_multi(x, r_stack, ids, w)
    want = kref.oftv2_linear_multi_ref(x, r_stack, ids, w)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("a,lead,d,n,b", MULTI_SHAPES[:3])
def test_oftv2_linear_multi_rowwise_bitwise_vs_single(a, lead, d, n, b):
    """Each row of the multi kernel's output is BITWISE the single-adapter
    kernel's row for that row's adapter -- the property the engine's
    batched-equals-sequential guarantee rests on."""
    x, r_stack, ids, w = _multi_inputs(a, lead, d, n, b)
    got = np.asarray(kops.oftv2_linear_multi(x, r_stack, ids, w))
    ids_np = np.asarray(jnp.broadcast_to(
        ids.reshape((-1,) + (1,) * (len(lead) - 1)), lead))
    for adapter in range(a):
        single = np.asarray(kops.oftv2_linear_fused(x, r_stack[adapter], w,
                                                    train_w=False))
        rows = ids_np == adapter
        np.testing.assert_array_equal(got[rows], single[rows])


def test_oftv2_linear_multi_id_permutations():
    """Permuting which row gets which adapter permutes (only) the rows."""
    a, d, n, b, t = 3, 64, 48, 16, 12
    x, r_stack, _, w = _multi_inputs(a, (t,), d, n, b)
    ids = jnp.arange(t, dtype=jnp.int32) % a
    perm = jax.random.permutation(jax.random.PRNGKey(9), t)
    got_perm = kops.oftv2_linear_multi(x[perm], r_stack, ids[perm], w)
    got = kops.oftv2_linear_multi(x, r_stack, ids, w)
    np.testing.assert_array_equal(np.asarray(got_perm),
                                  np.asarray(got)[np.asarray(perm)])


def test_oftv2_linear_multi_const_id_fast_path():
    """Python-int adapter_id lowers to the single-adapter fused kernel; an
    all-rows-same traced id vector matches it bitwise."""
    a, d, n, b = 4, 64, 48, 16
    x, r_stack, _, w = _multi_inputs(a, (21,), d, n, b)
    single = np.asarray(kops.oftv2_linear_fused(x, r_stack[2], w,
                                                train_w=False))
    fast = np.asarray(kops.oftv2_linear_multi(x, r_stack, 2, w))
    np.testing.assert_array_equal(fast, single)
    traced = np.asarray(kops.oftv2_linear_multi(
        x, r_stack, jnp.full((21,), 2, jnp.int32), w))
    np.testing.assert_array_equal(traced, single)


# -------------------------------------------------- qoft_linear_multi -----
@pytest.mark.parametrize("a,d,n,b,bs", [
    (3, 128, 64, 16, 64), (4, 256, 96, 32, 32), (2, 64, 33, 16, 16),
])
def test_qoft_linear_multi_matches_ref_and_single(a, d, n, b, bs):
    x, r_stack, ids, w = _multi_inputs(a, (29,), d, n, b, seed=1)
    qcfg = QuantConfig(kind="nf4", block_size=bs, double_quant=False)
    q = nf4.quantize(0.1 * w, qcfg)
    got = kops.qoft_linear_multi(x, r_stack, ids, q["nf4_codes"],
                                 q["absmax"], bs)
    want = kref.qoft_linear_multi_ref(x, r_stack, ids, q["nf4_codes"],
                                      q["absmax"], bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-5)
    ids_np = np.asarray(ids)
    for adapter in range(a):
        single = np.asarray(kops.qoft_linear_fused(
            x, r_stack[adapter], q["nf4_codes"], q["absmax"], bs))
        rows = ids_np == adapter
        # ULP-level tolerance: on some odd n the interpret-mode XLA:CPU
        # executor fuses the routing `where` into the dequant+dot chain and
        # reassociates one SIMD reduction; greedy tokens are still exact
        # (test_engine_multi_decode_bitwise_equals_single_runs).
        np.testing.assert_allclose(np.asarray(got)[rows], single[rows],
                                   rtol=1e-6, atol=3e-7)
    fast = np.asarray(kops.qoft_linear_multi(x, r_stack, 1, q["nf4_codes"],
                                             q["absmax"], bs))
    np.testing.assert_array_equal(
        fast, np.asarray(kops.qoft_linear_fused(x, r_stack[1],
                                                q["nf4_codes"], q["absmax"],
                                                bs)))


# ------------------------------------------------------------ scheduler ---
def test_scheduler_admission_eviction():
    from repro.serving import Request, Scheduler
    sched = Scheduler(2)
    sched.submit_all([Request(f"r{i}", [1, 2], adapter_id=0,
                              max_new_tokens=2) for i in range(3)])
    admitted = sched.admit()
    assert [slot for slot, _ in admitted] == [0, 1]
    assert sched.pending_count == 1 and sched.admit() == []
    # r0 finishes (2 tokens) -> slot 0 frees -> r2 takes it
    assert not sched.record_token(0, 5)
    assert sched.record_token(0, 5)
    sched.evict(0)
    assert [r.rid for _, r in sched.admit()] == ["r2"]
    # eos stops early
    sched2 = Scheduler(1)
    sched2.submit(Request("e", [1], adapter_id=0, max_new_tokens=99,
                          eos_id=7))
    sched2.admit()
    assert not sched2.record_token(0, 3)
    assert sched2.record_token(0, 7)


# ----------------------------------------------------- pool + engine ------
def _tiny_serving_model(qkind="none"):
    from repro.models import build
    cfg = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                      d_ff=128, vocab_size=128, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="nf4", block_size=32)
                    if qkind == "nf4" else QuantConfig(kind="none"))
    model = build(run)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def test_pool_stacks_rotations_once():
    """r_stack leaves have shape (scan, A, blocks, b, b) and row a equals
    the single-adapter hoisted rotations of adapter a."""
    from repro.core import rotations as rot_lib
    from repro.serving import AdapterPool, init_adapters
    model, params, cfg = _tiny_serving_model()
    adapters = init_adapters(model, 3, jax.random.PRNGKey(5))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"t{i}", tree)
    pooled = pool.build()
    leaf = pooled["groups"]["pos_0"]["attn"]["q"]
    assert leaf["r_stack"].shape[1] == 3          # (scan, A, blocks, b, b)
    acfg = model.run.adapter
    for a in range(3):
        single = rot_lib.with_rotations(adapters[a], acfg)
        want = single["groups"]["pos_0"]["attn"]["q"]["r_blocks"]
        np.testing.assert_array_equal(
            np.asarray(leaf["r_stack"][:, a]), np.asarray(want))


def test_pool_rejects_mismatched_and_lora():
    from repro.models import build
    from repro.serving import AdapterPool, init_adapters
    model, params, cfg = _tiny_serving_model()
    pool = AdapterPool(model)
    pool.register("a", init_adapters(model, 1)[0])
    with pytest.raises(ValueError, match="already registered"):
        pool.register("a", init_adapters(model, 1)[0])
    run_lora = model.run.replace(adapter=AdapterConfig(kind="lora", rank=4))
    with pytest.raises(ValueError, match="fuse_linear"):
        AdapterPool(build(run_lora))
    run_unfused = model.run.replace(
        adapter=AdapterConfig(kind="oftv2", block_size=16))
    with pytest.raises(ValueError, match="fuse_linear"):
        AdapterPool(build(run_unfused))


@pytest.mark.parametrize("qkind", ["none", "nf4"])
def test_engine_multi_decode_bitwise_equals_single_runs(qkind):
    """THE acceptance property: a mixed-adapter batch (N=4 adapters) decodes
    greedily to exactly the tokens of 4 single-adapter generate() runs --
    dense and NF4-quantized frozen base."""
    from repro.serving import (AdapterPool, Request, ServingEngine,
                               init_adapters)
    from repro.train.serving import generate
    model, params, cfg = _tiny_serving_model(qkind)
    n_adapters, prompt_len, gen = 4, 6, 5
    adapters = init_adapters(model, n_adapters, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"t{i}", tree)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(3), i), (prompt_len,), 0,
        cfg.vocab_size)) for i in range(n_adapters)]

    engine = ServingEngine(model, params, pool, n_slots=n_adapters)
    out = engine.run([Request(f"r{i}", prompts[i], adapter_id=i,
                              max_new_tokens=gen)
                      for i in range(n_adapters)])
    for i in range(n_adapters):
        single = {"base": params["base"], "adapter": adapters[i]}
        full = generate(model, single, jnp.asarray(prompts[i])[None],
                        steps=gen)
        np.testing.assert_array_equal(out[f"r{i}"],
                                      np.asarray(full)[0, prompt_len:])


def test_engine_continuous_batching_fewer_slots():
    """More requests than slots: admission/eviction interleaves them and
    every request still gets its exact single-run tokens."""
    from repro.serving import (AdapterPool, Request, ServingEngine,
                               init_adapters)
    model, params, cfg = _tiny_serving_model()
    adapters = init_adapters(model, 2, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"t{i}", tree)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(3), i), (4,), 0,
        cfg.vocab_size)) for i in range(5)]
    # varying lengths exercise staggered eviction
    reqs = [Request(f"r{i}", prompts[i], adapter_id=i % 2,
                    max_new_tokens=2 + (i % 3)) for i in range(5)]
    big = ServingEngine(model, params, pool, n_slots=5,
                        s_max=4 + 4).run(reqs)
    small = ServingEngine(model, params, pool, n_slots=2,
                          s_max=4 + 4).run(reqs)
    assert set(big) == set(small)
    for rid in big:
        np.testing.assert_array_equal(big[rid], small[rid])


def test_engine_heterogeneous_prompt_lengths_bitwise():
    """Prompt lengths off the 8-bucket (prefill pads to a multiple of 8 and
    invalidates the padded tail's cache entries): every request still gets
    exactly its single-run tokens."""
    from repro.serving import (AdapterPool, Request, ServingEngine,
                               init_adapters)
    from repro.train.serving import generate
    model, params, cfg = _tiny_serving_model()
    adapters = init_adapters(model, 2, jax.random.PRNGKey(7))
    pool = AdapterPool(model)
    for i, tree in enumerate(adapters):
        pool.register(f"t{i}", tree)
    lengths, gen = [3, 6, 11], 4
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(3), i), (n,), 0,
        cfg.vocab_size)) for i, n in enumerate(lengths)]
    engine = ServingEngine(model, params, pool, n_slots=3)
    out = engine.run([Request(f"r{i}", prompts[i], adapter_id=i % 2,
                              max_new_tokens=gen) for i in range(3)])
    for i in range(3):
        single = {"base": params["base"], "adapter": adapters[i % 2]}
        full = generate(model, single, jnp.asarray(prompts[i])[None],
                        steps=gen)
        np.testing.assert_array_equal(out[f"r{i}"],
                                      np.asarray(full)[0, lengths[i]:])


def test_engine_rejects_bad_requests():
    """Out-of-pool adapter_id and duplicate rids fail loudly instead of
    silently decoding zero-rotated garbage / interleaving outputs."""
    from repro.serving import (AdapterPool, Request, ServingEngine,
                               init_adapters)
    model, params, cfg = _tiny_serving_model()
    pool = AdapterPool(model)
    for i, tree in enumerate(init_adapters(model, 2)):
        pool.register(f"t{i}", tree)
    engine = ServingEngine(model, params, pool, n_slots=2)
    with pytest.raises(ValueError, match="adapter_id 5 outside"):
        engine.run([Request("r0", [1, 2], adapter_id=5)])
    with pytest.raises(ValueError, match="duplicate request ids"):
        engine.run([Request("r0", [1, 2], adapter_id=0),
                    Request("r0", [3, 4], adapter_id=1)])


def test_model_multi_fusion_plan():
    from repro.models.linears import model_multi_fusion_plan, \
        multi_fusion_mode
    acfg = AdapterConfig(kind="oftv2", block_size=16, fuse_linear=True)
    nf4_q = QuantConfig(kind="nf4", block_size=32)
    assert multi_fusion_mode("q", 128, 96, acfg, nf4_q) == "qoft_multi"
    assert multi_fusion_mode("q", 128, 96, acfg,
                             QuantConfig(kind="none")) == "oftv2_multi"
    assert multi_fusion_mode("router", 128, 96, acfg, nf4_q) == "unfused"
    cfg = ModelConfig(num_layers=2, d_model=128, num_heads=4,
                      num_kv_heads=2, d_ff=256)
    plan = model_multi_fusion_plan(cfg, acfg, QuantConfig(kind="none"))
    assert set(plan.values()) == {"oftv2_multi"}
