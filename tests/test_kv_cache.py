"""Paged KV cache as a unit: allocator invariants, prefix-index
adoption/copy-on-write, LRU eviction of cached blocks, and a property
test that ANY interleaving of begin/grow/free never leaks or
double-frees a block.

Control-plane only where possible -- the device pool rides along but the
assertions here are about block bookkeeping (token-for-token correctness
of paged attention lives in tests/test_serving_paged.py)."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig, \
    RunConfig
from repro.models import build
from repro.serving.kv_cache import NULL_BLOCK, BlockAllocator, PagedKVCache


def _tiny_model():
    cfg = ModelConfig(name="kvt", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=8,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="none"))
    return build(run)


def _kv(num_blocks=12, block_size=4, max_seq_len=32):
    return PagedKVCache(_tiny_model(), num_blocks=num_blocks,
                        block_size=block_size, max_seq_len=max_seq_len)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_reuse():
    a = BlockAllocator(4)                    # blocks 1..3
    got = [a.alloc() for _ in range(3)]
    assert sorted(got) == [1, 2, 3]
    assert a.alloc() is None                 # exhausted, no block 0 ever
    assert a.decref(2) is True
    a.release(2)
    assert a.alloc() == 2                    # reused
    assert a.n_free == 0 and a.n_used == 3


def test_allocator_refcounting():
    a = BlockAllocator(4)
    b = a.alloc()
    a.incref(b)
    assert a.ref(b) == 2
    assert a.decref(b) is False              # still referenced
    assert a.decref(b) is True               # now unreferenced
    with pytest.raises(ValueError, match="double free"):
        a.decref(b)
    with pytest.raises(ValueError, match="unallocated"):
        a.incref(b)
    a.release(b)
    with pytest.raises(ValueError, match="double release"):
        a.release(b)


def test_allocator_rejects_bad_sizes():
    with pytest.raises(ValueError, match="reserved"):
        BlockAllocator(1)


# ---------------------------------------------------------------------------
# block tables / capacity
# ---------------------------------------------------------------------------
def test_begin_grow_free_roundtrip():
    kv = _kv()
    start, shared = kv.begin("r0", [1, 2, 3, 4, 5], adapter_id=0)
    assert (start, shared) == (0, 0)         # cold cache: prefill everything
    kv.ensure_capacity("r0", 4)              # positions 0..4 -> 2 blocks
    assert len(kv.tables["r0"]) == 2
    kv.ensure_capacity("r0", 4)              # idempotent
    assert len(kv.tables["r0"]) == 2
    kv.audit()
    kv.free("r0")
    assert kv.audit() == {"free": kv.capacity_blocks, "used": 0, "cached": 0,
                          "seized": 0}


def test_table_rows_pads_with_null_block():
    kv = _kv()
    kv.begin("r0", [1, 2, 3, 4, 5])
    kv.ensure_capacity("r0", 4)
    rows = kv.table_rows(["r0", None])
    assert rows.shape == (2, kv.blocks_per_seq)
    assert (rows[0, :2] > NULL_BLOCK).all()  # real blocks
    assert (rows[0, 2:] == NULL_BLOCK).all()
    assert (rows[1] == NULL_BLOCK).all()


def test_ensure_capacity_rejects_overflow():
    kv = _kv(max_seq_len=8)
    kv.begin("r0", [1, 2])
    with pytest.raises(ValueError, match="max_seq_len"):
        kv.ensure_capacity("r0", 8)


def test_duplicate_begin_rejected():
    kv = _kv()
    kv.begin("r0", [1, 2])
    with pytest.raises(ValueError, match="already has a block table"):
        kv.begin("r0", [3, 4])


# ---------------------------------------------------------------------------
# prefix sharing / copy-on-write
# ---------------------------------------------------------------------------
def test_full_block_sharing_is_zero_copy_and_refcounted():
    kv = _kv(block_size=4)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9]     # 2 full blocks + tail of 1
    kv.begin("a", prompt)
    kv.ensure_capacity("a", 8)
    kv.commit_prefix("a")
    start, shared = kv.begin("b", prompt)
    assert shared == 2                       # both full blocks adopted
    assert start == 8                        # only the LAST token prefills:
    # its forward produces the first-token logits, so it is never adopted
    # the two full blocks are the SAME physical blocks, refcount 2
    assert kv.tables["b"] == kv.tables["a"][:2]
    for bid in kv.tables["a"][:2]:
        assert kv.alloc.ref(bid) == 2
    assert kv.stats["cow_copies"] == 0       # nothing needed copying
    kv.audit()
    kv.free("a")
    kv.free("b")
    kv.audit()


def test_prefix_sharing_is_per_adapter():
    kv = _kv(block_size=4)
    kv.begin("a", [1, 2, 3, 4, 5, 6, 7, 8], adapter_id=0)
    kv.ensure_capacity("a", 7)
    kv.commit_prefix("a")
    _, shared_same = kv.begin("b", [1, 2, 3, 4, 9], adapter_id=0)
    _, shared_other = kv.begin("c", [1, 2, 3, 4, 9], adapter_id=1)
    assert shared_same == 1                  # adopted the full block
    assert shared_other == 0                 # adapter-rotated k/v: no reuse
    kv.audit()


def test_cow_divergence_keeps_only_common_prefix():
    kv = _kv(block_size=4)
    kv.begin("a", [1, 2, 3, 4, 5, 6, 7])     # tail block holds [5, 6, 7]
    kv.ensure_capacity("a", 6)
    kv.commit_prefix("a")
    # b matches the full block and 2 of the 3 tail tokens, then diverges
    start, shared = kv.begin("b", [1, 2, 3, 4, 5, 6, 99])
    assert start == 6                        # 4 (full) + 2 (tail LCP)
    assert shared == 2
    assert kv.stats["shared_partial_tokens"] == 2
    assert kv.tables["b"][1] != kv.tables["a"][1]   # copied, not shared
    kv.audit()


def test_exact_block_prompt_shares_by_copy():
    # a prompt that ends exactly on a block boundary cannot adopt its
    # final full block zero-copy (the last token must prefill), but it
    # still shares all-but-one token of that block via an eager copy
    kv = _kv(block_size=4)
    kv.begin("a", [1, 2, 3, 4])
    kv.ensure_capacity("a", 3)
    kv.commit_prefix("a")
    start, shared = kv.begin("b", [1, 2, 3, 4])
    assert (start, shared) == (3, 1)
    assert kv.tables["b"][0] != kv.tables["a"][0]
    assert kv.stats["cow_copies"] == 1
    assert kv.stats["shared_partial_tokens"] == 3
    kv.audit()


def test_freed_indexed_blocks_stay_cached_then_lru_evict():
    kv = _kv(num_blocks=5, block_size=4, max_seq_len=16)   # 4 usable blocks
    kv.begin("a", [1, 2, 3, 4, 9])
    kv.ensure_capacity("a", 4)
    kv.commit_prefix("a")
    kv.free("a")
    assert kv.audit()["cached"] == 2         # indexed blocks survive free
    # a new request with the same prompt resurrects the full block from
    # the cache zero-copy (the tail token still prefills)
    start, shared = kv.begin("b", [1, 2, 3, 4, 9])
    assert (start, shared) == (4, 1)
    kv.free("b")
    # now exhaust the pool: cached blocks are evicted LRU under pressure
    kv.begin("c", [7] * 16)
    for pos in range(16):
        kv.ensure_capacity("c", pos)
    assert kv.stats["evictions"] == 2
    assert kv.audit() == {"free": 0, "used": 4, "cached": 0, "seized": 0}


def test_exhaustion_raises_when_nothing_evictable():
    kv = _kv(num_blocks=3, block_size=4, max_seq_len=16)   # 2 usable blocks
    kv.begin("a", [1] * 12)
    kv.ensure_capacity("a", 7)               # takes both blocks
    with pytest.raises(RuntimeError, match="exhausted"):
        kv.ensure_capacity("a", 8)


# ---------------------------------------------------------------------------
# property: no interleaving leaks or double-frees
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_any_interleaving_never_leaks_blocks(seed):
    """Random begin/grow/free/commit interleavings (with prompts drawn
    from a tiny vocabulary so prefix collisions are common) keep the
    audit invariants: free+used+cached partitions the pool, refcounts
    equal table entries, the index maps only to resident blocks."""
    rnd = random.Random(seed)
    kv = _kv(num_blocks=9, block_size=4, max_seq_len=24)
    live = {}                                # rid -> (prompt_len, grown_to)
    next_rid = 0
    for _ in range(60):
        ops = ["begin", "free", "grow", "commit"]
        op = rnd.choice(ops)
        if op == "begin":
            n = rnd.randint(1, 12)
            prompt = [rnd.randint(0, 3) for _ in range(n)]
            committed = sum(-(-pl // 4) + 1 for pl, _ in live.values())
            if committed + -(-n // 4) + 1 > kv.capacity_blocks:
                continue                     # the engine's admission gate
            rid = f"r{next_rid}"
            next_rid += 1
            start, _ = kv.begin(rid, prompt, adapter_id=rnd.randint(0, 1))
            assert start <= n
            live[rid] = (n, max(start - 1, -1))
        elif op == "free" and live:
            rid = rnd.choice(sorted(live))
            kv.free(rid)
            del live[rid]
        elif op == "grow" and live:
            rid = rnd.choice(sorted(live))
            pl, grown = live[rid]
            upto = min(grown + rnd.randint(1, 4), pl)   # prompt + 1 token
            kv.ensure_capacity(rid, upto)
            kv.flush()
            live[rid] = (pl, max(grown, upto))
        elif op == "commit" and live:
            rid = rnd.choice(sorted(live))
            pl, grown = live[rid]
            if grown >= pl - 1:              # only commit filled prompts
                kv.ensure_capacity(rid, pl - 1)
                kv.commit_prefix(rid)
        kv.audit()
    for rid in sorted(live):
        kv.free(rid)
    counts = kv.audit()
    assert counts["used"] == 0
    assert counts["free"] + counts["cached"] == kv.capacity_blocks
