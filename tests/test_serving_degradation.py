"""ISSUE-7 serving graceful degradation: KV block-pool pressure (chaos
``kv.seize``) must preempt + requeue instead of crashing, with ZERO token
drops -- every request still decodes exactly what the fixed-slot oracle
produces -- plus per-request deadlines, cancellation, health snapshots,
and bounded requeue backoff."""
import time

import numpy as np
import pytest

from repro.serving.kv_cache import BlockPoolExhausted
from test_serving_paged import _pooled, _prompts, _serving_model


def _engine(model, params, pool, **kw):
    from repro.serving import ServingEngine
    kw.setdefault("n_slots", 4)
    kw.setdefault("mode", "paged")
    kw.setdefault("page_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(model, params, pool, **kw)


def _reqs(cfg, n=4, lengths=None, gen=6):
    from repro.serving import Request, SamplingParams
    prompts = _prompts(cfg, lengths or [8] * n)
    return [Request(f"r{i}", prompts[i], adapter_id=i % 2,
                    sampling=SamplingParams(max_new_tokens=gen))
            for i in range(n)]


# ------------------------------------------------ preempt/requeue parity
def test_seize_preempts_requeues_and_drops_no_tokens():
    """Steal most of the block pool mid-flight: the engine preempts the
    youngest requests, requeues them (prefix-cached for a cheap retry),
    and after the pressure lifts EVERY request finishes with exactly the
    tokens the slots-mode oracle produces."""
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    reqs = _reqs(cfg, n=4, gen=6)
    oracle = _engine(model, params, pool, mode="slots").run(reqs)

    eng = _engine(model, params, pool, num_blocks=24)
    for r in reqs:
        eng.submit(r)
    results = {}
    for _ in range(2):
        for res in eng.step():
            results[res.rid] = res
    seized = eng.kv.seize(18)
    assert seized > 0
    for _ in range(6):                      # survive under pressure
        for res in eng.step():
            results[res.rid] = res
    h = eng.health()
    assert h["pool"]["seized"] == seized
    eng.kv.release_seized()
    results.update(eng.drain())

    assert eng.health()["counters"]["preemptions"] >= 1
    assert eng.health()["counters"]["retries"] >= 1
    assert any(r.retries > 0 for r in results.values())
    for i in range(4):
        np.testing.assert_array_equal(results[f"r{i}"].tokens,
                                      oracle[f"r{i}"])
    eng.kv.audit()


def test_repeated_seize_release_cycles_stay_exact():
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    reqs = _reqs(cfg, n=4, lengths=[5, 9, 8, 12], gen=5)
    oracle = _engine(model, params, pool, mode="slots").run(reqs)
    eng = _engine(model, params, pool, num_blocks=24)
    for r in reqs:
        eng.submit(r)
    results = {}
    for cycle in range(3):
        for _ in range(2):
            for res in eng.step():
                results[res.rid] = res
        eng.kv.seize(20)
        for _ in range(2):
            for res in eng.step():
                results[res.rid] = res
        eng.kv.release_seized()
    results.update(eng.drain())
    for i in range(4):
        np.testing.assert_array_equal(results[f"r{i}"].tokens,
                                      oracle[f"r{i}"])
    audit = eng.kv.audit()
    assert audit["used"] == 0 and audit["seized"] == 0


def test_admission_refused_under_seize_not_crashed():
    """A request whose worst case cannot fit RIGHT NOW (seized pool) just
    waits in the queue; one that can NEVER fit (absolute pool size) is a
    configuration error and raises."""
    from repro.serving import Request, SamplingParams
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = _engine(model, params, pool, num_blocks=12)
    # same 12-token worst case as r0: a LONGER later request would make
    # _ensure_state rebuild the pool (between flights), dropping the seize
    warm = Request("warm", [1, 2, 3], adapter_id=0,
                   sampling=SamplingParams(max_new_tokens=9))
    eng.submit(warm)
    eng.drain()                             # materialize the pool
    eng.kv.seize(9)
    eng.submit(_reqs(cfg, n=1, gen=4)[0])   # needs 3 blocks, 2 available
    assert eng.step() == [] and eng.has_work()
    assert eng.health()["pending"] == 1     # refused, not crashed
    eng.kv.release_seized()
    res = eng.drain()["r0"]
    assert res.n_generated == 4

    big = Request("huge", list(range(1, 60)), adapter_id=0,
                  sampling=SamplingParams(max_new_tokens=4))
    eng.submit(big)
    with pytest.raises(ValueError, match="alone needs"):
        eng.drain()


# --------------------------------------------------- deadlines + cancel
def test_deadline_expires_to_partial_result():
    from repro.serving import FINISH_DEADLINE, FINISH_LENGTH
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    reqs = _reqs(cfg, n=2, gen=5)
    from repro.serving import Request, SamplingParams
    doomed = Request("doomed", _prompts(cfg, [7])[0], adapter_id=0,
                     sampling=SamplingParams(max_new_tokens=5),
                     deadline_s=0.001)
    eng = _engine(model, params, pool)
    eng.submit(reqs[0])
    eng.submit(doomed)
    time.sleep(0.01)
    results = eng.drain()
    assert results["doomed"].finish_reason == FINISH_DEADLINE
    assert results["doomed"].n_generated < 5
    assert results["r0"].finish_reason == FINISH_LENGTH
    assert results["r0"].n_generated == 5
    assert eng.health()["counters"]["deadline_expired"] == 1
    eng.kv.audit()                          # expiry freed its blocks


def test_deadline_validation():
    from repro.serving import Request
    with pytest.raises(ValueError, match="deadline_s"):
        Request("r0", [1, 2], deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        Request("r0", [1, 2], deadline_s=-1.0)
    assert Request("r0", [1, 2], deadline_s=3.5).deadline_s == 3.5
    assert Request("r0", [1, 2]).deadline_s is None


def test_cancel_active_pending_and_unknown():
    from repro.serving import FINISH_CANCELLED
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    reqs = _reqs(cfg, n=3, gen=6)
    eng = _engine(model, params, pool, n_slots=2)   # r2 stays pending
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    active = eng.cancel("r0")               # mid-decode
    pending = eng.cancel("r2")              # never admitted
    assert active.finish_reason == FINISH_CANCELLED
    assert pending.finish_reason == FINISH_CANCELLED
    assert pending.n_generated == 0
    with pytest.raises(KeyError):
        eng.cancel("nope")
    with pytest.raises(KeyError):
        eng.cancel("r0")                    # already cancelled
    survivor = eng.drain()["r1"]
    oracle = _engine(model, params, pool, mode="slots").run([reqs[1]])
    np.testing.assert_array_equal(survivor.tokens, oracle["r1"])
    assert eng.health()["counters"]["cancelled"] == 2
    eng.kv.audit()


# ------------------------------------------------------- health + backoff
def test_health_snapshot_shape_and_pressure():
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = _engine(model, params, pool)
    h0 = eng.health()
    assert h0["mode"] == "paged" and h0["inflight"] == 0
    assert "pool" not in h0                 # no state materialized yet
    for r in _reqs(cfg, n=2, gen=4):
        eng.submit(r)
    eng.step()
    h1 = eng.health()
    assert set(h1) >= {"mode", "tick", "inflight", "pending", "requeued",
                       "counters"}
    assert h1["inflight"] == 2 and h1["tick"] >= 1
    pool_h = h1["pool"]
    assert pool_h["used"] > 0
    assert pool_h["capacity"] == eng.kv.capacity_blocks
    assert pool_h["committed"] >= pool_h["used"]
    seized = eng.kv.seize(4)
    assert eng.health()["pool"]["seized"] == seized
    assert eng.health()["pool"]["capacity"] == pool_h["capacity"] - seized
    eng.kv.release_seized()
    eng.drain()


def test_requeue_backoff_is_exponential_and_bounded():
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = _engine(model, params, pool, requeue_backoff=1,
                  requeue_backoff_max=4)
    req = _reqs(cfg, n=1)[0]
    eng.submit(req)
    delays = []
    for _ in range(5):
        eng._requeue_request(req)
        ready, _ = eng._requeue.pop()
        delays.append(ready - eng._tick)
    assert delays == [1, 2, 4, 4, 4]        # doubled, capped at max


def test_seize_never_steals_referenced_blocks():
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = _engine(model, params, pool)
    for r in _reqs(cfg, n=2, gen=4):
        eng.submit(r)
    eng.step()
    used_before = eng.kv.audit()["used"]
    eng.kv.seize(10 ** 6)                   # ask for everything
    audit = eng.kv.audit()
    assert audit["used"] == used_before     # in-use blocks untouched
    assert audit["free"] == 0 and audit["cached"] == 0
    with pytest.raises(BlockPoolExhausted):
        eng.kv._take_block()
    eng.kv.release_seized()
    eng.drain()
    eng.kv.audit()
