"""Checkpoint integrity tests (ISSUE-7): per-leaf checksums, corrupt-latest
fallback, stale tmp-dir sweeping, tolerant metadata, and the torn-save
property test (a writer killed at ANY point never yields a checkpoint that
both verifies and is wrong)."""
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import serialization as ser
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serialization import CheckpointCorruptError
from repro.distributed.chaos import (SaveCrashed, corrupt_checkpoint,
                                     make_save_killer)


def tree_for(step: int):
    rng = np.random.default_rng(step)
    return {"w": rng.normal(size=(8, 8)).astype(np.float32),
            "opt": {"m": rng.normal(size=(8, 8)).astype(np.float32),
                    "count": np.asarray(step, np.int32)}}


def assert_tree_equal(a, b):
    np.testing.assert_array_equal(a["w"], b["w"])
    np.testing.assert_array_equal(a["opt"]["m"], b["opt"]["m"])
    np.testing.assert_array_equal(a["opt"]["count"], b["opt"]["count"])


# ---------------------------------------------------------------- checksums
def test_checksum_detects_bitflip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, tree_for(1), metadata={"step": 1})
    assert mgr.verify(1)
    corrupt_checkpoint(str(tmp_path))
    assert not mgr.verify(1)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(1)


def test_verify_tree_returns_metadata(tmp_path):
    ser.save_tree(str(tmp_path / "ck"), tree_for(3),
                  metadata={"step": 3, "tag": "x"})
    meta = ser.verify_tree(str(tmp_path / "ck"))
    assert meta["step"] == 3 and meta["tag"] == "x"


def test_legacy_manifest_without_crc_still_loads(tmp_path):
    import msgpack
    path = str(tmp_path / "ck")
    ser.save_tree(path, tree_for(2), metadata={"step": 2})
    mpath = os.path.join(path, "manifest.msgpack")
    with open(mpath, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    for leaf in manifest["leaves"]:
        del leaf["crc"]
    with open(mpath, "wb") as f:
        f.write(msgpack.packb(manifest))
    tree, meta = ser.load_tree(path)
    assert meta["step"] == 2
    assert_tree_equal(tree, tree_for(2))


# --------------------------------------------------------- corrupt fallback
def test_restore_falls_back_to_newest_valid_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, tree_for(s), metadata={"step": s})
    corrupt_checkpoint(str(tmp_path))          # newest (step 3)
    tree, meta = mgr.restore()                 # no explicit step
    assert meta["step"] == 2
    assert_tree_equal(tree, tree_for(2))


def test_restore_raises_when_every_step_is_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    for s in (1, 2):
        mgr.save(s, tree_for(s), metadata={"step": s})
        corrupt_checkpoint(str(tmp_path), step=s)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore()


def test_explicit_step_does_not_fall_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    for s in (1, 2):
        mgr.save(s, tree_for(s), metadata={"step": s})
    corrupt_checkpoint(str(tmp_path), step=2)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(2)
    tree, meta = mgr.restore(1)
    assert meta["step"] == 1


# ---------------------------------------------------------------- tmp sweep
def test_init_sweeps_stale_tmp_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, tree_for(1), metadata={"step": 1})
    stale = tmp_path / "tmp_step_7"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"torn")
    mgr2 = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    assert mgr2.swept == 1
    assert not stale.exists()
    assert mgr2.steps() == [1]


def test_async_save_error_reraised_by_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, tree_for(1), metadata={"step": 1})
    mgr.wait()
    mgr.arm_fault(make_save_killer(2))
    mgr.save(2, tree_for(2), metadata={"step": 2})
    with pytest.raises(SaveCrashed):
        mgr.wait()
    # the torn save never became step_2; step_1 is intact
    assert mgr.latest_step() == 1
    assert mgr.verify(1)


# ----------------------------------------------------- tolerant train resume
def test_loop_tolerates_missing_data_cursor(tmp_path):
    from test_train_loop import loader_for, small_run
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.loop import run_training

    run = small_run(tmp_path / "run", steps=12)
    model = build(run)
    params = model.init(__import__("jax").random.PRNGKey(0))
    state = state_lib.create(params)
    mgr = CheckpointManager(run.train.ckpt_dir, keep=2, async_save=False)
    # a legacy/foreign checkpoint: right tree, no data_cursor in metadata
    mgr.save(5, state, metadata={"step": 5})
    msgs = []
    out = run_training(model, run, loader_for(run), manager=mgr,
                       log=msgs.append)
    assert out["last_step"] == 12
    assert any("no data_cursor" in m for m in msgs)


# -------------------------------------------------------- torn-save property
@settings(max_examples=15, deadline=None)
@given(kill_at=st.integers(0, 12))
def test_torn_save_never_yields_invalid_latest(kill_at):
    """Kill ``save_tree`` at an arbitrary fault point: whatever the
    interleaving, ``latest_step()`` + ``restore()`` always produce a
    complete checksum-valid tree (the good old step, or -- when the kill
    point lands after the manifest -- the fully-written new one)."""
    d = tempfile.mkdtemp(prefix="torn_save_")
    try:
        mgr = CheckpointManager(d, keep=5, async_save=False)
        mgr.save(1, tree_for(1), metadata={"step": 1})
        mgr.arm_fault(make_save_killer(kill_at))
        crashed = False
        try:
            mgr.save(2, tree_for(2), metadata={"step": 2})
        except SaveCrashed:
            crashed = True
        # a fresh manager = a restarted process: sweeps torn tmp dirs
        mgr2 = CheckpointManager(d, keep=5, async_save=False)
        latest = mgr2.latest_step()
        assert latest in (1, 2)
        if crashed:
            assert latest == 1, "a killed save must never publish step_2"
        tree, meta = mgr2.restore()
        assert meta["step"] == latest
        assert_tree_equal(tree, tree_for(latest))
        assert mgr2.verify(latest)
    finally:
        shutil.rmtree(d, ignore_errors=True)
