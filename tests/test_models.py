"""Model-zoo correctness: decode-with-cache == full forward, chunked SSD ==
naive recurrence, chunked attention == dense attention, MoE routing, every
family's forward/loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig, RunConfig
from repro.models import build
from repro.models import mamba2 as mamba_mod
from repro.models.attention import attention_core

KEY = jax.random.PRNGKey(0)


def tiny_dense(**kw):
    base = dict(name="tiny", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=128, rope_theta=1e4)
    base.update(kw)
    return ModelConfig(**base)


def run_cfg(cfg, adapter="oftv2", quant="none"):
    return RunConfig(model=cfg,
                     adapter=AdapterConfig(kind=adapter, block_size=16,
                                           neumann_terms=4, rank=4),
                     quant=QuantConfig(kind=quant, block_size=32))


def _decode_all(m, params, tokens, s_max):
    b, s = tokens.shape
    caches = m.make_caches(b, s_max)
    outs = []
    for t in range(s):
        batch = {"tokens": tokens[:, t:t + 1],
                 "positions": jnp.full((b, 1), t, jnp.int32),
                 "cache_index": jnp.full((b,), t, jnp.int32),
                 "caches": caches}
        logits, caches = m.decode_step(params, batch)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


# ------------------------------------------------- attention core ----------
def test_chunked_attention_equals_dense():
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    dense = attention_core(q, k, v, pos, pos, causal=True, window=0,
                           chunk=4096)
    chunked = attention_core(q, k, v, pos, pos, causal=True, window=0,
                             chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_chunked_attention_sliding_window():
    b, s, h, kv, hd = 1, 64, 2, 1, 8
    q = jax.random.normal(KEY, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    dense = attention_core(q, k, v, pos, pos, causal=True, window=16,
                           chunk=4096)
    chunked = attention_core(q, k, v, pos, pos, causal=True, window=16,
                             chunk=8)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------- dense decode == fwd -----
@pytest.mark.slow
@pytest.mark.parametrize("adapter", ["none", "oftv2", "lora"])
def test_decode_matches_forward_dense(adapter):
    cfg = tiny_dense()
    m = build(run_cfg(cfg, adapter=adapter))
    params = m.init(KEY)
    if adapter != "none":   # give adapters non-trivial values
        params["adapter"] = jax.tree_util.tree_map(
            lambda x: x + 0.05 * jax.random.normal(KEY, x.shape, x.dtype),
            params["adapter"])
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    full_logits, _, _ = m.forward(params, {"tokens": tokens})
    dec_logits = _decode_all(m, params, tokens, s_max=16)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_decode_matches_forward_swa():
    cfg = tiny_dense(sliding_window=4)
    m = build(run_cfg(cfg, adapter="none"))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    full_logits, _, _ = m.forward(params, {"tokens": tokens})
    dec_logits = _decode_all(m, params, tokens, s_max=16)  # ring cache = 4
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_continues_forward():
    cfg = tiny_dense()
    m = build(run_cfg(cfg, adapter="oftv2"))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size)
    # full forward over 10 tokens gives the reference logits at position 9
    full_logits, _, _ = m.forward(params, {"tokens": tokens})
    # prefill on first 9, then decode token 9
    logits_p, caches = m.prefill(params, {"tokens": tokens[:, :9]})
    from repro.train.serving import pad_caches
    caches = pad_caches(m, caches, s_max=16)
    batch = {"tokens": tokens[:, 9:10],
             "positions": jnp.full((2, 1), 9, jnp.int32),
             "cache_index": jnp.full((2,), 9, jnp.int32),
             "caches": caches}
    logits_d, _ = m.decode_step(params, batch)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, 9]), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, :9]), rtol=2e-3,
                               atol=2e-3)


# --------------------------------------------------------- mamba2 ----------
def test_ssd_chunked_equals_naive():
    b, s, h, p, g, n = 2, 32, 4, 8, 2, 16
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a = -jnp.exp(0.1 * jax.random.normal(k3, (h,)))
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 9), (b, s, g, n)) * 0.5
    d = jnp.ones((h,))
    y_naive, h_naive = mamba_mod.ssd_naive(x, dt, a, bm, cm, d)
    y_chunk, h_chunk = mamba_mod.ssd_chunked(x, dt, a, bm, cm, d, chunk=8)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               rtol=1e-3, atol=1e-4)


def tiny_ssm(**kw):
    base = dict(name="tiny-ssm", family="ssm", num_layers=2, d_model=64,
                num_heads=0, num_kv_heads=0, head_dim=0, d_ff=128,
                vocab_size=128, ssm_state=16, ssm_headdim=16, ssm_expand=2,
                ssm_chunk=8, use_rope=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.slow
def test_decode_matches_forward_ssm():
    cfg = tiny_ssm()
    m = build(run_cfg(cfg, adapter="oftv2"))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full_logits, _, _ = m.forward(params, {"tokens": tokens})
    dec_logits = _decode_all(m, params, tokens, s_max=16)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=3e-3, atol=3e-3)


def test_prefill_then_decode_ssm():
    cfg = tiny_ssm()
    m = build(run_cfg(cfg, adapter="none"))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 17), 0, cfg.vocab_size)
    full_logits, _, _ = m.forward(params, {"tokens": tokens})
    _, caches = m.prefill(params, {"tokens": tokens[:, :16]})
    batch = {"tokens": tokens[:, 16:17],
             "positions": jnp.full((1, 1), 16, jnp.int32),
             "cache_index": jnp.full((1,), 16, jnp.int32),
             "caches": caches}
    logits_d, _ = m.decode_step(params, batch)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full_logits[:, 16]), rtol=3e-3,
                               atol=3e-3)


# --------------------------------------------------------- hybrid ----------
def tiny_hybrid():
    # capacity_factor 4.0: no capacity drops, so teacher-forced forward ==
    # step-by-step decode exactly (capacity-dropped tokens are a train-time
    # regularizer that decode, one token at a time, never experiences)
    return ModelConfig(name="tiny-jamba", family="hybrid", num_layers=4,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=128, ssm_state=16, ssm_headdim=16,
                       ssm_expand=2, ssm_chunk=8, attn_period=4,
                       attn_offset=1, scan_block=4, num_experts=4, top_k=2,
                       moe_period=2, moe_offset=1, rope_theta=1e4,
                       capacity_factor=4.0)


@pytest.mark.slow
def test_hybrid_forward_and_decode():
    cfg = tiny_hybrid()
    m = build(run_cfg(cfg, adapter="oftv2"))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full_logits, aux, _ = m.forward(params, {"tokens": tokens})
    assert np.all(np.isfinite(np.asarray(full_logits)))
    dec_logits = _decode_all(m, params, tokens, s_max=16)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=5e-3, atol=5e-3)


# ------------------------------------------------------------ moe ----------
def test_moe_forward_loss_and_aux():
    cfg = tiny_dense(num_experts=4, top_k=2, moe_period=1, name="tiny-moe",
                     family="moe")
    m = build(run_cfg(cfg))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    loss, metrics = m.loss(params, {"tokens": tokens})
    assert np.isfinite(float(loss))
    # balanced-ish at init: aux ~= num_layers (E * sum f*p ~ 1 per layer)
    assert 0.5 * cfg.num_layers < float(metrics["aux"]) < 3 * cfg.num_layers


def test_moe_dense_residual():
    cfg = tiny_dense(num_experts=4, top_k=1, moe_period=1,
                     dense_residual=True, name="tiny-arctic", family="moe")
    m = build(run_cfg(cfg))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    logits, _, _ = m.forward(params, {"tokens": tokens})
    assert np.all(np.isfinite(np.asarray(logits)))


# -------------------------------------------------------- encoder ----------
def test_encoder_hubert_like():
    cfg = ModelConfig(name="tiny-hubert", family="encoder", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                      vocab_size=32, is_encoder=True, causal=False,
                      frontend="audio_frames", frontend_dim=24,
                      use_rope=True, rope_theta=1e4, act="gelu", glu=False)
    m = build(run_cfg(cfg))
    params = m.init(KEY)
    frames = jax.random.normal(KEY, (2, 16, 24))
    labels = jax.random.randint(KEY, (2, 16), 0, 32)
    loss, _ = m.loss(params, {"frames": frames, "labels": labels})
    assert np.isfinite(float(loss))
    # bidirectional: flipping future frames must change position-0 logits
    logits, _, _ = m.forward(params, {"frames": frames, "labels": labels})
    frames2 = frames.at[:, -1].set(0.0)
    logits2, _, _ = m.forward(params, {"frames": frames2, "labels": labels})
    assert float(jnp.max(jnp.abs(logits[:, 0] - logits2[:, 0]))) > 1e-6


# ------------------------------------------------------------ vlm ----------
def test_vlm_forward_loss_decode():
    cfg = ModelConfig(name="tiny-vlm", family="vlm", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, frontend="vision_patches",
                      frontend_dim=24, num_frontend_tokens=4, rope_theta=1e4)
    m = build(run_cfg(cfg))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    patches = jax.random.normal(KEY, (2, 4, 24))
    loss, _ = m.loss(params, {"tokens": tokens, "patches": patches})
    assert np.isfinite(float(loss))
    logits, _, _ = m.forward(params, {"tokens": tokens, "patches": patches})
    assert logits.shape == (2, 16, cfg.padded_vocab)


# --------------------------------------------- quantized (QOFT) model ------
@pytest.mark.slow
@pytest.mark.parametrize("quant", ["nf4", "int8"])
def test_quantized_model_forward(quant):
    cfg = tiny_dense()
    m = build(run_cfg(cfg, adapter="oftv2", quant=quant))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    loss, _ = m.loss(params, {"tokens": tokens})
    assert np.isfinite(float(loss))
    # adapter grads exist and are finite
    g = jax.grad(lambda a: m.loss({"base": params["base"], "adapter": a},
                                  {"tokens": tokens})[0])(params["adapter"])
    leaves = jax.tree_util.tree_leaves(g)
    assert leaves and all(np.all(np.isfinite(np.asarray(l))) for l in leaves)


def test_remat_matches_no_remat():
    cfg = tiny_dense()
    m = build(run_cfg(cfg))
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    l1, _ = m.loss(params, {"tokens": tokens}, remat=False)
    l2, _ = m.loss(params, {"tokens": tokens}, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
