"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.configs import ASSIGNED, REGISTRY, cells, get_config, get_smoke
from repro.models import build
from repro.train import state as state_lib
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg: ModelConfig, b=2, s=16, key=KEY):
    if cfg.frontend == "audio_frames":
        return {"frames": jax.random.normal(key, (b, s, cfg.frontend_dim)),
                "labels": jax.random.randint(key, (b, s), 0,
                                             cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        n = cfg.num_frontend_tokens
        return {"tokens": jax.random.randint(key, (b, s - n), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(key, (b, n, cfg.frontend_dim))}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}


# the heavy smoke configs (hybrid scan / big MoE) dominate suite runtime;
# the fast CI tier keeps the cheap archs for coverage
_SLOW_ARCHS = {"jamba-v0.1-52b", "granite-8b", "yi-34b"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
             else a for a in REGISTRY])
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=4,
                                          targets=("q", "k", "v", "o",
                                                   "gate", "up", "down",
                                                   "in_proj", "out_proj")),
                    train=TrainConfig(learning_rate=1e-3, steps=10,
                                      warmup_steps=0))
    model = build(run)
    params = model.init(KEY)
    batch = _batch_for(cfg)
    logits, aux, _ = model.forward(params, batch)
    s_total = 16
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    st = state_lib.create(model.init(KEY))
    st2, metrics = make_train_step(model, run)(st, batch)
    assert np.isfinite(float(metrics["loss"]))
    # adapter actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(st.adapter),
        jax.tree_util.tree_leaves(st2.adapter)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if REGISTRY[a].FAMILY != "encoder"])
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    run = RunConfig(model=cfg, adapter=AdapterConfig(kind="none"))
    model = build(run)
    params = model.init(KEY)
    caches = model.make_caches(2, 16)
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32),
             "positions": jnp.zeros((2, 1), jnp.int32),
             "cache_index": jnp.zeros((2,), jnp.int32),
             "caches": caches}
    logits, new_caches = model.decode_step(params, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


def test_full_configs_build_defs_without_alloc():
    """Full configs: abstract params only (no 405B allocation!)."""
    from repro.config.base import ParallelConfig
    for arch in ASSIGNED:
        cfg = get_config(arch).with_mesh_padding(16)
        pcfg = ParallelConfig(mesh_shape=(16, 16),
                              mesh_axes=("data", "model"))
        run = RunConfig(model=cfg, parallel=pcfg,
                        adapter=AdapterConfig(kind="oftv2", block_size=32))
        model = build(run)
        ap = model.abstract_params()
        leaves = jax.tree_util.tree_leaves(
            ap, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        assert leaves, arch
        counts = model.param_counts()
        assert counts["base"] > 1e6, arch
        assert 0 < counts["adapter"] < counts["base"] * 0.05, arch


def test_param_count_matches_analytic():
    """spec-tree count == ModelConfig.param_count analytic formula (dense)."""
    cfg = get_config("granite-8b")
    run = RunConfig(model=cfg, adapter=AdapterConfig(kind="none"))
    model = build(run)
    got = model.param_counts()["base"]
    want = cfg.param_count()
    assert abs(got - want) / want < 0.01, (got, want)


def test_cell_matrix_accounting():
    """40 nominal cells; skips exactly as documented in DESIGN.md §5."""
    all_cells = cells()
    assert len(all_cells) == 40
    skipped = [(a, s) for a, s, r in all_cells if r]
    runnable = [(a, s) for a, s, r in all_cells if not r]
    assert len(runnable) == 32, skipped
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    assert ("granite-8b", "long_500k") in skipped
    assert ("mixtral-8x22b", "long_500k") in runnable
    assert ("jamba-v0.1-52b", "long_500k") in runnable
    assert ("mamba2-370m", "long_500k") in runnable


# ------------------------------------------------ paper fidelity ----------
def test_paper_param_counts_llama2_7b():
    """Table 4 fidelity: Llama-2-7B all-linear adaptation.
    LoRA r=16 -> 39.98M; OFTv2 b=32 -> 17.65M."""
    from repro.configs.paper_models import llama2_7b
    from repro.core.adapter import adapter_param_count
    cfg = llama2_7b()
    d, ff = cfg.d_model, cfg.d_ff
    shapes = {"q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
              "gate": (d, ff), "up": (d, ff), "down": (ff, d)}
    for kind, expected in [("lora", 39_976_960), ("oftv2", 17_645_568)]:
        acfg = AdapterConfig(kind=kind, rank=16, block_size=32)
        per_layer = sum(adapter_param_count(n, di, do, acfg)
                        for n, (di, do) in shapes.items())
        total = per_layer * cfg.num_layers
        # paper reports 39.98M / 17.65M
        assert abs(total - expected) / expected < 0.005, (kind, total)


def test_adapter_tree_count_matches_helper():
    """Model-built adapter tree == closed-form accounting."""
    from repro.core.adapter import adapter_param_count
    cfg = get_smoke("granite-8b")
    acfg = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4)
    run = RunConfig(model=cfg, adapter=acfg)
    model = build(run)
    d, ff, h, kv, hd = (cfg.d_model, cfg.d_ff, cfg.padded_heads,
                        cfg.num_kv_heads, cfg.head_dim)
    shapes = {"q": (d, h * hd), "k": (d, kv * hd), "v": (d, kv * hd),
              "o": (h * hd, d), "gate": (d, ff), "up": (d, ff),
              "down": (ff, d)}
    want = cfg.num_layers * sum(adapter_param_count(n, di, do, acfg)
                                for n, (di, do) in shapes.items())
    assert model.param_counts()["adapter"] == want
