"""End-to-end behaviour tests for the paper's system: the full
finetune -> checkpoint -> resume -> merge -> serve pipeline on one config,
plus the public CLI entrypoints."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# every test here trains/serves a real (tiny) model end-to-end
pytestmark = pytest.mark.slow

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.models import build
from repro.train.loop import run_training
from repro.train.serving import generate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_pipeline_qoft(tmp_path):
    """QOFT lifecycle: NF4 base + OFTv2 adapters, train, resume, serve."""
    cfg = ModelConfig(name="sys", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=128,
                      rope_theta=1e4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5),
        quant=QuantConfig(kind="nf4", block_size=32),
        train=TrainConfig(global_batch=8, seq_len=32, steps=24,
                          learning_rate=8e-3, warmup_steps=4,
                          ckpt_every=12, ckpt_keep=2, log_every=0,
                          ckpt_dir=str(tmp_path)))
    model = build(run)
    loader = ShardedLoader(SyntheticSpec(vocab_size=128, seq_len=32,
                                         noise=0.05), global_batch=8, seed=0)
    out = run_training(model, run, loader, log=lambda s: None)
    assert out["losses"][-1] < out["losses"][0]

    # resume is a no-op when already complete; state round-trips
    out2 = run_training(model, run, loader, log=lambda s: None)
    assert out2["last_step"] == 24

    # batched serving with the trained adapter
    params = {"base": out["state"].base, "adapter": out["state"].adapter}
    gen = generate(model, params, jnp.zeros((2, 4), jnp.int32), steps=4)
    assert gen.shape == (2, 8)
    assert np.all(np.asarray(gen) < cfg.vocab_size)


def test_train_cli_smoke():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "mamba2-370m", "--smoke", "--steps", "6", "--batch", "4",
         "--seq", "32", "--ckpt-dir", "/tmp/repro_cli_test"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "final loss" in out.stdout


def test_serve_cli_smoke():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "granite-8b", "--smoke", "--batch", "2", "--prompt-len", "8",
         "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "tok/s" in out.stdout
