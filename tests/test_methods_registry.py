"""Registry conformance suite (ISSUE-4): every method registered in
``repro.methods`` inherits its test matrix for FREE -- init/param_count
round-trips, identity-at-init, merge-vs-apply agreement, fused==unfused==
oracle when a fused forward is declared, uniform PRNG-key threading, and
loud failures for missing capabilities.  A future method (BOFT, Givens,
principal-subspace, ...) gets all of this by calling ``register``.

Also pins the satellites: the empty-qstate ``fusion_mode`` fix, the
README capability-matrix sync, the no-string-dispatch grep gate, and the
HOFT end-to-end path (trainable via AdapterConfig(kind="hoft"), fused
kernel vs jnp oracle on odd/misaligned shapes, explicit
NotImplementedError where capabilities are absent).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import methods
from repro.config.base import (AdapterConfig, ModelConfig, ParallelConfig,
                               QuantConfig, RunConfig, TrainConfig)
from repro.core import adapter as ad
from repro.kernels import ops as kops
from repro.kernels import ref as kref

# misaligned d_out on purpose; d_in must satisfy the STRICTEST registered
# validator (BOFT: a power-of-two multiple of the block size) so the
# conformance sweep covers every method with one shape
D_IN, D_OUT = 64, 33
PARAM_KINDS = [k for k in methods.available() if methods.get(k).has_params]


def _acfg(kind: str, fused: bool = False) -> AdapterConfig:
    return AdapterConfig(kind=kind, block_size=16, neumann_terms=4, rank=4,
                         reflections=6, alpha=8.0, fuse_linear=fused)


def _perturb(tree, key, scale=0.05):
    """Generic 'trained-ish' params: every leaf nudged off init."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    out = [leaf + scale * jax.random.normal(jax.random.fold_in(key, i),
                                            leaf.shape, leaf.dtype)
           for i, leaf in enumerate(flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_count(tree) -> int:
    return sum(int(np.prod(leaf.shape))
               for leaf in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------- registry --
def test_unknown_kind_fails_loudly():
    with pytest.raises(ValueError, match="unknown adapter kind"):
        methods.get("principal-subspace")
    with pytest.raises(ValueError, match="registered"):
        methods.get("principal-subspace")  # message lists what IS registered
    # the built-ins are present; a newly registered method must NOT break
    # this (the suite picks it up from the registry automatically)
    assert set(PARAM_KINDS) >= {"boft", "goft", "hoft", "lora", "oftv1",
                                "oftv2"}


def test_reregistering_a_kind_is_an_error():
    class Dupe(methods.AdapterMethod):
        kind = "oftv2"

    with pytest.raises(ValueError, match="already registered"):
        methods.register(Dupe)


# ----------------------------------------------- per-method conformance ----
@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_init_param_count_roundtrip(kind):
    """init / param_count / param_defs agree on the same layout."""
    from repro.models import spec as spec_mod
    from repro.models.linears import adapter_defs
    acfg = _acfg(kind)
    params = ad.adapter_init(jax.random.PRNGKey(0), "q", D_IN, D_OUT, acfg)
    want = ad.adapter_param_count("q", D_IN, D_OUT, acfg)
    assert _leaf_count(params) == want
    defs = adapter_defs("q", D_IN, D_OUT, acfg)
    assert spec_mod.count_tree(defs) == want
    built = spec_mod.init_tree(jax.random.PRNGKey(1), defs)
    assert (jax.tree_util.tree_structure(built)
            == jax.tree_util.tree_structure(params))
    # untargeted linears get nothing
    assert ad.adapter_init(jax.random.PRNGKey(0), "zz", D_IN, D_OUT,
                           acfg) is None
    assert ad.adapter_param_count("zz", D_IN, D_OUT, acfg) == 0


@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_key_threading_uniform(kind):
    """One init signature for every method: stochastic inits consume the
    key (different seed => different params), deterministic ones ignore it
    -- and the registry flag tells the truth either way."""
    acfg = _acfg(kind)
    a = ad.adapter_init(jax.random.PRNGKey(0), "q", D_IN, D_OUT, acfg)
    b = ad.adapter_init(jax.random.PRNGKey(1), "q", D_IN, D_OUT, acfg)
    differs = any(not np.array_equal(np.asarray(x), np.asarray(y))
                  for x, y in zip(jax.tree_util.tree_leaves(a),
                                  jax.tree_util.tree_leaves(b)))
    assert differs == methods.get(kind).stochastic_init


@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_identity_at_init(kind):
    """Finetuning starts at the pretrained model for EVERY method (OFT:
    R=I from zero skew; LoRA: B=0; HOFT: paired reflections cancel)."""
    acfg = _acfg(kind)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 9, D_IN))
    w = jax.random.normal(jax.random.fold_in(key, 1), (D_IN, D_OUT)) / 8.0
    adp = ad.adapter_init(key, "q", D_IN, D_OUT, acfg)
    y = ad.adapted_linear(x, {"w": w}, adp, acfg, QuantConfig())
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_merge_matches_apply(kind):
    """Deployment contract: x @ merge(w) == runtime adapted forward, for
    'trained' (perturbed) params."""
    method = methods.get(kind)
    if not method.supports_merge:
        pytest.skip(f"{kind} declares no merge")
    acfg = _acfg(kind)
    key = jax.random.PRNGKey(4)
    adp = _perturb(ad.adapter_init(key, "q", D_IN, D_OUT, acfg),
                   jax.random.fold_in(key, 1))
    x = jax.random.normal(key, (5, D_IN))
    w = jax.random.normal(jax.random.fold_in(key, 2), (D_IN, D_OUT)) / 8.0
    y_runtime = ad.adapted_linear(x, {"w": w}, adp, acfg, QuantConfig())
    y_merged = x @ ad.merge_adapter(w, adp, acfg)
    np.testing.assert_allclose(np.asarray(y_runtime), np.asarray(y_merged),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_requant_report_hook(kind):
    """The §4 requantization report works through the registry hook for
    every method with merge."""
    from repro.core import merging
    if not methods.get(kind).supports_merge:
        pytest.skip(f"{kind} declares no merge")
    acfg = _acfg(kind)
    key = jax.random.PRNGKey(5)
    adp = _perturb(ad.adapter_init(key, "q", 64, 64, acfg),
                   jax.random.fold_in(key, 1), scale=0.02)
    w = 0.02 * jax.random.normal(key, (64, 64))
    rep = merging.requantization_report(
        w, adp, acfg, QuantConfig(kind="nf4", block_size=32,
                                  double_quant=False))
    assert set(rep) >= {"column_norm_drift", "dynamic_range_shift",
                        "requant_rel_fro"}
    assert all(np.isfinite(v) for v in rep.values())


@pytest.mark.kernels
@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_fused_matches_unfused_when_declared(kind):
    """supports_fused_forward methods: fuse_linear=True must be numerically
    the unfused path (odd token counts / misaligned dims included) AND
    differentiable; methods without the capability must report 'unfused'."""
    method = methods.get(kind)
    acfg_u, acfg_f = _acfg(kind, False), _acfg(kind, True)
    qcfg = QuantConfig()
    if not method.supports_fused_forward:
        assert ad.fusion_mode(acfg_f, qcfg, ("w",)) == "unfused"
        return
    assert ad.fusion_mode(acfg_f, qcfg, ("w",)) != "unfused"
    key = jax.random.PRNGKey(6)
    adp = _perturb(ad.adapter_init(key, "q", D_IN, D_OUT, acfg_u),
                   jax.random.fold_in(key, 1))
    for lead in [(1,), (7,), (2, 9)]:
        x = jax.random.normal(jax.random.fold_in(key, len(lead)),
                              lead + (D_IN,))
        w = jax.random.normal(jax.random.fold_in(key, 9),
                              (D_IN, D_OUT)) / 8.0
        y_u = ad.adapted_linear(x, {"w": w}, adp, acfg_u, qcfg)
        y_f = ad.adapted_linear(x, {"w": w}, adp, acfg_f, qcfg)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u),
                                   rtol=1e-4, atol=1e-4)

    def loss(a, fused):
        cfg = acfg_f if fused else acfg_u
        return jnp.sum(ad.adapted_linear(x, {"w": w}, a, cfg, qcfg) ** 2)

    g_u = jax.grad(loss)(adp, False)
    g_f = jax.grad(loss)(adp, True)
    for gu, gf in zip(jax.tree_util.tree_leaves(g_u),
                      jax.tree_util.tree_leaves(g_f)):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gu),
                                   rtol=1e-3, atol=1e-3)


# ------------------------------------------------ satellite: fusion_mode ----
def test_fusion_mode_empty_qstate_is_not_qoft():
    """The NF4 predicate is explicit: a genuinely empty (or raw-``w``)
    qstate under an nf4 QuantConfig must NOT route to the qoft_fused
    kernel (it has no codes to read) -- both sides tested."""
    acfg = AdapterConfig(kind="oftv2", block_size=16, fuse_linear=True)
    nf4_q = QuantConfig(kind="nf4", block_size=32)
    assert ad.fusion_mode(acfg, nf4_q, ()) == "oftv2_fused"
    assert ad.fusion_mode(acfg, nf4_q) == "oftv2_fused"
    assert ad.fusion_mode(acfg, nf4_q, ("w",)) == "oftv2_fused"
    assert ad.fusion_mode(acfg, nf4_q,
                          ("nf4_codes", "absmax")) == "qoft_fused"
    assert ad.fusion_mode(acfg, QuantConfig(), ("w",)) == "oftv2_fused"
    assert ad.fusion_mode(dataclasses.replace(acfg, fuse_linear=False),
                          nf4_q, ("nf4_codes",)) == "unfused"


# --------------------------------------------------- loud capability gaps --
def test_missing_capabilities_raise_explicitly():
    for kind in PARAM_KINDS:
        method = methods.get(kind)
        if method.supports_multi_tenant:
            continue
        with pytest.raises(NotImplementedError, match="multi-tenant"):
            method.stack_for_serving([{}], _acfg(kind))
        with pytest.raises(NotImplementedError, match="multi-tenant"):
            method.route_multi(jnp.zeros((2, 4)), {}, {}, jnp.zeros((2,),
                               jnp.int32), _acfg(kind), QuantConfig())


def test_pool_rejects_non_multi_tenant_method_at_registration():
    """ISSUE-4 acceptance: HOFT (fused config, but no stacking capability)
    fails at pool-construction time with an explicit NotImplementedError,
    not an implicit fall-through."""
    from repro.models import build
    from repro.serving import AdapterPool
    cfg = ModelConfig(name="t", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="hoft", reflections=4,
                                          fuse_linear=True))
    with pytest.raises(NotImplementedError, match="multi-tenant"):
        AdapterPool(build(run))


# ---------------------------------------------- mesh-sharding capability ---
@pytest.mark.parametrize("kind", PARAM_KINDS)
def test_sharding_capability_sweep(kind):
    """ISSUE-5 conformance, inherited by every registered method: a method
    advertising the ``shards`` capability is auto-swept for sharded ==
    unsharded parity (1x1 mesh in-process -- the structural path: mesh
    validation, spec resolution, shard_map'd kernels; 8-device numeric
    parity lives in tests/test_sharded_fused.py), and a method WITHOUT it
    raises loudly at mesh setup -- like the HOFT pool case, a config-time
    error, not a silent fall-through."""
    from repro.distributed.sharding import make_constrain, make_shard_context
    from repro.models import build
    from repro.models.spec import rules_variant

    method = methods.get(kind)
    pcfg = ParallelConfig(mesh_shape=(1, 1), mesh_axes=("data", "model"))
    cfg = ModelConfig(name=f"shard-{kind}", num_layers=1, d_model=64,
                      num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    run = RunConfig(model=cfg, parallel=pcfg,
                    adapter=_acfg(kind,
                                  fused=method.supports_fused_forward))
    mesh = jax.make_mesh(pcfg.mesh_shape, pcfg.mesh_axes)
    rules = rules_variant(pcfg, "fused_tp")
    if not method.supports_sharding:
        with pytest.raises(NotImplementedError, match="shards"):
            make_shard_context(mesh, rules, run)
        return
    ctx = make_shard_context(mesh, rules, run)
    assert ctx is not None and make_shard_context(None, rules, run) is None

    model_ref = build(run)
    key = jax.random.PRNGKey(0)
    init = model_ref.init(key)
    params = {"base": init["base"],
              "adapter": _perturb(init["adapter"],
                                  jax.random.fold_in(key, 1))}
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 2),
                                          (2, 8), 0, 64)}
    logits_ref, _, _ = model_ref.forward(params, batch)
    model_sh = build(run, constrain=make_constrain(rules, mesh), shard=ctx)
    with mesh:
        logits, _, _ = jax.jit(
            lambda p, b: model_sh.forward(p, b))(params, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-4, atol=2e-4)

    def loss(m):
        return lambda a, b, bt: m.loss({"base": b, "adapter": a}, bt)[0]

    g_ref = jax.grad(loss(model_ref))(params["adapter"], params["base"],
                                      batch)
    with mesh:
        g_sh = jax.jit(jax.grad(loss(model_sh)))(params["adapter"],
                                                 params["base"], batch)
    for gu, gf in zip(jax.tree_util.tree_leaves(g_ref),
                      jax.tree_util.tree_leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gu),
                                   rtol=2e-3, atol=2e-4)


def test_shards_capability_flag_tells_the_truth():
    """The matrix column is generated from supports_sharding; methods that
    set it must implement check_sharding + shard_forward (the conformance
    sweep exercises them), and the base hooks raise with the capability
    name for everyone else."""
    for kind in PARAM_KINDS:
        method = methods.get(kind)
        if method.supports_sharding:
            continue
        with pytest.raises(NotImplementedError, match="mesh-sharded"):
            method.check_sharding("q", 64, 64, _acfg(kind), QuantConfig(),
                                  k_shards=2, n_shards=1)
        with pytest.raises(NotImplementedError, match="mesh-sharded"):
            method.shard_forward(jnp.zeros((2, 4)), {}, {}, _acfg(kind),
                                 QuantConfig(), None)


# -------------------------------------------------- HOFT kernel vs oracle --
@pytest.mark.kernels
@pytest.mark.parametrize("t,k,n,m", [
    (8, 64, 32, 4),
    (7, 48, 33, 6),      # odd tokens, misaligned n
    (1, 32, 16, 2),      # decode-step shape
    (30, 96, 40, 8),     # token count off the tile grid
    (5, 48, 33, 2),
])
def test_hoft_fused_kernel_matches_oracle(t, k, n, m):
    key = jax.random.PRNGKey(t * 1000 + k + n + m)
    kx, kv, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (t, k))
    v = jax.random.normal(kv, (m, k))
    w = jax.random.normal(kw, (k, n)) / np.sqrt(k)
    got = kops.hoft_linear_fused(x, v, w)
    want = kref.hoft_linear_ref(x, v, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_hoft_reflections_must_be_even():
    acfg = AdapterConfig(kind="hoft", reflections=5)
    with pytest.raises(ValueError, match="even"):
        ad.adapter_init(jax.random.PRNGKey(0), "q", 32, 32, acfg)
    with pytest.raises(ValueError, match="even"):
        ad.adapter_param_count("q", 32, 32, acfg)


def test_hoft_orthogonality_preserves_column_norms():
    """Householder chains are exactly orthogonal -- the paper's merge/
    requantization argument extends to HOFT with no Neumann truncation."""
    from repro.core import merging
    key = jax.random.PRNGKey(11)
    acfg = AdapterConfig(kind="hoft", reflections=6)
    adp = _perturb(ad.adapter_init(key, "q", 64, 48, acfg),
                   jax.random.fold_in(key, 1), scale=0.3)
    w = jax.random.normal(key, (64, 48)) / 8.0
    merged = ad.merge_adapter(w, adp, acfg)
    assert float(merging.column_norm_drift(w, merged)) < 1e-5


# --------------------------------------------------- HOFT end-to-end model --
def _hoft_run(fused: bool = False, kind: str = "hoft") -> RunConfig:
    cfg = ModelConfig(name="hoft-e2e", num_layers=1, d_model=64, num_heads=2,
                      num_kv_heads=1, d_ff=128, vocab_size=64,
                      rope_theta=1e4)
    return RunConfig(model=cfg,
                     adapter=AdapterConfig(kind=kind, reflections=4,
                                           fuse_linear=fused),
                     train=TrainConfig(global_batch=2, seq_len=8, steps=3,
                                       learning_rate=5e-3, warmup_steps=1,
                                       ckpt_every=0, log_every=0))


def test_hoft_model_trains_end_to_end():
    """AdapterConfig(kind='hoft') builds, starts at the pretrained model
    (logits == no-adapter model), takes nonzero adapter grads, and steps."""
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step

    run = _hoft_run()
    model = build(run)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    # paired identity init survives the model init path
    leaves = jax.tree_util.tree_leaves(params["adapter"])
    assert leaves and all(l.shape[-2] == 4 for l in leaves)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, 64)}
    logits, _, _ = model.forward(params, batch)
    model_none = build(_hoft_run(kind="none"))
    params_none = model_none.init(key)
    logits_none, _, _ = model_none.forward(
        {"base": params_none["base"], "adapter": {}}, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_none),
                               rtol=1e-4, atol=1e-4)

    state = state_lib.create(params)
    step = jax.jit(make_train_step(model, run))
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(state.adapter),
                                jax.tree_util.tree_leaves(s2.adapter)))
    assert moved, "adapter params did not move under training"


@pytest.mark.kernels
def test_hoft_model_fused_matches_unfused():
    from repro.models import build
    key = jax.random.PRNGKey(1)
    model_u = build(_hoft_run(fused=False))
    model_f = build(_hoft_run(fused=True))
    params = model_u.init(key)
    params = {"base": params["base"],
              "adapter": _perturb(params["adapter"],
                                  jax.random.fold_in(key, 1), scale=0.05)}
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, 64)}
    lu, _, _ = model_u.forward(params, batch)
    lf, _, _ = model_f.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lu), rtol=2e-4,
                               atol=2e-4)


# ------------------------------------------------------- docs + CI gates ----
def test_capability_matrix_is_embedded_in_readme():
    """The README matrix is GENERATED (repro.methods.capability_matrix_md);
    this keeps the embed from rotting."""
    readme = Path(__file__).resolve().parents[1] / "README.md"
    assert methods.capability_matrix_md() in readme.read_text(), (
        "README capability matrix is stale -- regenerate with "
        "`PYTHONPATH=src python -m repro.methods` and paste")


def test_no_adapter_string_dispatch_outside_methods():
    """Tier-1 twin of the benchmarks/check_dispatch.py CI gate."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.check_dispatch import check
    assert check() == 0
