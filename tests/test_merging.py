"""Merge-back properties (paper §4, "QOFT vs QLoRA"): orthogonal merges
preserve per-column norms exactly, LoRA's range shift obeys its worst-case
bound, and the merged R@W forward equals the unmerged fused forward --
the claims repro.core.merging quantifies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import AdapterConfig, QuantConfig
from repro.core import adapter as ad
from repro.core import merging, skew
from repro.core.adapter import merge_adapter
from repro.core.lora import lora_init


def _oft_setup(d_in=64, d_out=48, b=16, neumann_terms=0, seed=0,
               scale=0.1):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d_in, d_out)) / np.sqrt(d_in)
    adp = {"q_packed": skew.random_skew(key, (d_in // b,), b, scale=scale)}
    acfg = AdapterConfig(kind="oftv2", block_size=b,
                         neumann_terms=neumann_terms)
    return w, adp, acfg


def test_oft_merge_column_norm_drift_is_zero():
    """Exact Cayley (neumann_terms=0) gives a truly orthogonal R, so the
    merged R@W preserves every column l2 norm to float precision."""
    w, adp, acfg = _oft_setup(neumann_terms=0)
    merged = merge_adapter(w, adp, acfg)
    assert float(merging.column_norm_drift(w, merged)) < 1e-5
    # truncated Neumann: approximately orthogonal, drift O(||Q||^{k+1})
    # (small skew so the k=5 truncation term is below the assertion)
    w5, adp5, acfg5 = _oft_setup(neumann_terms=5, scale=0.02)
    assert float(merging.column_norm_drift(w5, merge_adapter(w5, adp5,
                                                             acfg5))) < 1e-3


def test_lora_worstcase_range_shift_bound_holds():
    """|max|W+AB| - max|W|| <= ||(alpha/r) A@B||_inf (triangle inequality) --
    the paper's requantization argument against merged LoRA."""
    key = jax.random.PRNGKey(1)
    d_in, d_out, rank = 64, 48, 8
    w = jax.random.normal(key, (d_in, d_out)) / np.sqrt(d_in)
    acfg = AdapterConfig(kind="lora", rank=rank, alpha=16.0)
    adp = lora_init(jax.random.fold_in(key, 1), d_in, d_out, rank)
    # zero-init B gives a zero delta; perturb so the bound is non-trivial
    adp["lora_b"] = 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                            adp["lora_b"].shape)
    merged = merge_adapter(w, adp, acfg)
    shift = float(merging.dynamic_range_shift(w, merged))
    bound = float(merging.lora_worstcase_range_shift(adp, acfg))
    assert bound > 0
    assert shift <= bound + 1e-6
    # and OFT's shift is small where LoRA's bound is the worst case
    wo, adpo, acfgo = _oft_setup(neumann_terms=0, seed=2)
    assert float(merging.dynamic_range_shift(
        wo, merge_adapter(wo, adpo, acfgo))) <= bound + 1e-6


@pytest.mark.parametrize("fuse", [False, True])
def test_merged_forward_equals_unmerged_fused_forward(fuse):
    """x @ (R_bd @ W) == fused (x @ R_bd) @ W: deployment-time merge and
    serving-time unmerged kernels are the same function."""
    w, adp, acfg = _oft_setup(neumann_terms=5)
    acfg = AdapterConfig(kind="oftv2", block_size=acfg.block_size,
                         neumann_terms=5, fuse_linear=fuse)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 7, 64))
    merged = merge_adapter(w, adp, acfg)
    y_merged = x @ merged
    y_unmerged = ad.adapted_linear(x, {"w": w}, adp, acfg,
                                   QuantConfig(kind="none"))
    np.testing.assert_allclose(np.asarray(y_unmerged), np.asarray(y_merged),
                               rtol=1e-4, atol=1e-5)


def test_requantization_report_sane():
    """End-to-end report: merge -> NF4 requantize -> measure. OFT keeps the
    column norms; the requant error is bounded by the quant step."""
    w, adp, acfg = _oft_setup(d_in=128, d_out=64, b=16, neumann_terms=0)
    qcfg = QuantConfig(kind="nf4", block_size=32, double_quant=False)
    rep = merging.requantization_report(w, adp, acfg, qcfg)
    assert set(rep) == {"column_norm_drift", "dynamic_range_shift",
                       "requant_max_err", "requant_rel_fro"}
    assert rep["column_norm_drift"] < 1e-5
    assert np.isfinite(rep["requant_max_err"])
    assert 0 < rep["requant_rel_fro"] < 0.2
