"""ISSUE-8 telemetry correctness: histogram bucket math against a numpy
oracle, span nesting/export round-trips, the enable->disable->enable
no-leak property, jaxpr identity with collectors on vs off, and the
instrumented layers' registry views (engine health, chaos counters,
kernel launch hooks, /metrics HTTP)."""
import json
import urllib.request
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.obs.metrics import LATENCY_BUCKETS, Registry
from repro.obs.trace import SPAN_FIELDS, Tracer


@pytest.fixture(autouse=True)
def _obs_enabled():
    """Every test starts (and leaves) the global collectors enabled --
    the repo default -- no matter how it toggles them internally."""
    obs.enable()
    yield
    obs.enable()


# ---------------------------------------------------------------------------
# histogram bucket math vs a numpy oracle
# ---------------------------------------------------------------------------
def test_histogram_buckets_match_numpy_oracle():
    reg = Registry()
    hist = reg.histogram("t/h", buckets=LATENCY_BUCKETS)
    rng = np.random.default_rng(0)
    # span below the first edge, across all finite buckets, and overflow
    vals = np.concatenate([
        rng.uniform(0.0, LATENCY_BUCKETS[-1] * 1.2, size=500),
        np.asarray(LATENCY_BUCKETS),          # exactly-on-edge values
        np.asarray([0.0, 1e-9, 1e6]),
    ])
    for v in vals:
        hist.observe(float(v))

    # Prometheus le semantics: counts[i] counts v <= edges[i]; searchsorted
    # side="left" gives the first edge >= v, i.e. the same bucket.
    oracle = np.zeros(len(LATENCY_BUCKETS) + 1, dtype=int)
    idx = np.searchsorted(np.asarray(LATENCY_BUCKETS), vals, side="left")
    for i in idx:
        oracle[i] += 1

    child = hist.labels()
    assert child.counts == oracle.tolist()
    assert child.count == len(vals)
    assert child.sum == pytest.approx(float(vals.sum()))

    # exposition emits CUMULATIVE bucket counts ending in the total
    expo = reg.exposition()
    cum = np.cumsum(oracle)
    for edge, c in zip(LATENCY_BUCKETS, cum):
        assert f'le="{edge:g}"}} {c}' in expo
    assert f'le="+Inf"}} {len(vals)}' in expo


@settings(max_examples=25, deadline=None)
@given(q=st.floats(0.0, 1.0))
def test_histogram_quantile_within_buckets(q):
    reg = Registry()
    hist = reg.histogram("t/q", buckets=(1.0, 2.0, 4.0))
    assert hist.quantile(q) == 0.0                  # empty histogram
    for v in (0.5, 1.5, 1.7, 3.0, 9.0):
        hist.observe(v)
    est = hist.quantile(q)
    assert 0.0 <= est <= 4.0                        # clamped to last edge
    assert hist.quantile(1.0) >= hist.quantile(q) >= hist.quantile(0.0)


def test_histogram_quantile_interpolates():
    reg = Registry()
    hist = reg.histogram("t/qi", buckets=(1.0, 2.0))
    for _ in range(100):
        hist.observe(1.5)
    # all mass in (1, 2]: the median interpolates inside that bucket
    assert 1.0 < hist.quantile(0.5) <= 2.0


def test_bad_bucket_edges_rejected():
    reg = Registry()
    with pytest.raises(ValueError):
        reg.histogram("t/bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        reg.histogram("t/bad2", buckets=(1.0, 1.0, 2.0))
    # empty buckets fall back to the default latency edges
    assert reg.histogram("t/ok", buckets=()).buckets == LATENCY_BUCKETS


# ---------------------------------------------------------------------------
# counters / gauges / registry semantics
# ---------------------------------------------------------------------------
def test_counter_monotone_and_labels():
    reg = Registry()
    fam = reg.counter("t/c", labels=("kind",))
    fam.labels(kind="a").inc()
    fam.labels(kind="a").inc(2)
    fam.labels(kind="b").inc()
    assert fam.labels(kind="a").value == 3
    assert fam.labels(kind="b").value == 1
    with pytest.raises(ValueError):
        fam.labels(kind="a").inc(-1)
    with pytest.raises(ValueError):
        fam.labels(wrong="a")


def test_reregistration_conflicts_fail_loudly():
    reg = Registry()
    reg.counter("t/x")
    with pytest.raises(ValueError):
        reg.gauge("t/x")
    with pytest.raises(ValueError):
        reg.counter("t/x", labels=("k",))
    assert reg.counter("t/x") is reg.counter("t/x")   # idempotent get


@settings(max_examples=20, deadline=None)
@given(a=st.integers(1, 50), dropped=st.integers(1, 50),
       b=st.integers(1, 50))
def test_enable_disable_enable_never_leaks(a, dropped, b):
    """Mutations while disabled vanish entirely; values recorded while
    enabled persist and re-enabling resumes exactly where it left off."""
    reg = Registry()
    c = reg.counter("t/c")
    g = reg.gauge("t/g")
    h = reg.histogram("t/h", buckets=(1.0,))
    for _ in range(a):
        c.inc()
    g.set(a)
    h.observe(0.5)
    reg.disable()
    for _ in range(dropped):
        c.inc()
        h.observe(0.5)
    g.set(-1)
    assert c.value == a and g.value == a and h.labels().count == 1
    reg.enable()
    for _ in range(b):
        c.inc()
    assert c.value == a + b
    assert h.labels().count == 1 and g.value == a


def test_snapshot_roundtrips_through_json(tmp_path):
    reg = Registry()
    reg.counter("t/c").inc(3)
    reg.histogram("t/h", buckets=(1.0, 2.0)).observe(1.5)
    path = tmp_path / "m.jsonl"
    reg.dump_jsonl(str(path))
    reg.counter("t/c").inc()
    reg.dump_jsonl(str(path))                        # appends
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 2
    by_name = {m["name"]: m for m in lines[-1]["metrics"]}
    assert by_name["t/c"]["samples"][0]["value"] == 4
    assert by_name["t/h"]["samples"][0]["count"] == 1


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_span_nesting_and_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("outer", tick=1):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            tr.event("blip", kind="x")
    spans = {s["name"]: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner_a", "inner_b", "blip"}
    outer = spans["outer"]
    assert outer["parent_id"] == 0 and outer["depth"] == 0
    for name in ("inner_a", "inner_b"):
        assert spans[name]["parent_id"] == outer["span_id"]
        assert spans[name]["depth"] == 1
    # the event fired inside inner_b parents to it, one level deeper
    assert spans["blip"]["parent_id"] == spans["inner_b"]["span_id"]
    assert spans["blip"]["depth"] == 2
    assert spans["blip"]["dur"] == 0.0
    assert outer["attrs"] == {"tick": 1}
    # completion order: children land before the outer span
    order = [s["name"] for s in tr.spans()]
    assert order.index("inner_a") < order.index("outer")

    path = tmp_path / "spans.jsonl"
    n = tr.export_jsonl(str(path))
    assert n == 4
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [set(r) for r in recs] == [set(SPAN_FIELDS)] * 4
    assert tr.spans() == []                          # drained
    assert tr.export_jsonl(str(path)) == 0           # nothing duplicated


def test_disabled_tracer_runs_body_records_nothing():
    tr = Tracer()
    tr.enabled = False
    ran = []
    with tr.span("ghost"):
        ran.append(True)
    tr.event("ghost_event")
    assert ran == [True] and tr.spans() == []
    tr.enabled = True
    with tr.span("real"):
        pass
    assert [s["name"] for s in tr.spans()] == ["real"]


def test_span_ring_buffer_bounded():
    tr = Tracer(capacity=8)
    for i in range(20):
        with tr.span("s", i=i):
            pass
    spans = tr.spans()
    assert len(spans) == 8
    assert [s["attrs"]["i"] for s in spans] == list(range(12, 20))


# ---------------------------------------------------------------------------
# jaxpr identity: collectors on vs off leave traced computations untouched
# ---------------------------------------------------------------------------
# the shared repro.analysis fingerprint (this file used to carry its own
# make_jaxpr stringifier)
from repro.analysis import jaxpr_fingerprint as _jaxpr_str


def test_jaxpr_identity_fused_linear():
    """The serving/train hot kernel: record_launch fires at trace time,
    so this is exactly where instrumentation could perturb a jaxpr."""
    from repro.kernels import ops as kops
    x = jnp.ones((2, 8, 64), jnp.float32)
    r = jnp.tile(jnp.eye(16, dtype=jnp.float32), (4, 1, 1))
    w = jnp.ones((64, 32), jnp.float32)
    obs.enable()
    on = _jaxpr_str(kops.oftv2_linear_fused, x, r, w)
    obs.disable()
    off = _jaxpr_str(kops.oftv2_linear_fused, x, r, w)
    assert on == off


@pytest.mark.slow
def test_jaxpr_identity_fused_train_step():
    from benchmarks.obs_bench import _build_train
    step_fn, state, batch = _build_train()
    obs.enable()
    on = _jaxpr_str(step_fn, state, batch)
    obs.disable()
    off = _jaxpr_str(step_fn, state, batch)
    assert on == off


# ---------------------------------------------------------------------------
# kernel launch hooks
# ---------------------------------------------------------------------------
def test_kernel_launch_hook_counts_and_byte_model():
    from repro.kernels import runtime

    def launches(kernel):
        fam = obs.metric("kernel/launches_total")
        return fam.labels(kernel=kernel).value

    before = launches("oftv2_linear_fused")
    runtime.record_launch("oftv2_linear_fused", (4, 2), {"tm": 128},
                          t=512, k=64, n=64, b=16)
    assert launches("oftv2_linear_fused") == before + 1

    fused = obs.metric("kernel/modeled_hbm_bytes_total")
    unfused = obs.metric("kernel/modeled_hbm_bytes_unfused_total")
    f = fused.labels(kernel="oftv2_linear_fused").value
    u = unfused.labels(kernel="oftv2_linear_fused").value
    assert 0 < f < u                # fusion strictly reduces modeled bytes

    # disabled hook is a strict no-op
    obs.disable()
    runtime.record_launch("oftv2_linear_fused", (4, 2), {"tm": 128},
                          t=512, k=64, n=64, b=16)
    assert launches("oftv2_linear_fused") == before + 1


# ---------------------------------------------------------------------------
# chaos / fault telemetry
# ---------------------------------------------------------------------------
def test_straggler_monitor_counts_and_events():
    from repro.distributed.fault import StragglerMonitor
    fam = obs.metric("train/stragglers_total")
    before = fam.value
    obs.TRACER.clear()
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=2)
    for s in range(6):
        mon.record(s, 0.1)
    assert mon.record(6, 10.0) is True
    assert fam.value == before + 1
    names = [s["name"] for s in obs.TRACER.spans()]
    assert "train.straggler" in names


def test_chaos_schedule_counts_fired_faults():
    from repro.distributed.chaos import FaultSchedule
    fam = obs.metric("chaos/faults_fired_total")
    before = fam.labels(kind="straggler").value
    sched = FaultSchedule.parse("straggler@1:0.0")
    sched.straggler_delay(1)
    assert fam.labels(kind="straggler").value == before + 1
    assert [s["name"] for s in obs.TRACER.spans()].count("chaos.fault") >= 1


# ---------------------------------------------------------------------------
# /metrics HTTP endpoint
# ---------------------------------------------------------------------------
def test_metrics_http_endpoint_smoke():
    obs.metric("train/steps_total").inc()
    with obs.serve_metrics(port=0) as server:
        url = f"http://127.0.0.1:{server.port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "# TYPE train_steps_total counter" in body
        assert "serving_ttft_seconds" in body        # full schema emitted
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{server.port}/nope", timeout=10)


# ---------------------------------------------------------------------------
# schema <-> docs sync
# ---------------------------------------------------------------------------
def test_schema_covers_all_layers_and_readme_in_sync():
    from repro.obs import schema
    layers = {spec.layer for spec in schema.SPECS.values()}
    assert layers == set(schema.LAYERS)
    assert len(schema.SPECS) >= 25
    table = schema.markdown_table()
    readme = open("README.md").read()
    for line in table.splitlines():
        assert line in readme, f"README Observability table stale: {line!r}"


def test_undocumented_metric_name_fails_loudly():
    with pytest.raises(KeyError):
        obs.metric("train/not_a_real_metric")


# ---------------------------------------------------------------------------
# engine health as a registry view
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_health_is_registry_view_and_counters_deprecated():
    from test_serving_paged import _pooled, _prompts, _serving_model

    from repro.serving import Request, SamplingParams, ServingEngine
    model, params, cfg = _serving_model()
    pool, _ = _pooled(model)
    eng = ServingEngine(model, params, pool, n_slots=2, mode="paged",
                        page_size=4, prefill_chunk=8)
    prompts = _prompts(cfg, [8, 8])
    reqs = [Request(f"r{i}", prompts[i], adapter_id=i,
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(2)]
    results = eng.run(reqs)
    assert len(results) == 2

    h = eng.health()
    o = eng.obs
    assert h["counters"] == {"preemptions": int(o.preemptions.value),
                             "retries": int(o.retries.value),
                             "cancelled": int(o.cancelled.value),
                             "deadline_expired":
                                 int(o.deadline_expired.value)}
    assert h["pool"]["capacity"] == eng.kv.capacity_blocks
    assert h["kv_stats"] == eng.kv.stats            # registry-backed dict
    assert o.ticks.value > 0
    assert o.latency.count == 2 and o.ttft.count == 2

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = eng._counters
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert legacy == h["counters"]

    # engine telemetry lands in the shared exposition under its own label
    expo = obs.REGISTRY.exposition()
    assert f'serving_ticks_total{{engine="{o.engine_id}"}}' in expo
