"""Multi-device tests, run in subprocesses so the 8 fake host devices never
leak into the rest of the suite (jax locks device count at first init).
The harness (with proper XLA_FLAGS token filtering) lives in tests/_mesh.py,
shared with test_sharded_fused.py."""
import pytest

from _mesh import force_device_count_flags, run_py


def test_force_device_count_preserves_other_flags():
    """The old '=512' string replace corrupted any other preset value; the
    token filter must strip EVERY forced count and keep the rest."""
    out = force_device_count_flags(
        "--xla_force_host_platform_device_count=5120 "
        "--xla_cpu_enable_fast_math=true", 8)
    toks = out.split()
    assert toks[0] == "--xla_force_host_platform_device_count=8"
    assert "--xla_cpu_enable_fast_math=true" in toks
    assert len([t for t in toks if "device_count" in t]) == 1
    assert force_device_count_flags("", 4) == \
        "--xla_force_host_platform_device_count=4"


@pytest.mark.slow
def test_pipeline_matches_sequential():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.distributed.pipeline import gpipe, split_stages

    S, L, D, M, MB = 4, 8, 16, 6, 4
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    ws = 0.3 * jax.random.normal(key, (L, D, D)) / np.sqrt(D)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def seq_apply(ws, x):
        for i in range(L):
            x = layer(ws[i], x)
        return x

    def stage_fn(wchunk, x):
        def body(c, w):
            return layer(w, c), None
        y, _ = jax.lax.scan(body, x, wchunk)
        return y

    x = jax.random.normal(jax.random.fold_in(key, 1), (M, MB, D))
    want = jax.vmap(lambda xx: seq_apply(ws, xx))(x)
    staged = split_stages(ws, S)
    with mesh:
        got = gpipe(stage_fn, mesh)(staged, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)

    # gradients flow through the pipeline
    def loss_pipe(staged):
        with mesh:
            return jnp.sum(gpipe(stage_fn, mesh)(staged, x) ** 2)
    def loss_seq(ws):
        return jnp.sum(jax.vmap(lambda xx: seq_apply(ws, xx))(x) ** 2)
    g_pipe = jax.grad(loss_pipe)(staged).reshape(L, D, D)
    g_seq = jax.grad(loss_seq)(ws)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=2e-3, atol=2e-4)
    print("PIPELINE-OK")
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config.base import *
    from repro.models import build
    from repro.models.spec import default_rules
    from repro.distributed.sharding import (make_constrain,
                                            named_sharding_tree, batch_spec)
    from repro.train.step import make_train_step
    from repro.train import state as state_lib

    pcfg = ParallelConfig(mesh_shape=(2, 4), mesh_axes=("data", "model"))
    cfg = ModelConfig(name="tp-test", num_layers=2, d_model=64, num_heads=8,
                      num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=4),
                    parallel=pcfg,
                    train=TrainConfig(global_batch=8, seq_len=32,
                                      learning_rate=1e-3, steps=10,
                                      warmup_steps=0))
    mesh = jax.make_mesh(pcfg.mesh_shape, pcfg.mesh_axes)
    rules = default_rules(pcfg)

    # single-device reference
    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    st_ref = state_lib.create(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab_size)}
    _, m_ref = make_train_step(model_ref, run)(st_ref, batch)

    # sharded
    model = build(run, constrain=make_constrain(rules, mesh))
    specs = model.param_specs(rules)
    pshard = named_sharding_tree(specs, mesh)
    params_sh = jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, s), params, pshard)
    st = state_lib.create(params_sh)
    bshard = NamedSharding(mesh, batch_spec(pcfg, 2))
    batch_sh = {"tokens": jax.device_put(batch["tokens"], bshard)}
    with mesh:
        step = jax.jit(make_train_step(model, run))
        st2, m = step(st, batch_sh)
    np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                               rtol=2e-4)
    print("TP-OK", float(m["loss"]))
    """)


def test_elastic_reshard_1_to_4_devices():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.config.base import *
    from repro.models import build
    from repro.models.spec import default_rules
    from repro.distributed.sharding import named_sharding_tree
    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.elastic import reshard_tree

    cfg = ModelConfig(name="el", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(2)
    pcfg = ParallelConfig(mesh_shape=(2, 2), mesh_axes=("data", "model"))
    run = RunConfig(model=cfg, adapter=AdapterConfig(kind="oftv2",
                    block_size=16, neumann_terms=4), parallel=pcfg)
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))

    # save on "one topology" (host arrays)
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep=1, async_save=False)
    mgr.save(5, params, metadata={"data_cursor": 0})

    # restore onto a 2x2 mesh with full shardings
    restored, _ = mgr.restore(like=params)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    specs = model.param_specs(default_rules(pcfg))
    placed = reshard_tree(restored, specs, mesh)
    # values identical, shardings applied
    l0 = jax.tree_util.tree_leaves(params)
    l1 = jax.tree_util.tree_leaves(placed)
    for a, b in zip(l0, l1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
    sh = jax.tree_util.tree_leaves(placed)[0].sharding
    assert sh.mesh.shape == {"data": 2, "model": 2}
    print("ELASTIC-OK")
    """)


@pytest.mark.slow
def test_dp_loss_invariant_to_mesh_shape():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config.base import *
    from repro.models import build
    from repro.models.spec import default_rules
    from repro.distributed.sharding import make_constrain, batch_spec

    cfg = ModelConfig(name="dp", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=128,
                      rope_theta=1e4)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, 128)}
    losses = []
    for shape, axes in [((8,), ("data",)), ((2, 4), ("pod", "data"))]:
        pcfg = ParallelConfig(mesh_shape=shape, mesh_axes=axes)
        run = RunConfig(model=cfg, adapter=AdapterConfig(kind="oftv2",
                        block_size=16, neumann_terms=4), parallel=pcfg)
        mesh = jax.make_mesh(shape, axes)
        rules = default_rules(pcfg)
        model = build(run, constrain=make_constrain(rules, mesh))
        params = model.init(jax.random.PRNGKey(0))
        bsh = NamedSharding(mesh, batch_spec(pcfg, 2))
        bt = {"tokens": jax.device_put(batch["tokens"], bsh)}
        with mesh:
            loss, _ = jax.jit(lambda p, b: model.loss(p, b))(params, bt)
        losses.append(float(loss))
    assert abs(losses[0] - losses[1]) < 1e-4, losses
    print("DP-OK", losses)
    """)
