"""ISSUE-5: the fused OFTv2/QOFT train step and multi-tenant serving on a
(data, model) mesh, verified against single-device execution on 8 fake CPU
devices (subprocess harness: tests/_mesh.py).

What is pinned down:
  * fused forward + fused backward parity, sharded vs single-device;
  * a full hoisted train step (dense AND NF4): per-step loss parity over
    >= 5 steps at 2x4 / 4x2 / 8x1 mesh shapes;
  * the collective budget of the sharded fused path, asserted on the
    JAXPR: no all_gather / all_to_all anywhere (no gathered dense W, no
    gathered rotation blocks -- the kernels consume local shards), only
    the expected psums (partial y of K-sharded linears, dx/dR pullbacks);
  * sharded serving decode == single-device engine, token for token;
  * config-time failure when OFT blocks do not divide the model axis, and
    when the method lacks the `shards` capability (mesh-setup error, like
    the HOFT pool case).
"""
import textwrap

import pytest

from _mesh import run_py


def _run(body: str) -> str:
    """_COMMON is flush-left; test bodies are indented for readability --
    dedent them BEFORE concatenation (afterwards the mixed indent defeats
    dedent and the body would silently become part of the last _COMMON
    function)."""
    return run_py(_COMMON + textwrap.dedent(body))

# Shared subprocess preamble: a small fused OFTv2 model + its sharded twin.
# d_model=64, b=16 -> 4 blocks/linear on the embed dim; with_mesh_padding
# keeps heads/vocab divisible at every swept model-axis size.
_COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.config.base import *
from repro.models import build
from repro.models.spec import rules_variant
from repro.distributed.sharding import (batch_spec, fit_tree, make_constrain,
                                        make_shard_context)
from repro.train import state as state_lib
from repro.train.step import make_train_step

def make_run(mesh_shape, quant="none", batch=8):
    pcfg = ParallelConfig(mesh_shape=mesh_shape,
                          mesh_axes=("data", "model"))
    cfg = ModelConfig(name="shard-test", num_layers=2, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(pcfg.model_axis_size)
    return RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4,
                              fuse_linear=True),
        quant=QuantConfig(kind=quant, block_size=16),
        parallel=pcfg,
        train=TrainConfig(global_batch=batch, seq_len=32,
                          learning_rate=1e-3, steps=10, warmup_steps=0))

def make_sharded(run):
    mesh = jax.make_mesh(run.parallel.mesh_shape, run.parallel.mesh_axes)
    rules = rules_variant(run.parallel, "fused_tp")
    ctx = make_shard_context(mesh, rules, run)
    model = build(run, constrain=make_constrain(rules, mesh), shard=ctx)
    return mesh, rules, model

# the collective-budget assertions are the SHARED repro.analysis
# detectors (the same ones CI's `collective-budget` / `hlo-collective-
# budget` rules run); the budget itself comes from the method registry's
# shard_collectives, not a hardcoded psum-only list.  This preamble used
# to carry its own jaxpr walker + HLO scanner -- now deduped.
from repro.analysis import assert_collective_budget, assert_no_w_gathers_hlo
"""


def test_sharded_fused_forward_and_grads_match_single_device():
    """Fused forward logits and fused-backward adapter grads: 2x4 sharded
    == single device (fast tier twin of the slow per-mesh train sweep)."""
    _run("""
    run = make_run((2, 4))
    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, run.model.vocab_size)}
    logits_ref, _, _ = model_ref.forward(params, batch)

    mesh, rules, model = make_sharded(run)
    params_sh = fit_tree(params, model.param_specs(rules), mesh)
    bshard = NamedSharding(mesh, batch_spec(run.parallel, 2))
    batch_sh = {"tokens": jax.device_put(batch["tokens"], bshard)}
    with mesh:
        logits, _, _ = jax.jit(
            lambda p, b: model.forward(p, b))(params_sh, batch_sh)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-4, atol=2e-5)

    def loss(m):
        def f(adapter, base, b):
            return m.loss({"base": base, "adapter": adapter}, b)[0]
        return f

    g_ref = jax.grad(loss(model_ref))(params["adapter"], params["base"],
                                      batch)
    with mesh:
        g_sh = jax.jit(jax.grad(loss(model)))(params_sh["adapter"],
                                              params_sh["base"], batch_sh)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-5)
    print("FWD-BWD-OK")
    """)


@pytest.mark.slow
@pytest.mark.parametrize("mesh_shape,quant", [
    ((2, 4), "none"), ((4, 2), "none"), ((8, 1), "none"), ((2, 4), "nf4")])
def test_sharded_hoisted_train_step_matches_single_device(mesh_shape,
                                                          quant):
    """Full hoisted train step: per-step loss parity with single-device
    over 5 steps, at 2x4 / 4x2 / 8x1 mesh shapes over a dense base and at
    2x4 over an NF4 base (codes/absmax shard like the weight, dequantized
    tile-by-tile in the local kernels -- a dense W exists on no shard, in
    no direction).  Collective budget asserted twice: on the jaxpr (no
    all_gather/all_to_all primitives anywhere, psums present) AND on the
    compiled HLO (no GSPMD-inserted gather of a W-shaped tensor)."""
    _run(f"""
    run = make_run({mesh_shape!r}, quant={quant!r})
    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                           0, run.model.vocab_size)}}
    step_ref = jax.jit(make_train_step(model_ref, run))
    mesh, rules, model = make_sharded(run)
    params_sh = fit_tree(params, model.param_specs(rules), mesh)
    st_ref, st = state_lib.create(params), state_lib.create(params_sh)
    bshard = NamedSharding(mesh, batch_spec(run.parallel, 2))
    batch_sh = {{"tokens": jax.device_put(batch["tokens"], bshard)}}
    with mesh:
        assert_collective_budget(make_train_step(model, run),
                                 (st, batch_sh),
                                 run.parallel.model_axis_size)
        assert_no_w_gathers_hlo(make_train_step(model, run),
                                (st, batch_sh), run.model)
        step = jax.jit(make_train_step(model, run))
    for i in range(5):
        st_ref, m_ref = step_ref(st_ref, batch)
        with mesh:
            st, m = step(st, batch_sh)
        np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                                   rtol=2e-4, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(st_ref.adapter),
                    jax.tree_util.tree_leaves(st.adapter)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-5)
    print("TRAIN-OK", {mesh_shape!r}, {quant!r})
    """)


@pytest.mark.slow
def test_sharded_serving_decode_matches_single_device():
    """Mixed-adapter continuous-batching decode on the mesh: slot batch
    data-sharded, r_stack model-sharded -- greedy output token-for-token
    identical to the single-device engine, and the decode step's jaxpr
    stays gather-free."""
    _run("""
    from repro.serving import AdapterPool, Request, ServingEngine, \\
        init_adapters
    run = make_run((2, 4))
    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    adapters = init_adapters(model_ref, 3, jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(1)

    def requests():
        return [Request(f"r{i}", np.asarray(jax.random.randint(
                    jax.random.fold_in(key, i), (6 + i,), 0,
                    run.model.vocab_size)),
                    adapter_id=i % 3, max_new_tokens=7) for i in range(6)]

    pool_ref = AdapterPool(model_ref)
    for i, t in enumerate(adapters):
        pool_ref.register(f"t{i}", t)
    out_ref = ServingEngine(model_ref, params, pool_ref,
                            n_slots=4).run(requests())

    mesh, rules, model = make_sharded(run)
    params_sh = fit_tree(params, model.param_specs(rules), mesh)
    pool = AdapterPool(model)
    for i, t in enumerate(adapters):
        pool.register(f"t{i}", t)
    with mesh:
        engine = ServingEngine(model, params_sh, pool, n_slots=4)
        sp = engine.params
        caches = model.make_caches(4, 16)
        tok = jnp.zeros((4, 1), jnp.int32)
        pos = jnp.zeros((4,), jnp.int32)
        aid = jnp.zeros((4,), jnp.int32)
        assert_collective_budget(
            lambda p, c, t, po, a: model.decode_step(
                p, {"tokens": t, "positions": po[:, None],
                    "cache_index": po, "caches": c, "adapter_id": a}),
            (sp, caches, tok, pos, aid), run.parallel.model_axis_size)
        out = engine.run(requests())
    assert set(out) == set(out_ref)
    for rid in out_ref:
        np.testing.assert_array_equal(out[rid], out_ref[rid])
    print("SERVE-OK")
    """)


@pytest.mark.slow
@pytest.mark.parametrize("quant", ["none", "nf4"])
def test_sharded_paged_serving_matches_single_device_slots(quant):
    """ISSUE-6 acceptance: the paged + chunked-prefill + prefix-sharing
    data plane on the mesh reproduces the single-device FIXED-SLOT (v1)
    engine token for token, dense and NF4.  Requests share a system
    prompt so the mesh run exercises block adoption, and page_size /
    prefill_chunk are chosen so the 9-token shared prefix spans both full
    and partial blocks and the longest prompt needs multiple chunks."""
    _run(f"""
    from repro.serving import AdapterPool, Request, SamplingParams, \\
        ServingEngine, init_adapters
    run = make_run((2, 4), quant={quant!r})
    model_ref = build(run)
    params = model_ref.init(jax.random.PRNGKey(0))
    adapters = init_adapters(model_ref, 3, jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(1)
    sys_prompt = list(np.asarray(jax.random.randint(
        jax.random.fold_in(key, 99), (9,), 0, run.model.vocab_size)))

    def requests():
        out = []
        for i in range(6):
            tail = list(np.asarray(jax.random.randint(
                jax.random.fold_in(key, i), (3 + i,), 0,
                run.model.vocab_size)))
            out.append(Request(f"r{{i}}", np.asarray(sys_prompt + tail),
                               adapter_id=i % 3,
                               sampling=SamplingParams(max_new_tokens=7)))
        return out

    pool_ref = AdapterPool(model_ref)
    for i, t in enumerate(adapters):
        pool_ref.register(f"t{{i}}", t)
    out_ref = ServingEngine(model_ref, params, pool_ref, n_slots=4,
                            mode="slots").run(requests())

    mesh, rules, model = make_sharded(run)
    params_sh = fit_tree(params, model.param_specs(rules), mesh)
    pool = AdapterPool(model)
    for i, t in enumerate(adapters):
        pool.register(f"t{{i}}", t)
    with mesh:
        engine = ServingEngine(model, params_sh, pool, n_slots=4,
                               mode="paged", page_size=4, prefill_chunk=8)
        out = engine.run(requests())
    assert set(out) == set(out_ref)
    for rid in out_ref:
        np.testing.assert_array_equal(out[rid], out_ref[rid])
    print("PAGED-MESH-OK", {quant!r})
    """)


def test_mesh_setup_rejects_bad_configs():
    """Config-time gate: blocks not dividing the model axis -> ValueError
    naming the linear; a method without the `shards` capability (HOFT) ->
    NotImplementedError at mesh setup, before any trace."""
    run_py("""
    import jax
    from repro.config.base import *
    from repro.models.spec import rules_variant
    from repro.distributed.sharding import make_shard_context

    pcfg = ParallelConfig(mesh_shape=(2, 4), mesh_axes=("data", "model"))
    mesh = jax.make_mesh(pcfg.mesh_shape, pcfg.mesh_axes)
    rules = rules_variant(pcfg, "fused_tp")

    # d_model=64, block_size=32 -> o/down have 2 blocks over a 4-way model
    # axis: must fail at config time, naming blocks and shards
    cfg = ModelConfig(name="bad", num_layers=1, d_model=64, num_heads=8,
                      num_kv_heads=2, d_ff=64, vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(4)
    run = RunConfig(model=cfg, parallel=pcfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=32,
                                          fuse_linear=True))
    try:
        make_shard_context(mesh, rules, run)
        raise AssertionError("blocks-not-divisible config was accepted")
    except ValueError as e:
        assert "blocks must divide evenly" in str(e), e

    # no `shards` capability -> loud NotImplementedError at mesh setup
    run_hoft = RunConfig(model=cfg, parallel=pcfg,
                         adapter=AdapterConfig(kind="hoft", reflections=4,
                                               fuse_linear=True))
    try:
        make_shard_context(mesh, rules, run_hoft)
        raise AssertionError("non-shards method was accepted at mesh setup")
    except NotImplementedError as e:
        assert "shards" in str(e) and "oftv2" in str(e), e

    # SSM layers adapt in_proj/out_proj but do not thread the shard
    # context: fused-on-mesh must fail at setup, not silently replicate
    ssm_cfg = ModelConfig(name="ssm", family="ssm", num_layers=2,
                          d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                          vocab_size=256, ssm_state=16)
    run_ssm = RunConfig(model=ssm_cfg, parallel=pcfg,
                        adapter=AdapterConfig(kind="oftv2", block_size=16,
                                              fuse_linear=True))
    try:
        make_shard_context(mesh, rules, run_ssm)
        raise AssertionError("SSM-adapted config was accepted at mesh setup")
    except NotImplementedError as e:
        assert "SSM" in str(e), e

    # off-mesh: no context, no errors
    assert make_shard_context(None, rules, run) is None
    print("SETUP-GATE-OK")
    """)
