"""Minimal stand-in for the ``hypothesis`` package, installed into
``sys.modules`` by conftest.py ONLY when the real library is absent.

CI installs real hypothesis from requirements-dev.txt; this fallback exists
so the tier-1 suite still collects and runs in hermetic containers where
``pip install`` is unavailable.  It implements exactly the surface the test
suite uses -- ``@settings(max_examples=, deadline=)`` over ``@given(**kw)``
with ``st.floats(lo, hi)`` / ``st.integers(lo, hi)`` -- by drawing a
deterministic (seeded per-test) sample of examples instead of doing real
property search.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _floats(lo: float, hi: float, **_kw) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(lo, hi))


def _integers(lo: int, hi: int, **_kw) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(lo, hi))


def _given(**strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rnd = random.Random(fn.__qualname__)   # deterministic per test
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        # NOT functools.wraps: pytest must see the wrapper's (empty)
        # signature, not the wrapped one's, or it hunts for fixtures named
        # after the strategy kwargs.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def _settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register the fallback as the ``hypothesis`` package."""
    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    st = types.ModuleType("hypothesis.strategies")
    st.floats = _floats
    st.integers = _integers
    mod.strategies = st
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
