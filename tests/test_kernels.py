"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import QuantConfig
from repro.core import skew
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant import nf4


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-6)


# ------------------------------------------------------ block_oft_apply ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,b", [
    ((4, 64), 16), ((3, 7, 128), 32), ((512, 256), 32), ((2, 5, 96), 8),
    ((1, 64), 64), ((260, 64), 16),
])
def test_block_oft_apply_matches_ref(shape, b, dtype):
    key = jax.random.PRNGKey(0)
    d = shape[-1]
    x = jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)
    from repro.core.cayley import build_rotation
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5).astype(dtype)
    got = kops.block_oft_apply(x, r)
    want = kref.block_oft_apply_ref(x, r)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_block_oft_apply_grads_match_ref():
    key = jax.random.PRNGKey(1)
    b, d = 16, 64
    x = jax.random.normal(key, (32, d))
    from repro.core.cayley import build_rotation
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5)

    def f_kernel(x, r):
        return jnp.sum(jnp.sin(kops.block_oft_apply(x, r)))

    def f_ref(x, r):
        return jnp.sum(jnp.sin(kref.block_oft_apply_ref(x, r)))

    gx_k, gr_k = jax.grad(f_kernel, argnums=(0, 1))(x, r)
    gx_r, gr_r = jax.grad(f_ref, argnums=(0, 1))(x, r)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gr_k), np.asarray(gr_r), rtol=1e-4,
                               atol=1e-5)


# ------------------------------------------------------- cayley_neumann ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,b,k", [(4, 16, 5), (8, 32, 5), (16, 8, 3),
                                   (2, 64, 6), (3, 16, 1)])
def test_cayley_neumann_kernel_matches_ref(r, b, k, dtype):
    key = jax.random.PRNGKey(2)
    qp = skew.random_skew(key, (r,), b, scale=0.05).astype(dtype)
    got = kops.cayley_neumann(qp, b, k)
    want = kref.cayley_neumann_ref(qp, b, k)
    assert got.shape == (r, b, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_cayley_neumann_kernel_grad_matches_ref():
    key = jax.random.PRNGKey(3)
    qp = skew.random_skew(key, (4,), 16, scale=0.05)

    g_k = jax.grad(lambda q: jnp.sum(jnp.square(kops.cayley_neumann(q, 16, 5))))(qp)
    g_r = jax.grad(lambda q: jnp.sum(jnp.square(
        kref.cayley_neumann_ref(q, 16, 5))))(qp)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-4,
                               atol=1e-6)


def test_cayley_neumann_exact_fallback():
    qp = skew.random_skew(jax.random.PRNGKey(4), (4,), 16, scale=0.05)
    got = kops.cayley_neumann(qp, 16, 0)   # exact Cayley -> oracle path
    want = kref.cayley_neumann_ref(qp, 16, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


# ---------------------------------------------------------- nf4_dequant ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d_in,d_out,bs", [(128, 64, 64), (256, 128, 64),
                                           (512, 96, 32), (64, 256, 16),
                                           (128, 33, 64)])
def test_nf4_dequant_kernel_matches_ref(d_in, d_out, bs, dtype):
    qcfg = QuantConfig(kind="nf4", block_size=bs, double_quant=False)
    key = jax.random.PRNGKey(5)
    w = 0.1 * jax.random.normal(key, (d_in, d_out))
    q = nf4.quantize(w, qcfg)
    got = kops.nf4_dequant(q["nf4_codes"], q["absmax"], bs, dtype=dtype)
    want = kref.nf4_dequant_ref(q["nf4_codes"], q["absmax"], bs, dtype=dtype)
    assert got.shape == (d_in, d_out) and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # and the oracle itself matches the quant library
    lib = nf4.dequantize(q, qcfg, dtype)
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(lib, np.float32), rtol=1e-5,
                               atol=1e-6)


def test_oftv2_with_pallas_flag_end_to_end():
    """core.oft routes through the kernels when use_pallas=True."""
    from repro.config.base import AdapterConfig
    from repro.core import oft
    acfg_np = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5,
                            use_pallas=False)
    acfg_pl = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5,
                            use_pallas=True)
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (8, 9, 64))
    params = {"q_packed": skew.random_skew(key, (4,), 16, scale=0.1)}
    y_np = oft.oftv2_transform_input(x, params, acfg_np)
    y_pl = oft.oftv2_transform_input(x, params, acfg_pl)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_np), rtol=1e-5,
                               atol=1e-6)
