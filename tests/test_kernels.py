"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import QuantConfig
from repro.core import skew
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant import nf4

pytestmark = pytest.mark.kernels


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-6)


# ------------------------------------------------------ block_oft_apply ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,b", [
    ((4, 64), 16), ((3, 7, 128), 32), ((512, 256), 32), ((2, 5, 96), 8),
    ((1, 64), 64), ((260, 64), 16),
])
def test_block_oft_apply_matches_ref(shape, b, dtype):
    key = jax.random.PRNGKey(0)
    d = shape[-1]
    x = jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)
    from repro.core.cayley import build_rotation
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5).astype(dtype)
    got = kops.block_oft_apply(x, r)
    want = kref.block_oft_apply_ref(x, r)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_block_oft_apply_grads_match_ref():
    key = jax.random.PRNGKey(1)
    b, d = 16, 64
    x = jax.random.normal(key, (32, d))
    from repro.core.cayley import build_rotation
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5)

    def f_kernel(x, r):
        return jnp.sum(jnp.sin(kops.block_oft_apply(x, r)))

    def f_ref(x, r):
        return jnp.sum(jnp.sin(kref.block_oft_apply_ref(x, r)))

    gx_k, gr_k = jax.grad(f_kernel, argnums=(0, 1))(x, r)
    gx_r, gr_r = jax.grad(f_ref, argnums=(0, 1))(x, r)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gr_k), np.asarray(gr_r), rtol=1e-4,
                               atol=1e-5)


# ------------------------------------------------------- cayley_neumann ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,b,k", [(4, 16, 5), (8, 32, 5), (16, 8, 3),
                                   (2, 64, 6), (3, 16, 1)])
def test_cayley_neumann_kernel_matches_ref(r, b, k, dtype):
    key = jax.random.PRNGKey(2)
    qp = skew.random_skew(key, (r,), b, scale=0.05).astype(dtype)
    got = kops.cayley_neumann(qp, b, k)
    want = kref.cayley_neumann_ref(qp, b, k)
    assert got.shape == (r, b, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_cayley_neumann_kernel_grad_matches_ref():
    key = jax.random.PRNGKey(3)
    qp = skew.random_skew(key, (4,), 16, scale=0.05)

    g_k = jax.grad(lambda q: jnp.sum(jnp.square(kops.cayley_neumann(q, 16, 5))))(qp)
    g_r = jax.grad(lambda q: jnp.sum(jnp.square(
        kref.cayley_neumann_ref(q, 16, 5))))(qp)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-4,
                               atol=1e-6)


def test_cayley_neumann_exact_fallback():
    qp = skew.random_skew(jax.random.PRNGKey(4), (4,), 16, scale=0.05)
    got = kops.cayley_neumann(qp, 16, 0)   # exact Cayley -> oracle path
    want = kref.cayley_neumann_ref(qp, 16, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


# ---------------------------------------------------------- nf4_dequant ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d_in,d_out,bs", [(128, 64, 64), (256, 128, 64),
                                           (512, 96, 32), (64, 256, 16),
                                           (128, 33, 64)])
def test_nf4_dequant_kernel_matches_ref(d_in, d_out, bs, dtype):
    qcfg = QuantConfig(kind="nf4", block_size=bs, double_quant=False)
    key = jax.random.PRNGKey(5)
    w = 0.1 * jax.random.normal(key, (d_in, d_out))
    q = nf4.quantize(w, qcfg)
    got = kops.nf4_dequant(q["nf4_codes"], q["absmax"], bs, dtype=dtype)
    want = kref.nf4_dequant_ref(q["nf4_codes"], q["absmax"], bs, dtype=dtype)
    assert got.shape == (d_in, d_out) and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # and the oracle itself matches the quant library
    lib = nf4.dequantize(q, qcfg, dtype)
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(lib, np.float32), rtol=1e-5,
                               atol=1e-6)


# ------------------------------------------ fused oftv2 / qoft linears ----
FUSED_SHAPES = [
    # (lead shape, d_in, d_out, b): odd token counts / narrow d_out exercise
    # token padding and the n/k tile fallbacks
    ((37,), 64, 48, 16), ((3, 7), 128, 96, 32), ((260,), 96, 33, 8),
    ((1,), 64, 64, 64), ((512,), 256, 128, 32),
]


def _fused_inputs(lead, d, n, b, dtype=jnp.float32, seed=0):
    from repro.core.cayley import build_rotation
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, lead + (d,), jnp.float32).astype(dtype)
    w = (jax.random.normal(key, (d, n), jnp.float32) / np.sqrt(d)).astype(dtype)
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5).astype(dtype)
    return x, r, w


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lead,d,n,b", FUSED_SHAPES)
def test_oftv2_linear_fused_matches_ref_and_unfused(lead, d, n, b, dtype):
    x, r, w = _fused_inputs(lead, d, n, b, dtype)
    got = kops.oftv2_linear_fused(x, r, w)
    want = kref.oftv2_linear_ref(x, r, w)
    unfused = kref.block_oft_apply_ref(x, r) @ w
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(unfused, np.float32), **_tol(dtype))


def test_oftv2_linear_fused_grads_match_ref():
    x, r, w = _fused_inputs((21,), 64, 40, 16)

    def f_kernel(x, r, w):
        return jnp.sum(jnp.sin(kops.oftv2_linear_fused(x, r, w)))

    def f_ref(x, r, w):
        return jnp.sum(jnp.sin(kref.oftv2_linear_ref(x, r, w)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, r, w)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, r, w)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("d_in,d_out,b,bs", [
    (128, 64, 16, 64), (256, 96, 32, 32), (64, 33, 16, 16), (512, 128, 32, 64),
])
def test_qoft_linear_fused_matches_ref_and_unfused(d_in, d_out, b, bs):
    x, r, w = _fused_inputs((29,), d_in, d_out, b, seed=1)
    qcfg = QuantConfig(kind="nf4", block_size=bs, double_quant=False)
    q = nf4.quantize(0.1 * w, qcfg)
    got = kops.qoft_linear_fused(x, r, q["nf4_codes"], q["absmax"], bs)
    want = kref.qoft_linear_ref(x, r, q["nf4_codes"], q["absmax"], bs)
    w_dq = nf4.dequantize(q, qcfg, jnp.float32)
    unfused = kref.block_oft_apply_ref(x, r) @ w_dq
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(unfused),
                               rtol=2e-5, atol=1e-5)


def test_qoft_linear_fused_grads_match_ref():
    d, n, b, bs = 128, 40, 16, 64
    x, r, w = _fused_inputs((21,), d, n, b, seed=2)
    q = nf4.quantize(0.1 * w, QuantConfig(kind="nf4", block_size=bs,
                                          double_quant=False))

    def f_kernel(x, r):
        return jnp.sum(jnp.sin(
            kops.qoft_linear_fused(x, r, q["nf4_codes"], q["absmax"], bs)))

    def f_ref(x, r):
        return jnp.sum(jnp.sin(
            kref.qoft_linear_ref(x, r, q["nf4_codes"], q["absmax"], bs)))

    gk = jax.grad(f_kernel, argnums=(0, 1))(x, r)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, r)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-4)


def test_fused_flag_end_to_end_adapted_linear():
    """adapted_linear(fuse_linear=True) == unfused, for dense + NF4 +
    double-quant NF4 bases, fwd and adapter grads."""
    from repro.config.base import AdapterConfig
    from repro.core import adapter as ad
    from repro.quant.common import quantize_linear
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (2, 9, 128))
    w = 0.05 * jax.random.normal(key, (128, 96))
    adp = {"q_packed": skew.random_skew(key, (8,), 16, scale=0.1)}
    for qcfg in [QuantConfig(kind="none"),
                 QuantConfig(kind="nf4", block_size=32, double_quant=False),
                 QuantConfig(kind="nf4", block_size=32, double_quant=True,
                             double_block=32)]:
        qstate = quantize_linear(w, qcfg)
        acfg_u = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5)
        acfg_f = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5,
                               fuse_linear=True)
        y_u = ad.adapted_linear(x, qstate, adp, acfg_u, qcfg)
        y_f = ad.adapted_linear(x, qstate, adp, acfg_f, qcfg)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u),
                                   rtol=1e-5, atol=1e-5)

        def loss(p, acfg):
            return jnp.sum(jnp.square(
                ad.adapted_linear(x, qstate, p, acfg, qcfg)))

        g_u = jax.grad(loss)(adp, acfg_u)["q_packed"]
        g_f = jax.grad(loss)(adp, acfg_f)["q_packed"]
        np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_u),
                                   rtol=1e-4, atol=1e-4)


def test_linear_fusion_mode_plan():
    from repro.config.base import AdapterConfig
    from repro.models.linears import linear_fusion_mode
    acfg = AdapterConfig(kind="oftv2", block_size=16, fuse_linear=True)
    nf4_q = QuantConfig(kind="nf4", block_size=32)
    assert linear_fusion_mode("q", 128, 96, acfg, nf4_q) == "qoft_fused"
    # too small to quantize -> dense base, still fused
    assert linear_fusion_mode("q", 30, 96, acfg, nf4_q) == "oftv2_fused"
    assert linear_fusion_mode("q", 128, 96, acfg,
                              QuantConfig(kind="none")) == "oftv2_fused"
    # untargeted linear or fusion off -> unfused
    assert linear_fusion_mode("router", 128, 96, acfg, nf4_q) == "unfused"
    acfg_off = AdapterConfig(kind="oftv2", block_size=16)
    assert linear_fusion_mode("q", 128, 96, acfg_off, nf4_q) == "unfused"


def test_direct_kernel_calls_resolve_interpret_default():
    """Kernel entry points called WITHOUT interpret= auto-detect the
    backend (runtime.resolve_interpret) instead of a hardcoded True --
    direct callers on TPU get compiled kernels, and on CPU these still run
    (interpret) rather than failing to lower."""
    from repro.core.cayley import build_rotation
    from repro.kernels.block_oft_apply import block_oft_apply_kernel
    from repro.kernels.cayley_neumann import cayley_neumann_kernel
    from repro.kernels.nf4_dequant import nf4_dequant_kernel
    from repro.kernels.oftv2_linear_bwd import oftv2_linear_bwd_kernel
    from repro.kernels.oftv2_linear_fused import oftv2_linear_fused_kernel
    key = jax.random.PRNGKey(9)
    qp = skew.random_skew(key, (8,), 16, scale=0.05)
    r = cayley_neumann_kernel(qp, 16, 5, block_tile=8)
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(kref.cayley_neumann_ref(qp, 16, 5)),
                               rtol=1e-5, atol=1e-6)
    x3 = jax.random.normal(key, (8, 8, 16))
    y3 = block_oft_apply_kernel(x3, r, token_tile=8, block_tile=8)
    assert y3.shape == x3.shape
    x = jax.random.normal(key, (8, 128))
    w = 0.05 * jax.random.normal(key, (128, 64))
    rr = build_rotation(skew.random_skew(key, (8,), 16, scale=0.05), 16, 5)
    y = oftv2_linear_fused_kernel(x, rr, w, token_tile=8, n_tile=64,
                                  k_tile=128)
    dx, dr = oftv2_linear_bwd_kernel(jnp.ones_like(y), x, rr, w,
                                     token_tile=8, n_tile=64, k_tile=128)
    assert dx.shape == x.shape and dr.shape == rr.shape
    q = nf4.quantize(w, QuantConfig(kind="nf4", block_size=32,
                                    double_quant=False))
    wd = nf4_dequant_kernel(q["nf4_codes"], q["absmax"], 32, in_tile=128,
                            out_tile=64)
    assert wd.shape == w.shape


def test_oftv2_with_pallas_flag_end_to_end():
    """core.oft routes through the kernels when use_pallas=True."""
    from repro.config.base import AdapterConfig
    from repro.core import oft
    acfg_np = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5,
                            use_pallas=False)
    acfg_pl = AdapterConfig(kind="oftv2", block_size=16, neumann_terms=5,
                            use_pallas=True)
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (8, 9, 64))
    params = {"q_packed": skew.random_skew(key, (4,), 16, scale=0.1)}
    y_np = oft.oftv2_transform_input(x, params, acfg_np)
    y_pl = oft.oftv2_transform_input(x, params, acfg_pl)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_np), rtol=1e-5,
                               atol=1e-6)
