"""Quickstart: OFTv2-finetune a small frozen transformer on the synthetic
LM task, then merge the adapter and verify the merged model matches the
runtime adapter forward.

    PYTHONPATH=src python examples/quickstart.py [--steps N]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.core.adapter import merge_adapter
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.models import build
from repro.train.loop import run_training


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="training steps (CI smoke passes a smaller count)")
    args = ap.parse_args(argv)
    cfg = ModelConfig(name="quickstart", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="oftv2", block_size=32, neumann_terms=5),
        train=TrainConfig(global_batch=8, seq_len=64, steps=args.steps,
                          learning_rate=8e-3, warmup_steps=5,
                          ckpt_every=0, log_every=10,
                          ckpt_dir="/tmp/repro_quickstart"))
    model = build(run)
    print(f"base params:    {model.param_counts()['base'] / 1e6:.2f}M "
          f"(frozen)")
    print(f"adapter params: {model.param_counts()['adapter'] / 1e3:.1f}K "
          f"(trainable, packed skew-symmetric)")

    loader = ShardedLoader(SyntheticSpec(vocab_size=256, seq_len=64,
                                         noise=0.05), global_batch=8, seed=0)
    out = run_training(model, run, loader)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    # --- merge-back check: R @ W0 deployment == runtime adapter ----------
    state = out["state"]
    params = {"base": state.base, "adapter": state.adapter}
    batch = jax.tree_util.tree_map(jnp.asarray, loader.next_batch())
    logits_runtime, _, _ = model.forward(params, batch)

    acfg = run.adapter
    merged_base = jax.tree_util.tree_map(lambda x: x, state.base)
    for p in ["pos_0"]:
        layer_b = merged_base["groups"][p]
        layer_a = state.adapter["groups"][p]
        for blk in ("attn", "mlp"):
            for name, ad in layer_a[blk].items():
                w = layer_b[blk][name]["w"]
                merged = jax.vmap(lambda wl, al: merge_adapter(
                    wl, {"q_packed": al}, acfg))(w, ad["q_packed"])
                layer_b[blk][name]["w"] = merged
    logits_merged, _, _ = model.forward(
        {"base": merged_base, "adapter": {}}, batch)
    err = float(jnp.max(jnp.abs(logits_runtime - logits_merged)))
    print(f"merged-vs-runtime max logit err: {err:.2e}")
    assert err < 1e-2
    print("OK")


if __name__ == "__main__":
    main()
