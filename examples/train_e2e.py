"""End-to-end driver: OFTv2-finetune a ~27M-parameter decoder (a scaled
granite-family config) for a few hundred steps on the synthetic corpus,
with checkpointing + auto-resume + straggler monitoring -- the full
production loop at laptop scale.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    (re-running resumes from the latest checkpoint)
"""
import argparse

import numpy as np

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.distributed.fault import PreemptionGuard
from repro.models import build
from repro.train.loop import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--adapter", default="oftv2",
                    choices=["oftv2", "oftv1", "lora", "none"])
    ap.add_argument("--quant", default="none",
                    choices=["none", "nf4", "awq", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    # ~27M params: 8L x d=384 (granite-family geometry, scaled)
    cfg = ModelConfig(name="granite-27m", num_layers=8, d_model=384,
                      num_heads=8, num_kv_heads=2, head_dim=48, d_ff=1152,
                      vocab_size=8192, rope_theta=1e4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=args.adapter, block_size=32,
                              neumann_terms=5, rank=16),
        quant=QuantConfig(kind=args.quant),
        train=TrainConfig(global_batch=4, seq_len=128, steps=args.steps,
                          learning_rate=8e-3, warmup_steps=20,
                          schedule="cosine", ckpt_every=100, ckpt_keep=2,
                          log_every=20, ckpt_dir=args.ckpt_dir))
    model = build(run)
    counts = model.param_counts()
    print(f"[e2e] base {counts['base'] / 1e6:.1f}M frozen, "
          f"adapter {counts['adapter'] / 1e3:.1f}K trainable "
          f"({args.adapter}/{args.quant})")

    loader = ShardedLoader(
        SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=run.train.seq_len,
                      noise=0.05),
        global_batch=run.train.global_batch, seed=0)
    guard = PreemptionGuard(install=True)   # SIGTERM -> checkpoint + exit
    out = run_training(model, run, loader, guard=guard)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    print(f"[e2e] loss {first:.3f} -> {last:.3f} over "
          f"{out['last_step']} steps "
          f"({out.get('wall_time', 0):.0f}s, "
          f"{out['stragglers']} straggler steps)")
    assert last < first
    print("OK")


if __name__ == "__main__":
    main()
