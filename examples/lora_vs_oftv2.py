"""Head-to-head at matched conditions: LoRA vs OFTv2 vs OFTv1 on the same
frozen base + data stream (paper Tables 1/3 in miniature): final loss,
trainable params, step time.

    PYTHONPATH=src python examples/lora_vs_oftv2.py [--steps N]
"""
import argparse
import time

import numpy as np

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.models import build
from repro.train.loop import run_training


def run_one(kind: str, steps=60):
    cfg = ModelConfig(name="h2h", num_layers=2, d_model=128, num_heads=4,
                      num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4)
    lr = 4e-3 if kind == "lora" else 1.6e-2     # paper: OFT lr = 4x LoRA lr
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=kind, block_size=32, neumann_terms=5,
                              rank=16, alpha=32.0),
        train=TrainConfig(global_batch=8, seq_len=64, steps=steps,
                          learning_rate=lr, warmup_steps=5, ckpt_every=0,
                          log_every=0, ckpt_dir=f"/tmp/repro_h2h_{kind}"))
    model = build(run)
    loader = ShardedLoader(SyntheticSpec(vocab_size=256, seq_len=64,
                                         noise=0.05), global_batch=8, seed=2)
    t0 = time.time()
    out = run_training(model, run, loader, log=lambda s: None)
    dt = time.time() - t0
    return {"kind": kind, "final": float(np.mean(out["losses"][-10:])),
            "params": model.param_counts()["adapter"],
            "s_per_step": dt / steps}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="training steps per method (CI smoke uses fewer)")
    args = ap.parse_args(argv)
    rows = [run_one(k, steps=args.steps) for k in ("lora", "oftv2", "oftv1")]
    print(f"{'adapter':8} {'trainable':>10} {'final loss':>11} "
          f"{'s/step':>8}")
    for r in rows:
        print(f"{r['kind']:8} {r['params']:>10} {r['final']:>11.4f} "
              f"{r['s_per_step']:>8.3f}")
    # OFTv1 and OFTv2 are the same math -- different dataflow
    assert abs(rows[1]["final"] - rows[2]["final"]) < 0.35
    print("OK (v1/v2 land in the same quality band; v2 is the fast path)")


if __name__ == "__main__":
    main()
