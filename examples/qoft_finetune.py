"""QOFT: orthogonal finetuning of an NF4-quantized frozen base (the paper's
§4). Shows the memory story: frozen weights at ~0.53 bytes/param, trainable
state = packed-skew adapters only.

    PYTHONPATH=src python examples/qoft_finetune.py
"""
import jax
import jax.numpy as jnp

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig, TrainConfig)
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.models import build
from repro.quant.common import storage_bytes
from repro.train.loop import run_training


def tree_bytes(tree):
    return sum(l.nbytes for l in jax.tree_util.tree_leaves(tree))


def main():
    cfg = ModelConfig(name="qoft-demo", num_layers=2, d_model=256,
                      num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=512,
                      rope_theta=1e4)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind="oftv2", block_size=32, neumann_terms=5),
        quant=QuantConfig(kind="nf4", block_size=64, double_quant=True),
        train=TrainConfig(global_batch=8, seq_len=64, steps=50,
                          learning_rate=8e-3, warmup_steps=5, ckpt_every=0,
                          log_every=10, ckpt_dir="/tmp/repro_qoft"))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    bb = tree_bytes(params["base"])
    ab = tree_bytes(params["adapter"])
    nb = model.param_counts()["base"]
    print(f"frozen base: {nb / 1e6:.2f}M params in {bb / 1e6:.2f}MB "
          f"({bb / nb:.3f} bytes/param, NF4 + double quant)")
    print(f"trainable:   {ab / 1e3:.1f}KB of packed-skew adapters")

    loader = ShardedLoader(SyntheticSpec(vocab_size=512, seq_len=64,
                                         noise=0.05), global_batch=8, seed=1)
    out = run_training(model, run, loader)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    assert out["losses"][-1] < out["losses"][0]
    print("OK")


if __name__ == "__main__":
    main()
