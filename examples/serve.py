"""Batched serving with an unmerged OFTv2 adapter: prefill a batch of
prompts, decode continuations with the ring KV cache (this is how the paper
evaluates finetuned models -- adapters loaded as extra layers, never merged
into the quantized base).

    PYTHONPATH=src python examples/serve.py
"""
import jax
import jax.numpy as jnp

from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                               RunConfig)
from repro.models import build
from repro.serving import SamplingParams
from repro.train.serving import generate


def main():
    cfg = ModelConfig(name="serve-demo", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=32,
                                          neumann_terms=5),
                    quant=QuantConfig(kind="nf4", block_size=64))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, 256)
    sampling = SamplingParams(max_new_tokens=8)   # temperature=None: greedy
    out = generate(model, params, prompts, sampling=sampling)
    assert out.shape == (4, 20)
    print("prompts -> continuations (greedy):")
    for row in out:
        toks = [int(t) for t in row]
        print(" ", toks[:12], "->", toks[12:])
    # determinism check: greedy decode is reproducible
    out2 = generate(model, params, prompts, sampling=sampling)
    assert jnp.array_equal(out, out2)
    print("OK")


if __name__ == "__main__":
    main()
