"""Repo-level pytest configuration.

* Puts ``src`` on the path so the tier-1 command works without installing.
* Installs a minimal ``hypothesis`` fallback when the real package is not
  importable (hermetic containers without network); CI installs the real
  one from requirements-dev.txt.

Markers (``slow``, ``kernels``) are registered in pyproject.toml
[tool.pytest.ini_options] -- the single source of truth.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))
    from _hypothesis_fallback import install as _install_hypothesis_fallback
    _install_hypothesis_fallback()
