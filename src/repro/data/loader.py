"""Sharded host data loader with checkpointable cursor.

Multi-host contract: each process loads only its slice of the global batch
(process_index-strided), matching the batch's (pod, data) sharding. The
iterator state is a single integer cursor (plus the spec), so resume after
preemption / elastic re-scale is exact: a restarted job with a different
host count re-slices the same global stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import SyntheticCorpus, SyntheticSpec


@dataclasses.dataclass
class LoaderState:
    cursor: int = 0       # global example index of the next batch's start


class ShardedLoader:
    def __init__(self, spec: SyntheticSpec, global_batch: int,
                 seed: int = 0, process_index: int = 0,
                 process_count: int = 1,
                 state: Optional[LoaderState] = None):
        assert global_batch % process_count == 0
        self.corpus = SyntheticCorpus(spec, seed)
        self.global_batch = global_batch
        self.local_batch = global_batch // process_count
        self.process_index = process_index
        self.process_count = process_count
        self.state = state or LoaderState()

    def checkpoint(self) -> dict:
        return {"cursor": self.state.cursor}

    def restore(self, d: dict) -> None:
        self.state.cursor = int(d["cursor"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        base = self.state.cursor
        idxs = [base + self.process_index * self.local_batch + i
                for i in range(self.local_batch)]
        examples = [self.corpus.sample(i) for i in idxs]
        self.state.cursor = base + self.global_batch
        return {k: np.stack([e[k] for e in examples]) for k in examples[0]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
