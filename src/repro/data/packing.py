"""Sequence packing: concatenate variable-length documents into fixed-length
training rows with a segment mask (no cross-document attention leakage is
handled at the loss level via the boundary mask here; full segment-masked
attention is left to the attention mask hook)."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def pack_documents(docs: List[np.ndarray], seq_len: int, pad_id: int = 0
                   ) -> Dict[str, np.ndarray]:
    """Greedy first-fit packing. Returns {"tokens": (N, S), "segment_ids":
    (N, S), "loss_mask": (N, S)} -- loss masked at pad + segment starts."""
    rows: List[List[np.ndarray]] = []
    fills: List[int] = []
    seg_rows: List[List[int]] = []
    for doc in docs:
        doc = doc[:seq_len]
        placed = False
        for i, f in enumerate(fills):
            if f + len(doc) <= seq_len:
                rows[i].append(doc)
                seg_rows[i].append(len(doc))
                fills[i] += len(doc)
                placed = True
                break
        if not placed:
            rows.append([doc])
            seg_rows.append([len(doc)])
            fills.append(len(doc))

    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, dtype=np.int32)
    segs = np.zeros((n, seq_len), dtype=np.int32)
    mask = np.zeros((n, seq_len), dtype=np.float32)
    for i, (parts, lens) in enumerate(zip(rows, seg_rows)):
        off = 0
        for sid, (part, ln) in enumerate(zip(parts, lens), start=1):
            tokens[i, off:off + ln] = part
            segs[i, off:off + ln] = sid
            mask[i, off + 1:off + ln] = 1.0   # first token of a doc: no loss
            off += ln
    return {"tokens": tokens, "segment_ids": segs, "loss_mask": mask}
