from repro.data.loader import LoaderState, ShardedLoader
from repro.data.packing import pack_documents
from repro.data.synthetic import SyntheticCorpus, SyntheticSpec

__all__ = ["LoaderState", "ShardedLoader", "pack_documents",
           "SyntheticCorpus", "SyntheticSpec"]
