"""Deterministic synthetic corpora.

Real datasets (GSM8K / XSum / OpenR1) are not available offline, so every
experiment runs on structured synthetic streams with matched tensor shapes.
The LM stream is *learnable* (a noisy order-2 Markov chain over the vocab):
finetuning must reduce loss below the unigram entropy, which is what the
quality-proxy benchmarks measure (OFTv2 vs LoRA at matched budget).

Determinism contract: sample(i) depends only on (seed, i) => the loader can
resume mid-epoch from just an integer cursor (fault-tolerance story).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    vocab_size: int
    seq_len: int
    kind: str = "lm"          # lm | audio | vlm
    frontend_dim: int = 0
    num_frontend_tokens: int = 0
    num_classes: int = 0
    branching: int = 4        # markov fan-out
    noise: float = 0.1


class SyntheticCorpus:
    """Index-addressable deterministic corpus."""

    def __init__(self, spec: SyntheticSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed
        rng = np.random.default_rng(seed)
        v = spec.vocab_size
        # order-2 markov: next token = f(t-1, t-2) with `branching` choices
        self._succ = rng.integers(0, v, size=(v, spec.branching),
                                  dtype=np.int64)
        self._mix = rng.integers(0, spec.branching, size=(v,), dtype=np.int64)

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        v = self.spec.vocab_size
        out = np.empty(n, dtype=np.int32)
        out[0] = rng.integers(0, v)
        for t in range(1, n):
            prev = out[t - 1]
            if rng.random() < self.spec.noise:
                out[t] = rng.integers(0, v)
            else:
                pick = self._mix[(prev + t) % v]
                out[t] = self._succ[prev, pick]
        return out

    def sample(self, index: int) -> Dict[str, np.ndarray]:
        """One example, fully determined by (seed, index)."""
        sp = self.spec
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + index)
        if sp.kind == "lm":
            return {"tokens": self._tokens(rng, sp.seq_len)}
        if sp.kind == "audio":
            frames = rng.standard_normal(
                (sp.seq_len, sp.frontend_dim)).astype(np.float32)
            # labels correlated with frame content => learnable
            labels = (np.abs(frames.sum(-1) * 7.3).astype(np.int64)
                      % sp.num_classes).astype(np.int32)
            return {"frames": frames, "labels": labels}
        if sp.kind == "vlm":
            n_img = sp.num_frontend_tokens
            patches = rng.standard_normal(
                (n_img, sp.frontend_dim)).astype(np.float32)
            toks = self._tokens(rng, sp.seq_len - n_img)
            return {"tokens": toks, "patches": patches}
        raise ValueError(sp.kind)
