"""Default kernel-launch hook: counts Pallas lowerings and attributes
modeled FLOPs / HBM bytes from ``repro.roofline.kernels``.

``repro.kernels.runtime`` fires ``record_launch`` from each kernel
entry's Python body, which runs at TRACE time (the entries are
jit-wrapped): one firing per distinct-shape lowering, none per
steady-state executed call, and zero ops in any jaxpr.  The hook turns
those firings into counters; the fused-vs-unfused byte counters make the
paper's traffic-reduction claim a live ratio instead of a bench row.
"""
from __future__ import annotations

from repro.kernels import runtime
from repro.obs import metrics as metrics_lib
from repro.roofline.kernels import kernel_cost


def _on_launch(kernel: str, grid, tiles, **shape) -> None:
    reg = metrics_lib.REGISTRY
    if not reg.enabled:
        return
    reg.get("kernel/launches_total").labels(kernel=kernel).inc()
    reg.get("kernel/launch_shapes_total").labels(
        kernel=kernel,
        grid="x".join(str(g) for g in grid),
        tiles=",".join(f"{k}={v}" for k, v in sorted(tiles.items()))).inc()
    cost = kernel_cost(kernel, **shape)
    if cost is None:
        return
    reg.get("kernel/modeled_flops_total").labels(
        kernel=kernel).inc(cost["flops"])
    reg.get("kernel/modeled_hbm_bytes_total").labels(
        kernel=kernel).inc(cost["hbm_bytes"])
    reg.get("kernel/modeled_hbm_bytes_unfused_total").labels(
        kernel=kernel).inc(cost["hbm_bytes_unfused"])


def install() -> None:
    runtime.register_launch_hook(_on_launch)


def uninstall() -> None:
    runtime.unregister_launch_hook(_on_launch)
