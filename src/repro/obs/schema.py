"""The documented metric schema: one spec per metric family, the single
source of truth for

  * call sites -- ``repro.obs.metric(name)`` resolves through this table,
    so an instrumented layer cannot drift from the documentation;
  * exposition completeness -- ``register_all`` pre-registers every
    family, so ``/metrics`` always emits the full schema;
  * the CI gate -- ``benchmarks/check_metrics.py`` fails when a
    documented name is missing from a live smoke run's artifacts (or an
    exported name is undocumented here);
  * the README "Observability" table -- ``python -m repro.obs`` renders
    this module as markdown, and a test pins the README copy to it.

``smoke_required=True`` marks families that MUST carry at least one
sample after the CI train+serve smoke (``--metrics-dir``); the rest are
fault-path metrics that only fire under chaos/restart pressure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.obs import metrics as metrics_lib

LAYERS = ("train", "serving", "kernel", "chaos")


@dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str                      # counter | gauge | histogram
    layer: str                     # one of LAYERS
    help: str
    labels: Tuple[str, ...] = ()
    buckets: Tuple[float, ...] = ()
    smoke_required: bool = False


def _s(name, kind, layer, help, labels=(), smoke=False, buckets=()):
    return MetricSpec(name, kind, layer, help, tuple(labels),
                      tuple(buckets), smoke)


_E = ("engine",)    # per-engine isolation label ("e0", "e1", ...)
_C = ("cache",)     # per-PagedKVCache label ("c0", "c1", ...)

SPECS: Dict[str, MetricSpec] = {s.name: s for s in [
    # ------------------------------------------------------------- train --
    _s("train/step_seconds", "histogram", "train",
       "Wall-clock per optimizer step (includes injected straggler delay)",
       smoke=True),
    _s("train/steps_total", "counter", "train",
       "Optimizer steps completed", smoke=True),
    _s("train/tokens_total", "counter", "train",
       "Tokens consumed (batch x seq per step)", smoke=True),
    _s("train/tokens_per_second", "gauge", "train",
       "Instantaneous training throughput (last step)", smoke=True),
    _s("train/loss", "gauge", "train", "Last step's loss", smoke=True),
    _s("train/grad_norm", "gauge", "train",
       "Last step's global gradient norm", smoke=True),
    _s("train/lr", "gauge", "train",
       "Last step's learning rate", smoke=True),
    _s("train/stragglers_total", "counter", "train",
       "Steps the EWMA StragglerMonitor flagged as slow"),
    _s("train/restarts_total", "counter", "train",
       "Supervisor restarts after DeviceLost/SaveCrashed"),
    _s("train/preemptions_total", "counter", "train",
       "Preemption-guard exits (SIGTERM/chaos preempt)"),
    _s("train/checkpoint_save_seconds", "histogram", "train",
       "Checkpoint write duration (sync portion + async writer)",
       smoke=True),
    _s("train/checkpoint_saves_total", "counter", "train",
       "Checkpoints written", smoke=True),
    _s("train/checkpoint_restore_seconds", "histogram", "train",
       "Checkpoint restore duration (including corrupt-step fallbacks)"),
    _s("train/checkpoint_restores_total", "counter", "train",
       "Checkpoint restores (auto-resume)"),
    _s("oft/rotation_build_seconds", "histogram", "train",
       "Eager Cayley-Neumann rotation builds (serving pool stacking; the "
       "traced in-step build is invisible by design -- it must not "
       "perturb the jaxpr)", smoke=True),
    # ------------------------------------------------------------ kernel --
    _s("kernel/launches_total", "counter", "kernel",
       "Pallas kernel lowerings (trace-time; steady-state executions "
       "reuse the compiled kernel and are free)", ("kernel",), smoke=True),
    _s("kernel/launch_shapes_total", "counter", "kernel",
       "Lowerings by grid/tile shape", ("kernel", "grid", "tiles"),
       smoke=True),
    _s("kernel/modeled_flops_total", "counter", "kernel",
       "Modeled FLOPs attributed per lowering (roofline model)",
       ("kernel",), smoke=True),
    _s("kernel/modeled_hbm_bytes_total", "counter", "kernel",
       "Modeled HBM bytes for the fused kernel (roofline model)",
       ("kernel",), smoke=True),
    _s("kernel/modeled_hbm_bytes_unfused_total", "counter", "kernel",
       "Modeled HBM bytes the same math would move unfused -- the live "
       "fused-vs-unfused traffic claim", ("kernel",), smoke=True),
    # ------------------------------------------------------------- chaos --
    _s("chaos/faults_fired_total", "counter", "chaos",
       "Injected faults by kind (preempt, device_loss, straggler, "
       "save_crash, corrupt_latest)", ("kind",), smoke=True),
    # ----------------------------------------------------------- serving --
    _s("serving/ticks_total", "counter", "serving",
       "Scheduler ticks", _E, smoke=True),
    _s("serving/tick_seconds", "histogram", "serving",
       "Wall-clock per engine tick", _E, smoke=True),
    _s("serving/tick_utilization", "gauge", "serving",
       "Active slots / n_slots at the last tick", _E, smoke=True),
    _s("serving/ttft_seconds", "histogram", "serving",
       "Submit -> first token (queueing + prefill)", _E, smoke=True),
    _s("serving/latency_seconds", "histogram", "serving",
       "Submit -> finish, per request", _E, smoke=True),
    _s("serving/queue_wait_seconds", "histogram", "serving",
       "Submit -> slot admission", _E, smoke=True),
    _s("serving/requests_submitted_total", "counter", "serving",
       "Requests accepted by submit()", _E, smoke=True),
    _s("serving/requests_finished_total", "counter", "serving",
       "Finished requests by reason (length, stop, deadline, cancelled)",
       ("engine", "reason"), smoke=True),
    _s("serving/tokens_generated_total", "counter", "serving",
       "Generated tokens (prompt excluded)", _E, smoke=True),
    _s("serving/prefill_rows_total", "counter", "serving",
       "Paged-tick batch rows spent prefilling prompt chunks", _E,
       smoke=True),
    _s("serving/decode_rows_total", "counter", "serving",
       "Paged-tick batch rows spent decoding one token", _E, smoke=True),
    _s("serving/inflight", "gauge", "serving",
       "Requests holding a slot", _E, smoke=True),
    _s("serving/pending", "gauge", "serving",
       "Requests queued for admission", _E, smoke=True),
    _s("serving/requeued", "gauge", "serving",
       "Preempted requests waiting out their backoff", _E, smoke=True),
    _s("serving/preemptions_total", "counter", "serving",
       "Slots evicted under block-pool pressure", _E, smoke=True),
    _s("serving/retries_total", "counter", "serving",
       "Requeued requests readmitted after backoff", _E, smoke=True),
    _s("serving/cancelled_total", "counter", "serving",
       "Explicit cancel() calls", _E, smoke=True),
    _s("serving/deadline_expired_total", "counter", "serving",
       "Requests cancelled by their deadline_s budget", _E, smoke=True),
    _s("serving/kv/blocks_free", "gauge", "serving",
       "Free blocks in the paged pool", _E, smoke=True),
    _s("serving/kv/blocks_used", "gauge", "serving",
       "Blocks held by live sequences", _E, smoke=True),
    _s("serving/kv/blocks_cached", "gauge", "serving",
       "Blocks resident in the prefix cache", _E, smoke=True),
    _s("serving/kv/blocks_seized", "gauge", "serving",
       "Blocks seized by chaos pressure injection", _E, smoke=True),
    _s("serving/kv/blocks_committed", "gauge", "serving",
       "Worst-case blocks reserved by admitted requests", _E, smoke=True),
    _s("serving/kv/capacity_blocks", "gauge", "serving",
       "Usable pool capacity (excludes null block and seized)", _E,
       smoke=True),
    _s("serving/kv/prefix_shared_blocks_total", "counter", "serving",
       "Full KV blocks adopted zero-copy from the prefix cache", _C,
       smoke=True),
    _s("serving/kv/prefix_partial_tokens_total", "counter", "serving",
       "Tokens copied from a partially-matching cached tail block", _C,
       smoke=True),
    _s("serving/kv/cow_copies_total", "counter", "serving",
       "Copy-on-write block copies (partial tail adoption)", _C,
       smoke=True),
    _s("serving/kv/evictions_total", "counter", "serving",
       "Prefix-cache blocks LRU-evicted under pressure", _C, smoke=True),
]}


def register_all(registry=None) -> None:
    """Pre-register every documented family (no samples) so exposition
    and ``/metrics`` always carry the complete schema."""
    reg = registry if registry is not None else metrics_lib.REGISTRY
    for spec in SPECS.values():
        if spec.kind == "histogram":
            reg.histogram(spec.name, spec.help, spec.labels,
                          spec.buckets or metrics_lib.LATENCY_BUCKETS)
        elif spec.kind == "counter":
            reg.counter(spec.name, spec.help, spec.labels)
        else:
            reg.gauge(spec.name, spec.help, spec.labels)


def markdown_table() -> str:
    """The README "Observability" metric table, generated -- a test pins
    the README copy to this exact text."""
    lines = ["| metric | type | labels | layer | meaning |",
             "|---|---|---|---|---|"]
    for name in sorted(SPECS):
        s = SPECS[name]
        lbl = ", ".join(s.labels) if s.labels else "--"
        lines.append(f"| `{s.name}` | {s.kind} | {lbl} | {s.layer} "
                     f"| {s.help} |")
    return "\n".join(lines)
