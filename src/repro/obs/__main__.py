"""``python -m repro.obs``: print the documented metric schema as the
markdown table the README "Observability" section embeds (a test pins
the two copies to each other)."""
from repro.obs.schema import markdown_table

if __name__ == "__main__":
    print(markdown_table())
