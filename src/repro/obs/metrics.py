"""Typed metrics registry: Counter / Gauge / Histogram families with
labels, Prometheus-style text exposition, and JSONL snapshot dumps.

Zero dependencies beyond the stdlib.  All instruments are host-side --
nothing here ever enters a jaxpr (tests/test_obs.py pins that down by
comparing traced jaxprs with collectors on vs off).

Naming convention: canonical metric names use the repo's slash-separated
style (``serving/ttft_seconds``); exposition sanitizes ``/`` -> ``_`` so
the output is valid Prometheus text format.

Disabled semantics (``registry.enabled = False``): every mutation --
``inc``/``set``/``observe`` -- is dropped entirely, values recorded
while enabled persist, and re-enabling resumes counting.  A hypothesis
property test asserts enable -> disable -> enable never leaks state.
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

# Default bucket edges for latency-style histograms (seconds).  Chosen to
# cover everything from a sub-ms serving tick on real accelerators to a
# multi-second interpret-mode CPU step.
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

_NAME_OK = frozenset("abcdefghijklmnopqrstuvwxyz"
                     "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_/:")


def sanitize(name: str) -> str:
    """Canonical slash name -> Prometheus-legal metric name."""
    return name.replace("/", "_").replace(":", "_")


def _check_name(name: str) -> None:
    if not name or not set(name) <= _NAME_OK or name[0].isdigit():
        raise ValueError(f"bad metric name {name!r}")


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


class _Child:
    """One labeled instrument inside a family.  The registry reference is
    cached flat (``_reg``) because the enabled check runs on every
    mutation -- the instrumented layers' hot paths -- and a property
    chasing ``family.registry.enabled`` measurably widens the per-tick
    telemetry cost (benchmarks/obs_bench.py gates it under 2%)."""

    __slots__ = ("_family", "_reg", "labels")

    def __init__(self, family: "Family", labels: Dict[str, str]):
        self._family = family
        self._reg = family.registry
        self.labels = labels

    @property
    def _enabled(self) -> bool:
        return self._reg.enabled


class Counter(_Child):
    __slots__ = ("_value",)

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        if amount < 0:
            raise ValueError("counters only go up")
        with self._reg._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Child):
    __slots__ = ("_value",)

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self._value = 0.0

    def set(self, value: float) -> None:
        if self._reg.enabled:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if self._reg.enabled:
            with self._reg._lock:
                self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value


class Histogram(_Child):
    """Fixed-bucket histogram with Prometheus ``le`` (<=) semantics:
    ``counts[i]`` counts observations ``v <= edges[i]``; the final slot
    is the +Inf overflow bucket."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self.edges = family.buckets
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if not self._reg.enabled:
            return
        v = float(value)
        with self._reg._lock:
            self.counts[bisect.bisect_left(self.edges, v)] += 1
            self.sum += v
            self.count += 1

    def quantile(self, q: float) -> float:
        """Approximate quantile by linear interpolation inside the bucket
        holding the q-th observation.  The +Inf bucket clamps to the last
        finite edge; an empty histogram returns 0.0."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self.edges[-1]
                if i >= len(self.edges):
                    return hi
                frac = (target - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self.edges[-1]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """A named metric family: one instrument per distinct label set.
    Label-less families proxy ``inc``/``set``/``observe``/``value`` to
    their single default child so call sites stay terse."""

    def __init__(self, registry: "Registry", name: str, kind: str,
                 help: str = "", labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        _check_name(name)
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        if kind == "histogram":
            b = tuple(float(x) for x in buckets)
            if not b or list(b) != sorted(set(b)):
                raise ValueError(f"{name}: bucket edges must be strictly "
                                 f"increasing and non-empty, got {buckets}")
            self.buckets = b
        else:
            self.buckets = ()
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[Tuple[str, str], ...], _Child] = {}

    def labels(self, **labels: str):
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labels)}")
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            with self.registry._lock:
                child = self._children.setdefault(
                    key, _KINDS[self.kind](
                        self, {k: str(v) for k, v in labels.items()}))
        return child

    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.labelnames}; call .labels(...) first")
        return self.labels()

    # label-less convenience proxies
    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def set(self, value: float) -> None:
        self._default().set(value)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    @property
    def value(self) -> float:
        return self._default().value

    def quantile(self, q: float) -> float:
        return self._default().quantile(q)

    def children(self) -> Iterable[_Child]:
        return list(self._children.values())

    def clear(self) -> None:
        self._children.clear()


class Registry:
    """get-or-create registry of metric families.  ``enabled=False``
    turns every mutation into a strict no-op (reads still work)."""

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._lock = threading.RLock()
        self.enabled = True
        self._indices: Dict[str, int] = {}

    # ------------------------------------------------------------ factories --
    def _get_or_create(self, name, kind, help, labelnames, buckets):
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or (labelnames is not None
                                    and tuple(labelnames) != fam.labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {kind}"
                    f"/{tuple(labelnames or ())} but exists as "
                    f"{fam.kind}/{fam.labelnames}")
            return fam
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(self, name, kind, help or "",
                             tuple(labelnames or ()),
                             buckets or LATENCY_BUCKETS)
                self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._get_or_create(name, "counter", help, labels, None)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._get_or_create(name, "gauge", help, labels, None)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Family:
        return self._get_or_create(name, "histogram", help, labels, buckets)

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    def families(self) -> Iterable[Family]:
        return list(self._families.values())

    def next_index(self, kind: str) -> int:
        """Monotonic per-kind instance id, e.g. ``engine="e3"`` labels --
        the isolation mechanism letting many engines share one registry."""
        with self._lock:
            i = self._indices.get(kind, 0)
            self._indices[kind] = i + 1
            return i

    # ----------------------------------------------------------- lifecycle --
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all recorded values and children (families stay
        registered, so exposition completeness is unaffected)."""
        with self._lock:
            for fam in self._families.values():
                fam.clear()
            self._indices.clear()

    # -------------------------------------------------------------- export --
    def exposition(self) -> str:
        """Prometheus text format.  Every registered family is emitted
        (HELP/TYPE) even with no samples yet, so ``/metrics`` always
        documents the full schema."""
        out = []
        for name in sorted(self._families):
            fam = self._families[name]
            pname = sanitize(name)
            out.append(f"# HELP {pname} {fam.help}")
            out.append(f"# TYPE {pname} {fam.kind}")
            for child in fam.children():
                lbl = ",".join(f'{k}="{_escape(v)}"'
                               for k, v in sorted(child.labels.items()))
                if fam.kind == "histogram":
                    cum = 0
                    for edge, c in zip(fam.buckets, child.counts):
                        cum += c
                        le = ((f"{lbl}," if lbl else "")
                              + f'le="{edge:g}"')
                        out.append(f"{pname}_bucket{{{le}}} {cum}")
                    cum += child.counts[-1]
                    le = (f"{lbl}," if lbl else "") + 'le="+Inf"'
                    out.append(f"{pname}_bucket{{{le}}} {cum}")
                    brace = f"{{{lbl}}}" if lbl else ""
                    out.append(f"{pname}_sum{brace} {child.sum:g}")
                    out.append(f"{pname}_count{brace} {child.count}")
                else:
                    brace = f"{{{lbl}}}" if lbl else ""
                    out.append(f"{pname}{brace} {child.value:g}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """One JSON-ready dict of every family + sample (canonical
        names, not sanitized)."""
        metrics = []
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for child in fam.children():
                s: dict = {"labels": dict(child.labels)}
                if fam.kind == "histogram":
                    s.update(buckets=list(fam.buckets),
                             counts=list(child.counts),
                             sum=child.sum, count=child.count)
                else:
                    s["value"] = child.value
                samples.append(s)
            metrics.append({"name": name, "type": fam.kind,
                            "help": fam.help,
                            "labelnames": list(fam.labelnames),
                            "samples": samples})
        return {"ts": time.time(), "metrics": metrics}

    def dump_jsonl(self, path: str) -> None:
        """Append one snapshot line -- restarted runs append to the same
        file, so telemetry stitches across restarts."""
        with open(path, "a") as f:
            f.write(json.dumps(self.snapshot()) + "\n")


# The process-wide default registry every instrumented layer records into.
REGISTRY = Registry()
