"""Stdlib ``/metrics`` endpoint: a background ``http.server`` exposing
the default registry in Prometheus text format (``--metrics-port``).

    server = serve_metrics(port)        # port=0 -> ephemeral
    ... curl http://localhost:<server.port>/metrics ...
    server.close()
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import metrics as metrics_lib


class _Handler(BaseHTTPRequestHandler):
    def do_GET(self):                                       # noqa: N802
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_error(404)
            return
        body = metrics_lib.REGISTRY.exposition().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):                      # silence stderr
        pass


class MetricsServer:
    def __init__(self, port: int = 0, host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_metrics(port: int = 0, host: str = "127.0.0.1") -> MetricsServer:
    return MetricsServer(port, host)
