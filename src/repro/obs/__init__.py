"""Unified telemetry: metrics registry + span tracer + kernel profiling
hooks, shared by the train loop, the serving engine, and the Pallas
kernel layer (ISSUE-8).

    from repro import obs

    obs.metric("train/steps_total").inc()
    with obs.span("engine.step", tick=3):
        ...
    obs.dump("/tmp/metrics")            # metrics.jsonl + .prom + spans.jsonl

Everything is host-side and zero-dependency; disabled collectors
(``obs.disable()``) are strict no-ops that leave every jaxpr untouched
(tests/test_obs.py compares traced jaxprs with collectors on vs off).
``obs.metric(name)`` resolves through the documented schema
(``repro.obs.schema``), so instrumented call sites cannot drift from the
README metric table or the ``benchmarks/check_metrics.py`` CI gate.
"""
from __future__ import annotations

import os

from repro.obs import schema as schema  # noqa: PLC0414 (re-export)
from repro.obs.http import MetricsServer, serve_metrics  # noqa: F401
from repro.obs.metrics import (LATENCY_BUCKETS, REGISTRY,  # noqa: F401
                               Registry)
from repro.obs.trace import TRACER, Tracer  # noqa: F401
from repro.obs import kernels as _kernel_hooks

schema.register_all(REGISTRY)
_kernel_hooks.install()

span = TRACER.span
event = TRACER.event


def metric(name: str):
    """The schema-documented family for ``name`` (the only way the
    instrumented layers reach the registry -- undocumented names fail
    loudly here, not silently in exposition)."""
    fam = REGISTRY.get(name)
    if fam is None:
        if name not in schema.SPECS:
            raise KeyError(f"metric {name!r} is not in the documented "
                           f"schema (repro/obs/schema.py)")
        schema.register_all(REGISTRY)
        fam = REGISTRY.get(name)
    return fam


def enable() -> None:
    REGISTRY.enable()
    TRACER.enabled = True


def disable() -> None:
    REGISTRY.disable()
    TRACER.enabled = False


def enabled() -> bool:
    return REGISTRY.enabled


def next_index(kind: str) -> int:
    return REGISTRY.next_index(kind)


def record_train_step(dt: float, loss: float, grad_norm: float, lr: float,
                      tokens: int) -> None:
    """Per-step train telemetry, shared by ``train/loop.py`` and the
    ``obs_bench`` overhead measurement (so the bench times exactly what
    the loop pays)."""
    metric("train/step_seconds").observe(dt)
    metric("train/steps_total").inc()
    metric("train/loss").set(loss)
    metric("train/grad_norm").set(grad_norm)
    metric("train/lr").set(lr)
    if tokens:
        metric("train/tokens_total").inc(tokens)
        if dt > 0:
            metric("train/tokens_per_second").set(tokens / dt)


def dump(directory: str) -> dict:
    """Write/append the telemetry artifacts under ``directory``:

      metrics.jsonl -- one snapshot object appended per dump (restarted
                       runs append, so telemetry stitches across restarts)
      metrics.prom  -- current Prometheus text exposition (rewritten)
      spans.jsonl   -- completed spans appended (ring buffer drained)

    Returns ``{"spans": n, "families": m}``."""
    os.makedirs(directory, exist_ok=True)
    REGISTRY.dump_jsonl(os.path.join(directory, "metrics.jsonl"))
    with open(os.path.join(directory, "metrics.prom"), "w") as f:
        f.write(REGISTRY.exposition())
    n = TRACER.export_jsonl(os.path.join(directory, "spans.jsonl"))
    return {"spans": n, "families": len(list(REGISTRY.families()))}
