"""Span tracer: nested wall-clock spans with a ring buffer, JSONL export,
and an optional ``jax.profiler`` bridge.

    with TRACER.span("engine.step", tick=3):
        ...

Spans nest through a thread-local stack (each records its parent's id and
its own depth) and land in a bounded ring buffer at exit, in completion
order.  ``export_jsonl`` / ``drain`` serialize them; ``start_profile``
additionally opens a ``jax.profiler`` trace in a directory and wraps every
span in a ``TraceAnnotation`` so host spans line up with device timelines
in TensorBoard/Perfetto.

Like the metrics registry, a disabled tracer drops everything -- the
context manager still runs the body, it just records nothing.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import List, Optional

SPAN_FIELDS = ("name", "span_id", "parent_id", "depth", "ts", "dur", "attrs")


class _Span:
    """Hand-rolled context manager: ``span()`` sits on the per-tick hot
    path of the serving engine, and a generator-based ``@contextmanager``
    costs several microseconds per entry -- enough to flip the < 2%
    overhead gate (benchmarks/obs_bench.py) on its own."""

    __slots__ = ("_tracer", "name", "attrs", "sid", "parent", "_ann",
                 "ts", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tr = self._tracer
        if not tr.enabled:
            self.sid = None
            return None
        stack = tr._stack()
        self.sid = next(tr._ids)
        self.parent = stack[-1] if stack else 0
        stack.append(self.sid)
        self._ann = None
        if tr._profile_dir is not None:
            try:
                import jax.profiler
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:                               # noqa: BLE001
                self._ann = None
        self.ts = time.time()
        self.t0 = time.perf_counter()
        return self.sid

    def __exit__(self, *exc):
        if self.sid is None:
            return False
        tr = self._tracer
        dur = time.perf_counter() - self.t0
        if self._ann is not None:
            self._ann.__exit__(None, None, None)
        stack = tr._stack()
        if stack:
            stack.pop()
        rec = {"name": self.name, "span_id": self.sid,
               "parent_id": self.parent, "depth": len(stack),
               "ts": self.ts, "dur": dur, "attrs": self.attrs}
        with tr._lock:
            tr._buf.append(rec)
        return False


class Tracer:
    def __init__(self, capacity: int = 4096):
        self.enabled = True
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._profile_dir: Optional[str] = None

    # ------------------------------------------------------------- recording --
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, **attrs) -> _Span:
        """Record one nested wall-clock span around the body."""
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """A zero-duration span: chaos faults, stragglers, restarts."""
        if not self.enabled:
            return
        stack = self._stack()
        rec = {"name": name, "span_id": next(self._ids),
               "parent_id": stack[-1] if stack else 0,
               "depth": len(stack), "ts": time.time(), "dur": 0.0,
               "attrs": attrs}
        with self._lock:
            self._buf.append(rec)

    # --------------------------------------------------------------- export --
    def spans(self) -> List[dict]:
        with self._lock:
            return list(self._buf)

    def drain(self) -> List[dict]:
        """Return all buffered spans and clear the buffer (so repeated
        dumps append without duplicating)."""
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
        return out

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def export_jsonl(self, path: str, drain: bool = True) -> int:
        """Append one JSON object per span; returns the span count."""
        spans = self.drain() if drain else self.spans()
        with open(path, "a") as f:
            for rec in spans:
                f.write(json.dumps(rec) + "\n")
        return len(spans)

    # ------------------------------------------------------- profiler bridge --
    def start_profile(self, profile_dir: str) -> bool:
        """Open a ``jax.profiler`` trace under ``profile_dir`` (the
        ``--profile-dir`` flag); spans become TraceAnnotations until
        ``stop_profile``.  Returns False when the profiler is unavailable
        (the tracer still records spans normally)."""
        try:
            import jax.profiler
            jax.profiler.start_trace(profile_dir)
        except Exception:                                   # noqa: BLE001
            return False
        self._profile_dir = profile_dir
        return True

    def stop_profile(self) -> None:
        if self._profile_dir is None:
            return
        self._profile_dir = None
        try:
            import jax.profiler
            jax.profiler.stop_trace()
        except Exception:                                   # noqa: BLE001
            pass


# Process-wide default tracer (repro.obs re-exports `span`).
TRACER = Tracer()
