"""Frozen-weight quantization dispatch.

A quantized linear is a dict pytree (jit-traversable); which keys exist is
static per QuantConfig, so jit caching is stable. ``quantize_linear`` /
``dequantize_linear`` are the only entry points the model layers use -- this
is what makes OFTv2 "quantization-agnostic" (paper §4): the adapter never
looks inside the quant state.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import QuantConfig


def quantize_linear(w, qcfg: QuantConfig, act_scales=None) -> dict:
    if qcfg.kind == "none":
        return {"w": w}
    if qcfg.kind == "nf4":
        from repro.quant import nf4
        return nf4.quantize(w, qcfg)
    if qcfg.kind == "awq":
        from repro.quant import awq
        return awq.quantize(w, qcfg, act_scales=act_scales)
    if qcfg.kind == "int8":
        from repro.quant import int8
        return int8.quantize(w, qcfg)
    raise ValueError(f"unknown quant kind {qcfg.kind}")


def dequantize_linear(qstate: dict, qcfg: QuantConfig, dtype) -> jnp.ndarray:
    if "w" in qstate:
        return qstate["w"].astype(dtype)
    if qcfg.kind == "nf4":
        from repro.quant import nf4
        return nf4.dequantize(qstate, qcfg, dtype)
    if qcfg.kind == "awq":
        from repro.quant import awq
        return awq.dequantize(qstate, qcfg, dtype)
    if qcfg.kind == "int8":
        from repro.quant import int8
        return int8.dequantize(qstate, qcfg, dtype)
    raise ValueError(f"unknown quant kind {qcfg.kind}")


def storage_bytes(qstate: dict) -> int:
    """Actual bytes held by a (possibly quantized) linear -- memory accounting
    for the Fig-4 benchmark."""
    total = 0
    for leaf in qstate.values():
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        elif isinstance(leaf, dict):
            total += storage_bytes(leaf)
    return total
