"""Per-output-channel symmetric int8 weight quantization (used for gradient
compression ablations and as the cheapest quant tier in Fig-4)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import QuantConfig


def quantize(w: jnp.ndarray, qcfg: QuantConfig) -> dict:
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)    # (d_out,)
    scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]), -127, 127)
    return {"int8_codes": q.astype(jnp.int8),
            "int8_scale": scale.astype(jnp.float32)}


def dequantize(qstate: dict, qcfg: QuantConfig, dtype) -> jnp.ndarray:
    return (qstate["int8_codes"].astype(jnp.float32)
            * qstate["int8_scale"][None, :]).astype(dtype)
