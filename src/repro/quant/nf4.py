"""NormalFloat4 quantization with double quantization (QLoRA, Dettmers et al.
2023) -- reimplemented in pure JAX (the paper uses bitsandbytes CUDA).

Layout decisions (TPU/sharding-aware, see DESIGN.md §3):
  * absmax blocks run along the *in-features* axis per output column:
    codes (d_in//2, d_out) uint8 (two 4-bit codes per byte, in-dim pairs),
    absmax (d_in//block, d_out). Both shard exactly like the bf16 weight
    (in -> data/FSDP, out -> model/TP) with no extra resharding.
  * double quantization compresses absmax to int8 with per-group fp32 scales
    and a global fp32 offset (QLoRA's scheme), applied when the absmax count
    divides the group size; otherwise absmax stays fp32 (same numerics).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config.base import QuantConfig

# Canonical NF4 code values (quantiles of N(0,1), normalized; QLoRA Appx E).
NF4_TABLE = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
], dtype=np.float32)


def _nearest_code(x: jnp.ndarray) -> jnp.ndarray:
    """Map values in [-1, 1] to nearest NF4 code index (uint8 in [0, 15])."""
    table = jnp.asarray(NF4_TABLE)
    # boundaries are midpoints between adjacent code values
    bounds = (table[1:] + table[:-1]) / 2.0
    return jnp.searchsorted(bounds, x, side="left").astype(jnp.uint8)


def quantize(w: jnp.ndarray, qcfg: QuantConfig) -> dict:
    """w (d_in, d_out) float -> NF4 qstate dict."""
    d_in, d_out = w.shape
    bs = qcfg.block_size
    if d_in % (2 * bs) and d_in % bs:
        raise ValueError(f"d_in={d_in} not divisible by nf4 block {bs}")
    wf = w.astype(jnp.float32).reshape(d_in // bs, bs, d_out)
    absmax = jnp.max(jnp.abs(wf), axis=1)                       # (nb, d_out)
    safe = jnp.where(absmax == 0, 1.0, absmax)
    normed = wf / safe[:, None, :]
    idx = _nearest_code(normed).reshape(d_in, d_out)
    packed = (idx[0::2, :] << 4) | idx[1::2, :]                 # (d_in//2, d_out)

    out = {"nf4_codes": packed}
    nb = absmax.shape[0]
    db = qcfg.double_block
    if qcfg.double_quant and d_out % db == 0:
        # second-level quantization: int8 absmax with per-(row, out-group)
        # fp32 scales + one global offset. Grouping runs along d_out so both
        # tensors shard exactly like the weight (DESIGN.md §3).
        offset = jnp.mean(absmax)
        centered = (absmax - offset).reshape(nb, d_out // db, db)
        gmax = jnp.max(jnp.abs(centered), axis=2)
        gsafe = jnp.where(gmax == 0, 1.0, gmax)
        q8 = jnp.clip(jnp.round(centered / gsafe[:, :, None] * 127.0),
                      -127, 127)
        out["absmax_q8"] = q8.reshape(nb, d_out).astype(jnp.int8)
        out["absmax_scale"] = (gsafe / 127.0).astype(jnp.float32)  # (nb, groups)
        out["absmax_offset"] = offset.astype(jnp.float32)
    else:
        out["absmax"] = absmax.astype(jnp.float32)
    return out


def _absmax(qstate: dict, nb: int, d_out: int) -> jnp.ndarray:
    if "absmax" in qstate:
        return qstate["absmax"]
    scale = qstate["absmax_scale"]
    db = d_out // scale.shape[1]
    q8 = qstate["absmax_q8"].astype(jnp.float32).reshape(nb, d_out // db, db)
    return (q8 * scale[:, :, None] + qstate["absmax_offset"]).reshape(nb, d_out)


def absmax_fp32(qstate: dict, qcfg: QuantConfig) -> jnp.ndarray:
    """fp32 absmax (nb, d_out) from a (possibly double-quantized) NF4 state.

    The fused QOFT kernel (repro.kernels.qoft_linear_fused) consumes codes +
    fp32 absmax directly; decoding the (tiny) double-quantized absmax happens
    here, outside the kernel, so the kernel sees one layout."""
    d_in = qstate["nf4_codes"].shape[0] * 2
    d_out = qstate["nf4_codes"].shape[1]
    return _absmax(qstate, d_in // qcfg.block_size, d_out)


def dequantize(qstate: dict, qcfg: QuantConfig, dtype) -> jnp.ndarray:
    packed = qstate["nf4_codes"]
    d_in2, d_out = packed.shape
    d_in = d_in2 * 2
    bs = qcfg.block_size
    hi = (packed >> 4).astype(jnp.int32)
    lo = (packed & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=1).reshape(d_in, d_out)
    vals = jnp.take(jnp.asarray(NF4_TABLE), idx, axis=0)        # fp32
    absmax = _absmax(qstate, d_in // bs, d_out)
    w = vals.reshape(d_in // bs, bs, d_out) * absmax[:, None, :]
    return w.reshape(d_in, d_out).astype(dtype)


def roundtrip_error(w: jnp.ndarray, qcfg: QuantConfig) -> jnp.ndarray:
    """max |w - dq(q(w))| -- used by tests and the requant-error benchmark."""
    q = quantize(w, qcfg)
    return jnp.max(jnp.abs(w - dequantize(q, qcfg, w.dtype)))
