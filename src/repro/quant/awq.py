"""AWQ-style int4 group-wise quantization (Lin et al. 2024), pure JAX.

Activation-aware: salient input channels (large mean |x|) get their weight
rows scaled up before quantization (less relative error) and the inverse
scale folded into the activation path. With no real calibration data on this
container, act_scales defaults to ones (plain groupwise int4) and the
synthetic-calibration helper below reproduces the mechanism.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import QuantConfig


def synthetic_act_scales(key, d_in: int, alpha: float = 0.5) -> jnp.ndarray:
    """Log-normal per-channel activation magnitudes -> AWQ scales s = m^alpha
    normalized to geometric mean 1 (the AWQ grid-search optimum surrogate)."""
    import jax
    mags = jnp.exp(jax.random.normal(key, (d_in,)) * 0.5)
    s = mags ** alpha
    return s / jnp.exp(jnp.mean(jnp.log(s)))


def quantize(w: jnp.ndarray, qcfg: QuantConfig, act_scales=None) -> dict:
    d_in, d_out = w.shape
    g = qcfg.group_size
    if d_in % g:
        raise ValueError(f"d_in={d_in} not divisible by awq group {g}")
    if act_scales is None:
        act_scales = jnp.ones((d_in,), dtype=jnp.float32)
    ws = w.astype(jnp.float32) * act_scales[:, None]
    wg = ws.reshape(d_in // g, g, d_out)
    wmax = jnp.max(wg, axis=1)
    wmin = jnp.min(wg, axis=1)
    scale = jnp.maximum((wmax - wmin) / 15.0, 1e-8)             # (ng, d_out)
    zero = jnp.clip(jnp.round(-wmin / scale), 0, 15)            # (ng, d_out)
    q = jnp.clip(jnp.round(wg / scale[:, None, :] + zero[:, None, :]), 0, 15)
    idx = q.reshape(d_in, d_out).astype(jnp.uint8)
    packed = (idx[0::2, :] << 4) | idx[1::2, :]
    return {
        "awq_codes": packed,
        "awq_scale": scale.astype(jnp.float32),
        "awq_zero": zero.astype(jnp.int8),
        "awq_act_scale": act_scales.astype(jnp.float32),
    }


def dequantize(qstate: dict, qcfg: QuantConfig, dtype) -> jnp.ndarray:
    packed = qstate["awq_codes"]
    d_in = packed.shape[0] * 2
    d_out = packed.shape[1]
    g = qcfg.group_size
    hi = (packed >> 4).astype(jnp.float32)
    lo = (packed & 0xF).astype(jnp.float32)
    idx = jnp.stack([hi, lo], axis=1).reshape(d_in, d_out)
    wg = idx.reshape(d_in // g, g, d_out)
    w = (wg - qstate["awq_zero"].astype(jnp.float32)[:, None, :]) \
        * qstate["awq_scale"][:, None, :]
    w = w.reshape(d_in, d_out) / qstate["awq_act_scale"][:, None]
    return w.astype(dtype)
