from repro.quant.common import dequantize_linear, quantize_linear, storage_bytes

__all__ = ["quantize_linear", "dequantize_linear", "storage_bytes"]
