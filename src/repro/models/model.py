"""Public model API used by train/serve/dry-run:

    build(run_cfg)            -> Model (defs + jit-ready fns)
    model.init(key)           -> {"base":..., "adapter":...}
    model.abstract_params()   -> same tree of ShapeDtypeStruct
    model.param_specs(rules)  -> same tree of PartitionSpec
    model.loss(params, batch)            -> scalar loss, metrics   (train)
    model.forward(params, batch)         -> logits                 (prefill)
    model.prefill(params, batch)         -> logits, caches
    model.decode_step(params, batch)     -> logits, new caches     (decode)
    model.init_cache / abstract_cache    -> KV / SSM decode state

Batch schemas (synthetic data pipeline + dry-run input_specs):
    LM:      {"tokens": (B,S) i32}                (labels = shifted tokens)
    VLM:     {"tokens": (B,S_text) i32, "patches": (B,N_img,frontend_dim)}
    audio:   {"frames": (B,S,frontend_dim), "labels": (B,S) i32}
    decode:  {"tokens": (B,1) i32, "positions": (B,1) i32, "caches": ...,
              "cache_index": (B,) i32}
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ParallelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import spec as spec_mod
from repro.models import transformer as tfm
from repro.models.transformer import Statics


def pick_ep(cfg: ModelConfig, pcfg: Optional[ParallelConfig]) -> bool:
    if cfg.num_experts <= 0 or pcfg is None:
        return False
    if pcfg.moe_layout == "ep":
        return True
    if pcfg.moe_layout == "tp":
        return False
    # auto: EP when experts divide the data axis (or vice versa)
    ds = pcfg.data_axis_size
    return ds > 1 and (cfg.num_experts % ds == 0)


@dataclass
class Model:
    cfg: ModelConfig
    run: RunConfig
    base_defs: dict
    adapter_defs: dict
    ep: bool
    constrain: Callable = tfm._noop_constrain
    shard: Optional[Any] = None    # MeshContext: mesh-native fused kernels

    # ------------------------------------------------------------ params --
    def statics(self, mode: str, remat: bool = False,
                adapter_id=None, block_tables=None) -> Statics:
        return Statics(cfg=self.cfg, acfg=self.run.adapter,
                       qcfg=self.run.quant, ep=self.ep,
                       constrain=self.constrain, remat=remat, mode=mode,
                       adapter_id=adapter_id, shard=self.shard,
                       block_tables=block_tables)

    def init(self, key) -> dict:
        pd = jnp.dtype(self.cfg.param_dtype)
        out = {"base": spec_mod.init_tree(key, self.base_defs, pd)}
        out["adapter"] = spec_mod.init_tree(
            jax.random.fold_in(key, 1), self.adapter_defs, jnp.float32) \
            if self.adapter_defs else {}
        return out

    def abstract_params(self) -> dict:
        pd = jnp.dtype(self.cfg.param_dtype)
        return {
            "base": spec_mod.abstract_tree(self.base_defs, pd),
            "adapter": spec_mod.abstract_tree(self.adapter_defs, jnp.float32)
            if self.adapter_defs else {},
        }

    def param_specs(self, rules) -> dict:
        return {
            "base": spec_mod.spec_tree(self.base_defs, rules),
            "adapter": spec_mod.spec_tree(self.adapter_defs, rules)
            if self.adapter_defs else {},
        }

    def param_counts(self) -> Dict[str, int]:
        return {
            "base": spec_mod.count_tree(self.base_defs),
            "adapter": spec_mod.count_tree(self.adapter_defs)
            if self.adapter_defs else 0,
        }

    # ----------------------------------------------------------- forward --
    def _embed(self, st: Statics, params, batch):
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            x = tfm.project_frontend(st, params, batch["frames"])
        elif cfg.frontend == "vision_patches":
            xt = tfm.embed_tokens(st, params, batch["tokens"])
            xi = tfm.project_frontend(st, params, batch["patches"])
            x = jnp.concatenate([xi, xt], axis=1)
        else:
            x = tfm.embed_tokens(st, params, batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        return x, positions

    def forward(self, params, batch, mode: str = "train",
                remat: bool = False):
        """Full-sequence forward. Returns (logits, aux, caches).

        batch may carry "adapter_id" ((B,) int32): multi-tenant serving
        routing for pooled adapter params (repro.serving)."""
        st = self.statics(mode, remat=remat,
                          adapter_id=batch.get("adapter_id"))
        x, positions = self._embed(st, params, batch)
        x = st.constrain(x, "batch", "seq", None)
        x, aux, caches = tfm._run_stack(st, params, x, positions)
        logits = tfm.logits_head(st, params, x)
        return logits, aux, caches

    def loss(self, params, batch, remat: bool = False):
        """Next-token (or per-frame) CE. Returns (loss, metrics)."""
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, mode="train",
                                      remat=remat)
        if cfg.is_encoder:
            labels = batch["labels"]
            lg = logits
        elif cfg.frontend == "vision_patches":
            n_img = batch["patches"].shape[1]
            labels = batch["tokens"][:, 1:]
            lg = logits[:, n_img:-1]
        else:
            labels = batch["tokens"][:, 1:]
            lg = logits[:, :-1]
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        tc = self.run.train
        total = loss + 1e-2 * aux / max(cfg.num_layers, 1)
        if tc.z_loss > 0:
            zl = jnp.mean(jnp.square(jax.nn.logsumexp(
                lg.astype(jnp.float32), axis=-1)))
            total = total + tc.z_loss * zl
        return total, {"ce": loss, "aux": aux}

    # ----------------------------------------------------------- serving --
    def prefill(self, params, batch):
        logits, _, caches = self.forward(params, batch, mode="prefill")
        return logits, caches

    def decode_step(self, params, batch):
        """batch: {"tokens": (B,1), "positions": (B,1), "cache_index": (B,),
        "caches": {...}, optional "adapter_id": (B,)}.
        Returns (logits (B,1,V), new_caches).

        Paged serving (v2) passes "block_tables" ((B, NBT) int32) and the
        shared block pool as "caches"; tokens/positions may then be (B, C)
        for a prefill chunk, with positions == -1 marking padding lanes."""
        st = self.statics("decode", adapter_id=batch.get("adapter_id"),
                          block_tables=batch.get("block_tables"))
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            raise ValueError("encoder-only model has no decode step")
        x = tfm.embed_tokens(st, params, batch["tokens"])
        x = st.constrain(x, "batch", None, None)
        x, _, caches = tfm._run_stack(st, params, x, batch["positions"],
                                      caches=batch["caches"],
                                      cache_index=batch["cache_index"])
        logits = tfm.logits_head(st, params, x)
        return logits, caches

    # ------------------------------------------------------------ caches --
    def _cache_entry(self, p: int, batch: int, s_max: int, abstract: bool):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if tfm.layer_kind(cfg, p) == "attn":
            kv_s = s_max if cfg.sliding_window <= 0 \
                else min(s_max, cfg.sliding_window)
            shape = (batch, kv_s, cfg.num_kv_heads, cfg.head_dim)
            pshape = (batch, kv_s)
            if abstract:
                return {"k": jax.ShapeDtypeStruct(shape, dt),
                        "v": jax.ShapeDtypeStruct(shape, dt),
                        "pos": jax.ShapeDtypeStruct(pshape, jnp.int32)}
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                    "pos": jnp.full(pshape, -1, jnp.int32)}
        if abstract:
            return mamba_mod.abstract_decode_state(cfg, batch, dt)
        return mamba_mod.init_decode_state(cfg, batch, dt)

    def _stack_cache(self, entry, n, abstract: bool):
        if abstract:
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                entry)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), entry)

    def make_caches(self, batch: int, s_max: int, abstract: bool = False):
        g, n = tfm.group_structure(self.cfg)
        return {f"pos_{p}": self._stack_cache(
            self._cache_entry(p, batch, s_max, abstract), n, abstract)
            for p in range(g)}

    def cache_specs(self, rules, batch: int, s_max: int):
        """PartitionSpecs for the decode caches (seq-sharded split-KV for
        attention when enabled; SSM states batch-sharded)."""
        from jax.sharding import PartitionSpec as P
        g, n = tfm.group_structure(self.cfg)
        seq_axis = rules.lookup("seq") if \
            self.run.parallel.decode_cache_seq_shard else None
        out = {}
        for p in range(g):
            if tfm.layer_kind(self.cfg, p) == "attn":
                spec = P(None, rules.lookup("batch"), seq_axis, None, None)
                out[f"pos_{p}"] = {"k": spec, "v": spec,
                                   "pos": P(None, rules.lookup("batch"),
                                            seq_axis)}
            else:
                bspec = rules.lookup("batch")
                inner = rules.lookup("ssm_inner")
                out[f"pos_{p}"] = {
                    "conv_x": P(None, bspec, None, inner),
                    "conv_b": P(None, bspec, None, None),
                    "conv_c": P(None, bspec, None, None),
                    "ssm": P(None, bspec, inner, None, None),
                }
        return out


def build(run: RunConfig, constrain: Callable = tfm._noop_constrain,
          shard=None) -> Model:
    """``shard`` (optional): a validated ``MeshContext`` from
    ``repro.distributed.sharding.make_shard_context`` -- every adapted
    linear then runs its fused kernels per-shard inside shard_map."""
    cfg = run.model
    ep = pick_ep(cfg, run.parallel)
    base_defs, adapter_defs = tfm.build_defs(cfg, run.adapter, run.quant,
                                             run.parallel, ep)
    return Model(cfg=cfg, run=run, base_defs=base_defs,
                 adapter_defs=adapter_defs, ep=ep, constrain=constrain,
                 shard=shard)
