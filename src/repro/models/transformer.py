"""Model assembly: embeddings/frontends -> scan-over-layers decoder/encoder
stack -> head.  One assembly covers all 10 assigned families via ModelConfig
flags; layer heterogeneity (jamba's 1-attention-per-8 interleave) is handled
with a scan *group*: the scan body applies ``scan_block`` consecutive layers
whose types repeat periodically, so HLO stays compact (one group traced
once) for the 126-layer dry-runs.

Params layout:  {"base": frozen (possibly quantized), "adapter": trainable}
Both trees mirror:  embed / frontend / groups/pos_{i}/... / final_norm / head
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import (AdapterConfig, ModelConfig, ParallelConfig,
                               QuantConfig, RunConfig)
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.linears import linear_defs
from repro.models.spec import ParamDef, stack_defs


def _noop_constrain(x, *axes):
    return x


@dataclass(frozen=True)
class Statics:
    """Static context threaded through every apply function.

    ``adapter_id`` is the one traced member: the per-batch-row adapter ids
    ((B,) int32) of a multi-tenant serving batch, present only when the
    params carry a pooled ``r_stack`` (repro.serving).  It rides here so
    every adapted linear sees it without new plumbing per layer type."""
    cfg: ModelConfig
    acfg: AdapterConfig
    qcfg: QuantConfig
    ep: bool = False                       # expert-parallel MoE layout
    constrain: Callable = _noop_constrain  # sharding-constraint hook
    remat: bool = False
    mode: str = "train"                    # train | prefill | decode
    adapter_id: Optional[Any] = None       # (B,) int32 multi-adapter routing
    shard: Optional[Any] = None            # MeshContext: shard_map'd kernels
    block_tables: Optional[Any] = None     # (B, NBT) i32 paged-KV tables


# ---------------------------------------------------------------------------
# layer-kind pattern
# ---------------------------------------------------------------------------
def layer_kind(cfg: ModelConfig, i: int) -> str:
    return "mamba" if cfg.is_ssm_layer(i) else "attn"


def group_structure(cfg: ModelConfig) -> Tuple[int, int]:
    """(group_size, n_groups); layer types must be periodic in group_size."""
    g = max(cfg.scan_block, 1)
    assert cfg.num_layers % g == 0, (cfg.num_layers, g)
    n = cfg.num_layers // g
    for p in range(g):
        kinds = {layer_kind(cfg, grp * g + p) for grp in range(n)}
        moes = {cfg.is_moe_layer(grp * g + p) for grp in range(n)}
        assert len(kinds) == 1 and len(moes) == 1, \
            f"layer pattern not periodic with scan_block={g}"
    return g, n


# ---------------------------------------------------------------------------
# defs
# ---------------------------------------------------------------------------
def _norm_def(d):
    return ParamDef((d,), (None,), "ones")


def _one_layer_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
                    idx: int, ms: int, ep: bool):
    d = cfg.d_model
    kind = layer_kind(cfg, idx)
    has_mlp = cfg.is_moe_layer(idx) or cfg.d_ff > 0
    base: Dict[str, Any] = {"ln1": _norm_def(d)}
    if has_mlp:
        base["ln2"] = _norm_def(d)
    adapt: Dict[str, Any] = {}
    if kind == "attn":
        b, a = attn_mod.attention_defs(cfg, acfg, qcfg, ms)
        base["attn"], adapt["attn"] = b, a
    else:
        b, a = mamba_mod.mamba_defs(cfg, acfg, qcfg, ms)
        base["mamba"], adapt["mamba"] = b, a
    if cfg.is_moe_layer(idx):
        b, a = moe_mod.moe_defs(cfg, acfg, qcfg, ms, ep)
        base["moe"], adapt["moe"] = b, a
        if cfg.dense_residual:
            b2, a2 = mlp_mod.mlp_defs(cfg, acfg, qcfg, ms)
            base["mlp"], adapt["mlp"] = b2, a2
    elif cfg.d_ff > 0:
        b, a = mlp_mod.mlp_defs(cfg, acfg, qcfg, ms)
        base["mlp"], adapt["mlp"] = b, a
    adapt = {k: v for k, v in adapt.items() if v}
    return base, adapt


def build_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
               pcfg: Optional[ParallelConfig] = None, ep: bool = False):
    """Returns (base_defs, adapter_defs)."""
    ms = pcfg.model_axis_size if pcfg else 1
    d, v = cfg.d_model, cfg.padded_vocab
    g, n = group_structure(cfg)

    base: Dict[str, Any] = {}
    adapt: Dict[str, Any] = {}
    if cfg.frontend == "none" or cfg.family == "vlm":
        base["embed"] = {"w": ParamDef((v, d), ("vocab", "embed"), "embed",
                                       scale=0.02)}
    if cfg.frontend != "none":
        base["frontend_proj"] = linear_defs(cfg.frontend_dim, d, None,
                                            "embed", QuantConfig())
    groups_base: Dict[str, Any] = {}
    groups_adapt: Dict[str, Any] = {}
    for p in range(g):
        lb, la = _one_layer_defs(cfg, acfg, qcfg, p, ms, ep)
        groups_base[f"pos_{p}"] = stack_defs(lb, n)
        if la:
            groups_adapt[f"pos_{p}"] = stack_defs(la, n)
    base["groups"] = groups_base
    if groups_adapt:
        adapt["groups"] = groups_adapt
    base["final_norm"] = _norm_def(d)
    out_dim = cfg.padded_vocab
    if not cfg.tie_embeddings:
        base["head"] = linear_defs(d, out_dim, "embed", "vocab", QuantConfig())
    return base, adapt


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------
def _rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


def _apply_layer(st: Statics, idx_in_group: int, base, adapt, x, positions,
                 cache=None, cache_index=None):
    """One transformer layer. Returns (x, aux, new_cache)."""
    cfg = st.cfg
    kind = layer_kind(cfg, idx_in_group)
    aux = jnp.zeros((), jnp.float32)
    h = _rmsnorm(x, base["ln1"], cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        out, new_cache = attn_mod.attention_apply(
            base["attn"], adapt.get("attn", {}), h, positions, cfg, st.acfg,
            st.qcfg, cache=cache, cache_index=cache_index,
            collect_cache=(st.mode == "prefill"), constrain=st.constrain,
            adapter_id=st.adapter_id, shard=st.shard,
            block_tables=st.block_tables)
    else:
        out, new_cache = mamba_mod.mamba_apply(
            base["mamba"], adapt.get("mamba", {}), h, cfg, st.acfg, st.qcfg,
            state=cache, collect_state=(st.mode == "prefill"))
    x = x + out
    if "moe" in base or "mlp" in base:
        h = _rmsnorm(x, base["ln2"], cfg.norm_eps)
        if "moe" in base:
            out, aux = moe_mod.moe_apply(base["moe"], adapt.get("moe", {}),
                                         h, cfg, st.acfg, st.qcfg,
                                         constrain=st.constrain, ep=st.ep)
            if cfg.dense_residual:
                out = out + mlp_mod.mlp_apply(base["mlp"],
                                              adapt.get("mlp", {}), h, cfg,
                                              st.acfg, st.qcfg,
                                              constrain=st.constrain,
                                              adapter_id=st.adapter_id,
                                              shard=st.shard)
        else:
            out = mlp_mod.mlp_apply(base["mlp"], adapt.get("mlp", {}), h,
                                    cfg, st.acfg, st.qcfg,
                                    constrain=st.constrain,
                                    adapter_id=st.adapter_id,
                                    shard=st.shard)
        x = x + out
    return x, aux, new_cache


def _constrain_residual(st: Statics, x):
    # batch over (pod, data); seq over model (SP) when shapes allow
    return st.constrain(x, "batch", "seq", None)


def _run_stack(st: Statics, params, x, positions, caches=None,
               cache_index=None):
    """Scan the layer groups. caches: {"pos_i": stacked-cache} or None.
    Returns (x, total_aux, new_caches)."""
    cfg = st.cfg
    g, n = group_structure(cfg)
    base_groups = params["base"]["groups"]
    adapt_groups = params.get("adapter", {}).get("groups", {})

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_caches = xs
        new_caches = {}
        for p in range(g):
            pb = layer_params[f"pos_{p}"]
            pa = adapt_groups_get(layer_params, p)
            cache_p = layer_caches.get(f"pos_{p}") if layer_caches else None
            x = _constrain_residual(st, x)
            x, aux_p, nc = _apply_layer(st, p, pb, pa, x, positions,
                                        cache=cache_p,
                                        cache_index=cache_index)
            aux = aux + aux_p
            if nc is not None:
                new_caches[f"pos_{p}"] = nc
        x = _constrain_residual(st, x)
        return (x, aux), (new_caches if new_caches else None)

    # adapter params for position p live in a parallel tree; we zip them into
    # the scanned xs so the scan sees both
    def adapt_groups_get(layer_params, p):
        return layer_params.get(f"__adapt_pos_{p}", {})

    scanned = dict(params["base"]["groups"])
    for p in range(g):
        if f"pos_{p}" in adapt_groups:
            scanned[f"__adapt_pos_{p}"] = adapt_groups[f"pos_{p}"]

    body_fn = body
    if st.remat:
        body_fn = jax.checkpoint(body,
                                 policy=jax.checkpoint_policies.nothing_saveable)

    if not cfg.scan_layers:
        # unrolled path (also the cost-calibration probe: scan bodies are
        # counted once by HLO cost analysis, unrolled layers are not)
        carry = (x, jnp.zeros((), jnp.float32))
        ys = []
        for i in range(n):
            xs_i = jax.tree_util.tree_map(lambda a: a[i], (scanned, caches))
            carry, y = body_fn(carry, xs_i)
            ys.append(y)
        (x, aux) = carry
        if ys and ys[0] is not None:
            new_caches = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a, axis=0), *ys)
        else:
            new_caches = None
        return x, aux, new_caches

    (x, aux), new_caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                        (scanned, caches))
    return x, aux, new_caches


# ---------------------------------------------------------------------------
# embeddings / head / losses
# ---------------------------------------------------------------------------
def embed_tokens(st: Statics, params, tokens):
    table = params["base"]["embed"]["w"]
    x = jnp.take(table, tokens, axis=0).astype(jnp.dtype(st.cfg.dtype))
    return x


def project_frontend(st: Statics, params, feats):
    w = params["base"]["frontend_proj"]["w"]
    return (feats.astype(jnp.dtype(st.cfg.dtype)) @ w.astype(
        jnp.dtype(st.cfg.dtype)))


def logits_head(st: Statics, params, x):
    cfg = st.cfg
    x = _rmsnorm(x, params["base"]["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["base"]["embed"]["w"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = x @ params["base"]["head"]["w"].astype(x.dtype)
    if cfg.padded_vocab > cfg.vocab_size:
        mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab_size)
        logits = jnp.where(mask[None, None, :], logits, -1e30)
    return logits
