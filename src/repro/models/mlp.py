"""Dense MLP (SwiGLU or GELU) with adapter integration."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
from repro.core.adapter import adapted_linear
from repro.models.linears import adapter_defs, linear_defs


def mlp_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
             model_axis_size: int = 1, d_ff: int = 0):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    base = {"up": linear_defs(d, ff, "embed", "mlp", qcfg),
            "down": linear_defs(ff, d, "mlp", "embed", qcfg)}
    names = {"up": (d, ff), "down": (ff, d)}
    if cfg.glu:
        base["gate"] = linear_defs(d, ff, "embed", "mlp", qcfg)
        names["gate"] = (d, ff)
    adapters = {}
    for name, (di, do) in names.items():
        a = adapter_defs(name, di, do, acfg, model_axis_size)
        if a is not None:
            adapters[name] = a
    return base, adapters


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def mlp_apply(base: dict, adapters: dict, x: jnp.ndarray, cfg: ModelConfig,
              acfg: AdapterConfig, qcfg: QuantConfig,
              constrain=None, adapter_id=None, shard=None) -> jnp.ndarray:
    def lin(name, inp):
        return adapted_linear(inp, base[name], adapters.get(name), acfg,
                              qcfg, constrain=constrain,
                              adapter_id=adapter_id,
                              shard=shard.linear(name) if shard else None)

    up = lin("up", x)
    if cfg.glu:
        up = _act(lin("gate", x), cfg.act) * up
    else:
        up = _act(up, cfg.act)
    return lin("down", up)
