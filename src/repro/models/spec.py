"""Single-source-of-truth parameter definitions.

Every layer declares its parameters once as a tree of ``ParamDef`` (shape +
logical axes + initializer). From that one tree we derive:

  * materialized params        (init_tree)        -- real training runs
  * abstract params            (abstract_tree)    -- dry-run .lower() without
                                                     allocating 405B weights
  * PartitionSpecs             (spec_tree)        -- jit in_shardings
  * parameter counts           (count_tree)

Logical axes are mapped to mesh axes by ``AxisRules`` (MaxText-style), so
re-sharding experiments (§Perf hillclimbs) are one-dict changes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (None = replicated)
    init: str = "normal"                 # normal | zeros | ones | embed | identity_skew
    scale: float = 1.0                   # stddev multiplier for normal
    dtype: Any = None                    # None -> param_dtype at init time

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


class CompositeDef:
    """A leaf that expands to several related arrays initialized together
    (e.g. a quantized linear: codes + scales from one sampled weight).

    Subclasses implement: expand_defs() -> def tree (for abstract/spec/count)
    and init(key, param_dtype) -> param subtree."""

    def expand_defs(self) -> dict:
        raise NotImplementedError

    def init(self, key, param_dtype):
        raise NotImplementedError


def is_composite(x) -> bool:
    return isinstance(x, CompositeDef)


class StackedDef(CompositeDef):
    """n copies of an inner composite, stacked on a leading 'layers' dim
    (scan-over-layers parameter layout)."""

    def __init__(self, inner: CompositeDef, n: int):
        self.inner = inner
        self.n = n

    def expand_defs(self) -> dict:
        return stack_defs(self.inner.expand_defs(), self.n)

    def init(self, key, param_dtype):
        keys = jax.random.split(key, self.n)
        return jax.vmap(lambda k: self.inner.init(k, param_dtype))(keys)


def stack_defs(tree, n: int):
    """Add a leading ('layers', n) dim to every leaf (scan stacking)."""
    if is_def(tree):
        return ParamDef((n,) + tree.shape, ("layers",) + tree.axes,
                        tree.init, tree.scale, tree.dtype)
    if is_composite(tree):
        return StackedDef(tree, n)
    if isinstance(tree, dict):
        return {k: stack_defs(v, n) for k, v in tree.items()}
    raise TypeError(f"bad def tree node: {type(tree)}")


# ---------------------------------------------------------------------------
# Axis rules: logical axis -> mesh axis (or tuple of mesh axes, or None).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AxisRules:
    rules: Tuple[Tuple[str, Any], ...]

    def lookup(self, logical: Optional[str]):
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def spec(self, axes: Tuple[Optional[str], ...]) -> PartitionSpec:
        return PartitionSpec(*[self.lookup(a) for a in axes])


def default_rules(pcfg) -> AxisRules:
    return rules_variant(pcfg, "baseline")


def rules_variant(pcfg, preset: str = "baseline") -> AxisRules:
    """Sharding strategies (DESIGN.md §3; presets are the §Perf hillclimb
    lever -- one-dict re-sharding experiments).

    baseline : FSDP over (pod, data) + TP over model (Megatron-style)
    dp       : pure data parallelism over every axis, params replicated
               (small models: kills the TP activation all-reduces)
    dp_fsdp  : batch over (data, model); params ZeRO-3 over data only
    ep_model : no attention/dense TP; experts EP over `model`, expert d_ff
               over `data` (arctic-class MoE: trades TP all-reduces for
               dispatch all-to-alls)
    fused_tp : the mesh-native fused-kernel layout (ISSUE-5): batch over
               (pod, data), W / NF4 codes / rotation blocks TP-only over
               `model` (no ZeRO-3 on the embed dim, no SP on the residual
               -- the per-shard Pallas kernels consume local W directly
               inside shard_map, so the only storage sharding that works
               is the one the kernels compute on)
    """
    fsdp = pcfg.data_axes if len(pcfg.data_axes) > 1 else (
        pcfg.data_axes[0] if pcfg.data_axes else None)
    has_model = "model" in pcfg.mesh_axes
    model = "model" if has_model else None
    all_axes = tuple(pcfg.mesh_axes)

    base = {
        "batch": fsdp,
        "vocab": model,
        "embed": fsdp,            # d_model dim of weights (ZeRO-3)
        "heads": model,           # q heads / attn out dim
        "kv_heads": None,         # small; replicated (GQA)
        "head_dim": None,
        "mlp": model,             # d_ff dim
        # EP within a pod: 'data' (16) divides all assigned expert counts
        # (128, 16); across pods experts are replicated (DP) -- DESIGN.md §3
        "expert": "data" if "data" in pcfg.mesh_axes else None,
        "expert_mlp": model,      # d_ff dim of expert stacks
        "oft_block_sharded": model,   # OFT blocks on a model-sharded input
        "oft_block": None,        # OFT blocks on replicated inputs
        "lora_rank": None,
        "layers": None,
        "seq": model,             # SP: sequence dim of saved activations
        "ssm_inner": model,       # mamba d_inner / heads
        "ssm_state": None,
        "conv": None,
    }
    if preset == "dp":
        base.update(batch=all_axes, vocab=None, embed=None, heads=None,
                    mlp=None, expert=None, expert_mlp=None,
                    oft_block_sharded=None, seq=None, ssm_inner=None)
    elif preset == "dp_fsdp":
        base.update(batch=all_axes, vocab=None,
                    embed="data", heads=None, mlp=None, expert=None,
                    expert_mlp=None, oft_block_sharded=None, seq=None,
                    ssm_inner=None)
    elif preset == "ep_model":
        base.update(heads=None, mlp=None, seq=None, ssm_inner=None,
                    oft_block_sharded=None,
                    expert=model, expert_mlp="data")
    elif preset == "fused_tp":
        base.update(embed=None, seq=None, ssm_inner=None)
    elif preset != "baseline":
        raise ValueError(f"unknown rules preset {preset}")
    return AxisRules(rules=tuple(base.items()))


# ---------------------------------------------------------------------------
# Tree derivations
# ---------------------------------------------------------------------------
def _map_defs(tree, fn):
    if is_def(tree):
        return fn(tree)
    if is_composite(tree):
        return _map_defs(tree.expand_defs(), fn)
    if isinstance(tree, dict):
        return {k: _map_defs(v, fn) for k, v in tree.items()}
    raise TypeError(f"bad def tree node: {type(tree)}")


def _path_hash(path) -> int:
    """Deterministic across processes (unlike builtin str hash, which is
    PYTHONHASHSEED-salted): same config + seed -> same init everywhere."""
    import zlib
    return zlib.crc32("/".join(path).encode())


def init_tree(key, defs, param_dtype=jnp.float32):
    """Materialize params. Keys are derived per-leaf from the tree path hash
    so initialization is order-independent."""
    leaves = []

    def collect(tree, path):
        if is_def(tree) or is_composite(tree):
            leaves.append((path, tree))
        else:
            for k in sorted(tree.keys()):
                collect(tree[k], path + (k,))

    collect(defs, ())

    out = {}
    for path, d in leaves:
        sub = jax.random.fold_in(key, _path_hash(path) % (2 ** 31))
        if is_composite(d):
            val = d.init(sub, param_dtype)
            node = out
            for k in path[:-1]:
                node = node.setdefault(k, {})
            node[path[-1]] = val
            continue
        dtype = d.dtype or param_dtype
        if d.init == "zeros":
            val = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            val = jnp.ones(d.shape, dtype)
        elif d.init == "normal":
            fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            std = d.scale / np.sqrt(fan_in)
            val = (std * jax.random.normal(sub, d.shape, jnp.float32)).astype(dtype)
        elif d.init == "embed":
            val = (d.scale * jax.random.normal(sub, d.shape, jnp.float32)
                   ).astype(dtype)
        else:
            raise ValueError(f"unknown init {d.init}")
        node = out
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = val
    return out


def abstract_tree(defs, param_dtype=jnp.float32):
    return _map_defs(defs, lambda d: jax.ShapeDtypeStruct(
        d.shape, d.dtype or param_dtype))


def spec_tree(defs, rules: AxisRules):
    return _map_defs(defs, lambda d: rules.spec(d.axes))


def count_tree(defs) -> int:
    total = 0

    def add(d):
        nonlocal total
        n = 1
        for s in d.shape:
            n *= s
        total += n
        return None

    _map_defs(defs, add)
    return total


def bytes_tree(defs, param_dtype=jnp.float32) -> int:
    total = 0

    def add(d):
        nonlocal total
        n = 1
        for s in d.shape:
            n *= s
        dt = np.dtype(jnp.dtype(d.dtype or param_dtype).name)
        total += n * dt.itemsize
        return None

    _map_defs(defs, add)
    return total
