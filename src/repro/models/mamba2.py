"""Mamba-2 (SSD, state-space duality -- arXiv:2405.21060) in pure JAX.

Layer structure (per token, d_inner = expand * d_model, H heads of dim P,
G groups sharing B/C, state size N):

    z, x, B, C, dt = projections(u)          # separate linears (TP-clean;
                                             # numerics == fused in_proj)
    x, B, C <- causal depthwise conv1d + silu
    dt <- softplus(dt + dt_bias); a = dt * A  (A = -exp(A_log) < 0)
    h_t = exp(a_t) h_{t-1} + dt_t * x_t (x) B_t      (state h: (H, P, N))
    y_t = C_t . h_t + D * x_t
    out = out_proj( rmsnorm(y * silu(z)) )

Three execution paths:
  * ssd_chunked  -- training/prefill: intra-chunk quasi-attention +
                    inter-chunk state scan (the SSD algorithm)
  * ssd_naive    -- O(S) sequential oracle (tests)
  * decode step  -- O(1) per token with carried (conv_state, ssm_state):
                    this is what makes long_500k runnable for SSM/hybrid.

Sharding: heads/d_inner -> `model`; B/C/dt projections replicated (small);
per-device SSD needs no collectives; out_proj all-reduces.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
from repro.core.adapter import adapted_linear
from repro.models.linears import adapter_defs, linear_defs
from repro.models.spec import ParamDef


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim


def mamba_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
               model_axis_size: int = 1):
    d = cfg.d_model
    d_inner, h, g, n, p = dims(cfg)
    w = cfg.ssm_conv_width
    base = {
        "z_proj": linear_defs(d, d_inner, "embed", "ssm_inner", qcfg),
        "x_proj": linear_defs(d, d_inner, "embed", "ssm_inner", qcfg),
        "b_proj": linear_defs(d, g * n, "embed", None, qcfg),
        "c_proj": linear_defs(d, g * n, "embed", None, qcfg),
        "dt_proj": linear_defs(d, h, "embed", "ssm_inner", qcfg),
        "conv_x": {"w": ParamDef((w, d_inner), ("conv", "ssm_inner"), "normal",
                                 scale=1.0)},
        "conv_b": {"w": ParamDef((w, g * n), ("conv", None), "normal")},
        "conv_c": {"w": ParamDef((w, g * n), ("conv", None), "normal")},
        "a_log": ParamDef((h,), ("ssm_inner",), "zeros"),
        "d_skip": ParamDef((h,), ("ssm_inner",), "ones"),
        "dt_bias": ParamDef((h,), ("ssm_inner",), "zeros"),
        "norm": ParamDef((d_inner,), ("ssm_inner",), "ones"),
        "out_proj": linear_defs(d_inner, d, "ssm_inner", "embed", qcfg),
    }
    adapters = {}
    for name, (di, do) in {"in_proj": (d, d_inner),
                           "out_proj": (d_inner, d)}.items():
        a = adapter_defs(name, di, do, acfg, model_axis_size)
        if a is not None:
            adapters[name] = a
    return base, adapters


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, C), w: (W, C) depthwise causal conv.
    state: (B, W-1, C) trailing context (decode). Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)        # (B, S+W-1, C)
    y = sum(xp[:, j:j + x.shape[1], :] * w[j][None, None, :]
            for j in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return y, new_state


def _rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


# ----------------------------------------------------------- SSD cores -----
def ssd_naive(x, dt, a_coef, bm, cm, d_skip, h0=None):
    """Sequential oracle. x: (B,S,H,P), dt: (B,S,H), a_coef: (H,) (negative),
    bm/cm: (B,S,G,N). Returns (y: (B,S,H,P), h_final: (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    rep = h // g
    bm_h = jnp.repeat(bm, rep, axis=2)            # (B,S,H,N)
    cm_h = jnp.repeat(cm, rep, axis=2)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt.astype(jnp.float32)
                        * a_coef[None, :]).astype(hprev.dtype)   # (B,H)
        hnew = hprev * decay[..., None, None] + \
            ((dtt[..., None] * xt)[..., None]
             * bt[..., None, :]).astype(hprev.dtype)
        yt = jnp.einsum("bhpn,bhn->bhp", hnew, ct.astype(hprev.dtype))
        return hnew, yt

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bm_h.transpose(1, 0, 2, 3), cm_h.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + x * d_skip[None, None, :, None]
    return y, h_final


def ssd_chunked(x, dt, a_coef, bm, cm, d_skip, chunk: int):
    """Chunked SSD (the Mamba-2 algorithm): quadratic intra-chunk attention
    with decay mask + linear inter-chunk state recurrence."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    if s % chunk:
        return ssd_naive(x, dt, a_coef, bm, cm, d_skip)
    nc, q = s // chunk, chunk
    rep = h // g

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = bm.reshape(b, nc, q, g, n)
    cc = cm.reshape(b, nc, q, g, n)
    a = dtc * a_coef[None, None, None, :]                 # (B,NC,Q,H) <= 0
    cs = jnp.cumsum(a, axis=2)                            # within-chunk cumsum
    total = cs[:, :, -1, :]                               # (B,NC,H)

    # --- intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(cs_i - cs_j) dt_j x_j
    bh = jnp.repeat(bc, rep, axis=3)                      # (B,NC,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)
    cb = jnp.einsum("bcihn,bcjhn->bchij", ch.astype(jnp.float32),
                    bh.astype(jnp.float32))               # (B,NC,H,Q,Q)
    seg = cs[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - cs[:, :, None, :, :].transpose(0, 1, 4, 2, 3)   # (B,NC,H,Q,Q) cs_i-cs_j
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(seg), 0.0)
    w_ij = cb * decay                                     # (B,NC,H,Q,Q)
    dx = dtc[..., None] * xc                              # (B,NC,Q,H,P)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w_ij,
                         dx.astype(jnp.float32))

    # --- chunk states: S_c = sum_j exp(total - cs_j) B_j (x) dt_j x_j
    state_decay = jnp.exp(total[:, :, None, :] - cs)      # (B,NC,Q,H)
    sc = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", bh.astype(jnp.float32),
                    state_decay, dx.astype(jnp.float32))  # (B,NC,H,P,N)

    # --- inter-chunk recurrence over running state
    def chunk_step(hprev, inp):
        sc_c, tot_c = inp                                 # (B,H,P,N),(B,H)
        hnew = hprev * jnp.exp(tot_c)[..., None, None] + sc_c
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        chunk_step, h0, (sc.transpose(1, 0, 2, 3, 4),
                         total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)            # (B,NC,H,P,N)

    # --- inter-chunk output: Y[i] += exp(cs_i) C_i . h_entering
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", ch.astype(jnp.float32),
                         h_prevs) * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    return y + x * d_skip[None, None, :, None].astype(x.dtype), \
        h_final.astype(x.dtype)


# ------------------------------------------------------------ layer apply --
def _projections(base, adapters, u, acfg, qcfg):
    def lin(name, pname, inp):
        return adapted_linear(inp, base[pname], adapters.get(name), acfg,
                              qcfg)
    z = lin("in_proj", "z_proj", u)
    x = lin("in_proj", "x_proj", u)
    bm = lin(None, "b_proj", u)
    cm = lin(None, "c_proj", u)
    dt = lin(None, "dt_proj", u)
    return z, x, bm, cm, dt


def mamba_apply(base: dict, adapters: dict, u: jnp.ndarray, cfg: ModelConfig,
                acfg: AdapterConfig, qcfg: QuantConfig,
                state: Optional[dict] = None, collect_state: bool = False
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """u: (B, S, d_model). state (decode): {"conv_x","conv_b","conv_c":
    (B, W-1, C), "ssm": (B, H, P, N)}. Returns (y, new_state_or_None)."""
    bsz, s, _ = u.shape
    d_inner, h, g, n, p = dims(cfg)
    z, x, bm, cm, dt = _projections(base, adapters, u, acfg, qcfg)

    decoding = state is not None
    cx, ncx = _causal_conv(x, base["conv_x"]["w"],
                           state["conv_x"] if decoding else None)
    cb, ncb = _causal_conv(bm, base["conv_b"]["w"],
                           state["conv_b"] if decoding else None)
    cc, ncc = _causal_conv(cm, base["conv_c"]["w"],
                           state["conv_c"] if decoding else None)
    x = jax.nn.silu(cx).reshape(bsz, s, h, p)
    bm = jax.nn.silu(cb).reshape(bsz, s, g, n)
    cm = jax.nn.silu(cc).reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + base["dt_bias"].astype(jnp.float32)[None, None])
    a_coef = -jnp.exp(base["a_log"].astype(jnp.float32))
    d_skip = base["d_skip"].astype(jnp.float32)

    new_state = None
    if decoding:
        # O(1) recurrence step(s) from carried state
        y, h_final = ssd_naive(x, dt.astype(x.dtype), a_coef, bm, cm,
                               d_skip.astype(x.dtype),
                               h0=state["ssm"].astype(x.dtype))
        new_state = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc,
                     "ssm": h_final}
    else:
        y, h_final = ssd_chunked(x, dt.astype(x.dtype), a_coef, bm, cm,
                                 d_skip.astype(x.dtype), cfg.ssm_chunk)
        if collect_state:
            # prefill: trailing conv context + final SSM state seed decoding
            new_state = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc,
                         "ssm": h_final}

    y = y.reshape(bsz, s, d_inner)
    y = y * jax.nn.silu(z)
    y = _rmsnorm(y, base["norm"], cfg.norm_eps)
    out = adapted_linear(y, base["out_proj"], adapters.get("out_proj"),
                         acfg, qcfg)
    return out, new_state


def init_decode_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, h, g, n, p = dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, d_inner), dtype),
        "conv_b": jnp.zeros((batch, w - 1, g * n), dtype),
        "conv_c": jnp.zeros((batch, w - 1, g * n), dtype),
        "ssm": jnp.zeros((batch, h, p, n), dtype),
    }


def abstract_decode_state(cfg: ModelConfig, batch: int, dtype):
    d_inner, h, g, n, p = dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, w - 1, d_inner), dtype),
        "conv_b": jax.ShapeDtypeStruct((batch, w - 1, g * n), dtype),
        "conv_c": jax.ShapeDtypeStruct((batch, w - 1, g * n), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, h, p, n), dtype),
    }
