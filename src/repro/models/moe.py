"""Mixture-of-Experts with top-k routing and grouped capacity-based one-hot
dispatch (GShard/GSPMD pattern).

Tokens are split into groups of ``group_size``; each group has its own
per-expert capacity C = ceil(cf * group_size * k / E) (rounded up to 4).
This keeps the dispatch/combine one-hots at O(T * E * C_group) with small
C_group -- the difference between 5 MB/device and 80 GB/device at
arctic-480b train_4k scale.

Two layouts (DESIGN.md §3), applied as sharding constraints on the
expert-stacked intermediates so GSPMD inserts the all-to-alls:
  "ep": expert dim -> `data` axis (arctic 128e, jamba 16e)
  "tp": expert dim replicated, d_ff -> `model` (mixtral 8e: 8 does not
        divide the 16-wide axes)
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
from repro.core.adapter import adapted_linear
from repro.models.linears import adapter_defs, linear_defs
from repro.models.spec import ParamDef

DEFAULT_GROUP = 256


def moe_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
             model_axis_size: int = 1, ep: bool = True):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    # EP: experts sharded over 'data' => the d_model dim must NOT also use
    # the fsdp ('embed') axes (duplicate mesh axis); TP layout keeps fsdp.
    expert_axis = "expert" if ep else None
    d_axis = None if ep else "embed"
    base = {
        "router": {"w": ParamDef((d, e), ("embed", None), "normal")},
        "experts": {
            "up": ParamDef((e, d, ff), (expert_axis, d_axis, "expert_mlp"),
                           "normal"),
            "down": ParamDef((e, ff, d), (expert_axis, "expert_mlp", d_axis),
                             "normal"),
        },
    }
    if cfg.glu:
        base["experts"]["gate"] = ParamDef(
            (e, d, ff), (expert_axis, d_axis, "expert_mlp"), "normal")
    adapters = {}
    a = adapter_defs("router", d, e, acfg, model_axis_size)
    if a is not None:
        adapters["router"] = a
    return base, adapters


def group_capacity(group_size: int, e: int, k: int, factor: float) -> int:
    cap = -(-int(factor * group_size * k) // e)   # ceil
    return max(4, ((cap + 3) // 4) * 4)


def moe_apply(base: dict, adapters: dict, x: jnp.ndarray, cfg: ModelConfig,
              acfg: AdapterConfig, qcfg: QuantConfig,
              constrain: Optional[Callable] = None, ep: bool = True,
              group_size: int = DEFAULT_GROUP
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    constrain(x, *logical_axes) applies a sharding constraint when running
    under a mesh (no-op otherwise) -- provided by the transformer assembly."""
    if constrain is None:
        constrain = lambda arr, *axes: arr   # noqa: E731
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    gsz = min(group_size, t)
    if t % gsz:
        gsz = t          # tiny smoke configs: one group
    g = t // gsz
    xt = x.reshape(g, gsz, d)

    logits = adapted_linear(xt, base["router"], adapters.get("router"),
                            acfg, qcfg).astype(jnp.float32)      # (G, Tg, E)
    topw, topi = jax.lax.top_k(logits, k)
    topw = jax.nn.softmax(topw, axis=-1)                         # (G, Tg, k)

    # Switch-style load-balancing aux loss
    probs = jax.nn.softmax(logits, axis=-1)
    onehot_k = jax.nn.one_hot(topi, e, dtype=jnp.float32)        # (G, Tg, k, E)
    frac_tokens = jnp.mean(jnp.sum(onehot_k, axis=2), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    cap = group_capacity(gsz, e, k, cfg.capacity_factor)
    # position of each (token, choice) within its expert's per-group buffer
    flat = onehot_k.reshape(g, gsz * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos.reshape(g, gsz, k, e) * onehot_k, axis=-1
                  ).astype(jnp.int32)                             # (G, Tg, k)
    keep = pos < cap
    w = topw * keep.astype(topw.dtype)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=jnp.float32)[..., :cap]         # (G,Tg,k,C)
    disp = jnp.einsum("gtke,gtkc->gtec",
                      onehot_k * keep[..., None].astype(jnp.float32), pos_oh)
    comb = jnp.einsum("gtke,gtkc->gtec", onehot_k * w[..., None], pos_oh)

    xin = jnp.einsum("gtec,gtd->egcd", disp.astype(x.dtype), xt)  # (E,G,C,d)
    if ep:
        xin = constrain(xin, "expert", None, None, None)
    we = base["experts"]
    up = jnp.einsum("egcd,edf->egcf", xin, we["up"].astype(x.dtype))
    if cfg.glu:
        gate = jnp.einsum("egcd,edf->egcf", xin, we["gate"].astype(x.dtype))
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = jax.nn.gelu(up)
    if not ep:
        hidden = constrain(hidden, None, "batch", None, "mlp")
    out = jnp.einsum("egcf,efd->egcd", hidden, we["down"].astype(x.dtype))
    if ep:
        out = constrain(out, "expert", None, None, None)
    y = jnp.einsum("gtec,egcd->gtd", comb.astype(x.dtype), out)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
