"""Attention: GQA / MQA / MHA, causal or bidirectional, optional sliding
window, RoPE, memory-efficient chunked online-softmax (flash-style at the
JAX level so 32k-prefill never materializes an S x S score matrix), and
single-token decode against a (possibly seq-sharded) KV cache.

Sharding contract (baseline rules): q/k/v computed from a residual that is
replicated over `model`; q heads sharded over `model` (padded per config),
kv heads replicated (GQA keeps them small), so the attention core needs no
collectives; the o-projection contracts the model-sharded head dim
(all-reduce inserted by SPMD). Decode for large archs shards the cache seq
dim over `model` instead (split-KV / FlashDecoding pattern).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig, ModelConfig, QuantConfig
from repro.core.adapter import adapted_linear
from repro.models.linears import adapter_defs, linear_defs
from repro.models.spec import ParamDef

NEG_INF = -1e30


# ----------------------------------------------------------------- RoPE ----
def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float,
                dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (B, S) int32 -> cos/sin (B, S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ----------------------------------------------------------- param defs ----
def attention_defs(cfg: ModelConfig, acfg: AdapterConfig, qcfg: QuantConfig,
                   model_axis_size: int = 1):
    d = cfg.d_model
    h = cfg.padded_heads
    hd = cfg.head_dim
    kv = cfg.num_kv_heads
    base = {
        "q": linear_defs(d, h * hd, "embed", "heads", qcfg),
        "k": linear_defs(d, kv * hd, "embed", "kv_heads", qcfg),
        "v": linear_defs(d, kv * hd, "embed", "kv_heads", qcfg),
        "o": linear_defs(h * hd, d, "heads", "embed", qcfg),
    }
    adapters = {}
    for name, (di, do) in {"q": (d, h * hd), "k": (d, kv * hd),
                           "v": (d, kv * hd), "o": (h * hd, d)}.items():
        a = adapter_defs(name, di, do, acfg, model_axis_size)
        if a is not None:
            adapters[name] = a
    return base, adapters


# ------------------------------------------------------- masking helpers ---
def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: int) -> jnp.ndarray:
    """Additive bias (..., Sq, Sk) from absolute positions.

    q_pos: (B, Sq), k_pos: (B, Sk). Negative k_pos marks an invalid
    (not-yet-written) cache slot."""
    diff = q_pos[:, :, None] - k_pos[:, None, :]        # (B, Sq, Sk)
    ok = (k_pos >= 0)[:, None, :]
    if causal:
        ok = ok & (diff >= 0)
    if window > 0:
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Sq, KV, G, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _gqa_out(p: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """p: (B, KV, G, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, KV, G, hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v)


# --------------------------------------------------- chunked core (train) --
def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, k_pos: jnp.ndarray, *, causal: bool,
                   window: int, chunk: int, softcap: float = 0.0
                   ) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Sq, H, hd) with H = KV * G; k/v: (B, Sk, KV, hd).
    Chunks both q (outer loop via scan) and kv (inner online-softmax scan) so
    peak memory is O(q_chunk * kv_chunk) per head -- 32k/500k-safe."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = (q * scale.astype(q.dtype)).reshape(b, sq, kvh, g, hd)

    if sq * skv <= chunk * chunk * 4 or skv <= chunk:
        # small case: single dense pass
        s = _gqa_scores(qg.astype(jnp.float32), k.astype(jnp.float32))
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = s + _mask_bias(q_pos, k_pos, causal, window)[:, None, None]
        p = jax.nn.softmax(s, axis=-1)
        o = _gqa_out(p.astype(v.dtype), v)
        return o.reshape(b, sq, h, hd)

    qc = min(chunk, sq)
    kc = min(chunk, skv)
    nq, nk = sq // qc, skv // kc
    assert sq % qc == 0 and skv % kc == 0, (sq, qc, skv, kc)

    qg_c = qg.reshape(b, nq, qc, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos_c = q_pos.reshape(b, nq, qc).transpose(1, 0, 2)
    k_c = k.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)
    kpos_c = k_pos.reshape(b, nk, kc).transpose(1, 0, 2)

    def q_block(carry, qi):
        qq, qp = qi   # (B, qc, KV, G, hd), (B, qc)

        def kv_block(state, ki):
            m_prev, l_prev, acc = state
            kk, vv, kp = ki
            s = _gqa_scores(qq.astype(jnp.float32), kk.astype(jnp.float32))
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            s = s + _mask_bias(qp, kp, causal, window)[:, None, None]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vv.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (k_c, v_c, kpos_c))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4)   # (B, qc, KV, G, hd)

    _, outs = jax.lax.scan(q_block, None, (qg_c, qpos_c))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out.astype(v.dtype)


# ------------------------------------------------------------ full layer ---
def attention_apply(base: dict, adapters: dict, x: jnp.ndarray,
                    positions: jnp.ndarray, cfg: ModelConfig,
                    acfg: AdapterConfig, qcfg: QuantConfig,
                    cache: Optional[dict] = None,
                    cache_index: Optional[jnp.ndarray] = None,
                    collect_cache: bool = False,
                    constrain=None, adapter_id=None, shard=None,
                    block_tables=None
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d). If cache is given (decode), S == 1 and the KV cache
    {"k","v": (B, S_max, KV, hd)} is updated at cache_index.

    With ``block_tables`` (serving v2), ``cache`` is instead the *paged*
    block pool {"k","v": (NB, bs, KV, hd), "pos": (NB, bs)} shared by all
    requests; ``block_tables`` is (B, NBT) int32 mapping each request's
    position span ``[i*bs, (i+1)*bs)`` to a physical block. S may be > 1
    (a prefill chunk); lanes with ``positions < 0`` are padding and route
    to the reserved null block 0.

    Returns (output (B, S, d), new_cache_or_None)."""
    b, s, d = x.shape
    h, hd, kv = cfg.padded_heads, cfg.head_dim, cfg.num_kv_heads

    def lin(name, inp):
        return adapted_linear(inp, base[name], adapters.get(name), acfg,
                              qcfg, constrain=constrain,
                              adapter_id=adapter_id,
                              shard=shard.linear(name) if shard else None)

    q = lin("q", x).reshape(b, s, h, hd)
    k = lin("k", x).reshape(b, s, kv, hd)
    v = lin("v", x).reshape(b, s, kv, hd)

    if cfg.use_rope:
        cos, sin = rope_tables(positions, hd, cfg.rope_theta, x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None and block_tables is not None:
        # paged decode / chunked prefill: scatter this step's k/v into each
        # request's blocks (block = table[pos // bs], lane = pos % bs), then
        # attend over the gather of the whole table. Stored absolute
        # positions mask invalid lanes, so blocks are exact-length: no
        # padded-tail invalidation, no length bucketing. Padding lanes
        # (positions < 0) write to the reserved null block 0.
        nb, bs = cache["pos"].shape
        nbt = block_tables.shape[1]
        valid = positions >= 0                                    # (B, S)
        blk = jnp.clip(jnp.where(valid, positions, 0) // bs, 0, nbt - 1)
        phys = jnp.take_along_axis(block_tables, blk, axis=1)     # (B, S)
        slot = jnp.where(valid, phys * bs + positions % bs, 0)
        flat = slot.reshape(-1)
        kf = cache["k"].reshape(nb * bs, kv, hd)
        vf = cache["v"].reshape(nb * bs, kv, hd)
        pf = cache["pos"].reshape(nb * bs)
        kf = kf.at[flat].set(k.reshape(-1, kv, hd).astype(kf.dtype))
        vf = vf.at[flat].set(v.reshape(-1, kv, hd).astype(vf.dtype))
        pf = pf.at[flat].set(
            jnp.where(valid, positions, -1).reshape(-1).astype(jnp.int32))
        new_cache = {"k": kf.reshape(nb, bs, kv, hd),
                     "v": vf.reshape(nb, bs, kv, hd),
                     "pos": pf.reshape(nb, bs)}
        k_seq = jnp.take(new_cache["k"], block_tables, axis=0)
        v_seq = jnp.take(new_cache["v"], block_tables, axis=0)
        p_seq = jnp.take(new_cache["pos"], block_tables, axis=0)
        out = attention_core(
            q, k_seq.reshape(b, nbt * bs, kv, hd).astype(q.dtype),
            v_seq.reshape(b, nbt * bs, kv, hd).astype(q.dtype),
            positions, p_seq.reshape(b, nbt * bs), causal=True,
            window=cfg.sliding_window, chunk=cfg.attn_chunk,
            softcap=cfg.attn_logit_softcap)
    elif cache is not None:
        # decode: ring-buffer scatter of this step's k/v. For SWA the cache
        # holds only `window` slots (slot = index % window) and the stored
        # absolute positions make masking exact; for full attention the
        # buffer covers all of s_max so slot == index.
        s_cache = cache["k"].shape[1]
        write = cache_index % s_cache
        slot = jnp.arange(s_cache, dtype=jnp.int32)[None, :]
        hit2 = slot == write.reshape(-1, 1)                       # (B, S_c)
        hit = hit2[:, :, None, None]
        k_cache = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
        k_pos = jnp.where(hit2, positions.astype(jnp.int32),
                          cache["pos"])                           # (B, S_c)
        new_cache = {"k": k_cache, "v": v_cache, "pos": k_pos}
        out = attention_core(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
            positions, k_pos, causal=True, window=cfg.sliding_window,
            chunk=cfg.attn_chunk, softcap=cfg.attn_logit_softcap)
    else:
        out = attention_core(q, k, v, positions, positions,
                             causal=(cfg.causal and not cfg.is_encoder),
                             window=cfg.sliding_window, chunk=cfg.attn_chunk,
                             softcap=cfg.attn_logit_softcap)
        if collect_cache:
            # prefill: the computed k/v ARE the cache (S_max == prefill S);
            # for SWA keep only the trailing window slots (ring layout: slot
            # i holds absolute position aligned with i % window)
            if cfg.sliding_window > 0 and s > cfg.sliding_window:
                w = cfg.sliding_window
                start = s - w
                kk, vv, pp = k[:, start:], v[:, start:], positions[:, start:]
                shift = start % w
                kk = jnp.roll(kk, shift, axis=1)
                vv = jnp.roll(vv, shift, axis=1)
                pp = jnp.roll(pp, shift, axis=1)
                new_cache = {"k": kk, "v": vv, "pos": pp.astype(jnp.int32)}
            else:
                new_cache = {"k": k, "v": v,
                             "pos": positions.astype(jnp.int32)}

    y = lin("o", out.reshape(b, s, h * hd))
    return y, new_cache
