"""Frozen (possibly quantized) linear layers + their adapter defs.

``linear_defs`` gives the base (frozen) parameter layout for one linear --
raw bf16 or NF4/AWQ/int8 quantized -- and ``adapter_defs`` the trainable
adapter layout (OFT packed-skew or LoRA A/B). The apply path is
``repro.core.adapter.adapted_linear``; with ``AdapterConfig.fuse_linear``
that path collapses to one Pallas kernel per linear
(``linear_fusion_mode`` reports which variant a given linear gets).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import methods
from repro.config.base import AdapterConfig, QuantConfig
from repro.core import adapter as ad
from repro.models.spec import CompositeDef, ParamDef
from repro.quant.common import quantize_linear

# Logical (in_axis, out_axis) of every adapted dense linear -- the single
# source the defs below AND the mesh-native fused path
# (repro.distributed.sharding.MeshContext.linear) read, so weight placement
# and the per-shard kernel specs can never disagree.
LINEAR_AXES = {
    "q": ("embed", "heads"),
    "k": ("embed", "kv_heads"),
    "v": ("embed", "kv_heads"),
    "o": ("heads", "embed"),
    "gate": ("embed", "mlp"),
    "up": ("embed", "mlp"),
    "down": ("mlp", "embed"),
}


class QuantLinearDef(CompositeDef):
    """Composite leaf: a quantized frozen linear (codes + scales expand from
    one sampled weight at init; shapes/specs known statically)."""

    def __init__(self, d_in: int, d_out: int, in_axis: Optional[str],
                 out_axis: Optional[str], qcfg: QuantConfig,
                 scale: float = 1.0):
        self.d_in, self.d_out = d_in, d_out
        self.in_axis, self.out_axis = in_axis, out_axis
        self.qcfg = qcfg
        self.scale = scale

    def expand_defs(self) -> dict:
        q = self.qcfg
        d_in, d_out = self.d_in, self.d_out
        ia, oa = self.in_axis, self.out_axis
        if q.kind == "nf4":
            nb = d_in // q.block_size
            defs = {"nf4_codes": ParamDef((d_in // 2, d_out), (ia, oa),
                                          "zeros", dtype=jnp.uint8)}
            if q.double_quant and d_out % q.double_block == 0:
                defs["absmax_q8"] = ParamDef((nb, d_out), (ia, oa), "zeros",
                                             dtype=jnp.int8)
                defs["absmax_scale"] = ParamDef(
                    (nb, d_out // q.double_block), (ia, oa), "ones",
                    dtype=jnp.float32)
                defs["absmax_offset"] = ParamDef((), (), "zeros",
                                                 dtype=jnp.float32)
            else:
                defs["absmax"] = ParamDef((nb, d_out), (ia, oa), "ones",
                                          dtype=jnp.float32)
            return defs
        if q.kind == "awq":
            ng = d_in // q.group_size
            return {
                "awq_codes": ParamDef((d_in // 2, d_out), (ia, oa), "zeros",
                                      dtype=jnp.uint8),
                "awq_scale": ParamDef((ng, d_out), (ia, oa), "ones",
                                      dtype=jnp.float32),
                "awq_zero": ParamDef((ng, d_out), (ia, oa), "zeros",
                                     dtype=jnp.int8),
                "awq_act_scale": ParamDef((d_in,), (ia,), "ones",
                                          dtype=jnp.float32),
            }
        if q.kind == "int8":
            return {
                "int8_codes": ParamDef((d_in, d_out), (ia, oa), "zeros",
                                       dtype=jnp.int8),
                "int8_scale": ParamDef((d_out,), (oa,), "ones",
                                       dtype=jnp.float32),
            }
        raise ValueError(self.qcfg.kind)

    def init(self, key, param_dtype):
        import numpy as np
        std = self.scale / np.sqrt(self.d_in)
        w = std * jax.random.normal(key, (self.d_in, self.d_out), jnp.float32)
        return quantize_linear(w, self.qcfg)


def linear_defs(d_in: int, d_out: int, in_axis: Optional[str],
                out_axis: Optional[str], qcfg: QuantConfig,
                scale: float = 1.0):
    """Base (frozen) defs for one linear: {"w": ...} or quantized composite."""
    quantizable = qcfg.enabled and d_in % 2 == 0
    if qcfg.kind == "nf4":
        quantizable = quantizable and d_in % qcfg.block_size == 0
    elif qcfg.kind == "awq":
        quantizable = quantizable and d_in % qcfg.group_size == 0
    if not quantizable:
        # raw bf16 weight (also the fallback for layers too small/misaligned
        # to quantize, e.g. tiny smoke configs)
        return {"w": ParamDef((d_in, d_out), (in_axis, out_axis), "normal",
                              scale=scale)}
    return QuantLinearDef(d_in, d_out, in_axis, out_axis, qcfg, scale=scale)


def _is_quantized(defs) -> bool:
    return isinstance(defs, QuantLinearDef)


def linear_fusion_mode(name: str, d_in: int, d_out: int, acfg: AdapterConfig,
                       qcfg: QuantConfig, scale: float = 1.0) -> str:
    """Which fused forward THIS linear takes under the given configs, per
    the adapter method's registry entry: 'qoft_fused' | 'oftv2_fused' |
    'hoft_fused' | 'unfused'.  Resolves the same quantizability rules
    linear_defs applies (a layer too small/misaligned to quantize falls
    back to the dense fused path), so benchmarks and the launch dry-run can
    report the per-layer fusion plan without building params."""
    if not ad.wants_adapter(name, acfg):
        return "unfused"
    defs = linear_defs(d_in, d_out, in_axis=None, out_axis=None, qcfg=qcfg,
                       scale=scale)
    keys = (defs.expand_defs().keys() if _is_quantized(defs)
            else defs.keys())
    return ad.fusion_mode(acfg, qcfg, keys)


def multi_fusion_mode(name: str, d_in: int, d_out: int, acfg: AdapterConfig,
                      qcfg: QuantConfig, scale: float = 1.0) -> str:
    """Which multi-adapter serving kernel THIS linear takes when its params
    come from an adapter pool (repro.serving.pool): 'qoft_multi' |
    'oftv2_multi' | 'unfused'.  Mirrors linear_fusion_mode so serving
    benchmarks can emit a check_fusion-gated plan for the multi kernels."""
    mode = linear_fusion_mode(name, d_in, d_out, acfg, qcfg, scale=scale)
    # methods without multi-adapter kernels (the registry's
    # supports_multi_tenant=False set) report 'unfused' in the serving plan
    return {"qoft_fused": "qoft_multi",
            "oftv2_fused": "oftv2_multi"}.get(mode, "unfused")


def layer_linear_shapes(cfg) -> dict:
    """{name: (d_in, d_out)} of the dense adapted linears of one
    transformer layer of ``cfg`` -- shared by the fusion-plan reports and
    the config-time mesh validation (make_shard_context)."""
    d = cfg.d_model
    h, kv, hd = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {"q": (d, h * hd), "k": (d, kv * hd), "v": (d, kv * hd),
              "o": (h * hd, d)}
    if cfg.d_ff > 0:
        shapes.update({"gate": (d, cfg.d_ff), "up": (d, cfg.d_ff),
                       "down": (cfg.d_ff, d)})
    return shapes


def model_multi_fusion_plan(cfg, acfg: AdapterConfig,
                            qcfg: QuantConfig) -> dict:
    """Per-linear multi-adapter serving plan for a transformer layer of
    ``cfg``: {name: 'qoft_multi' | 'oftv2_multi' | 'unfused'}.  Emitted by
    benchmarks/serving_bench.py as ``fusion_plan/serving/*`` rows so the
    existing check_fusion CI gate also fails on a silent fallback of the
    serving path."""
    return {name: multi_fusion_mode(name, di, do, acfg, qcfg)
            for name, (di, do) in layer_linear_shapes(cfg).items()}


def model_fusion_plan(cfg, acfg: AdapterConfig, qcfg: QuantConfig) -> dict:
    """Per-linear fusion plan for a transformer layer of ``cfg``
    (ModelConfig): {name: 'qoft_fused' | 'oftv2_fused' | 'unfused'}.

    The benchmark smoke run emits these as ``fusion_plan/*`` rows and CI
    fails if a path expected to fuse reports 'unfused' -- a silent fallback
    to the oracle is a perf regression, not a correctness one, so tests
    alone don't catch it."""
    return {name: linear_fusion_mode(name, di, do, acfg, qcfg)
            for name, (di, do) in layer_linear_shapes(cfg).items()}


def sharded_fusion_mode(name: str, d_in: int, d_out: int,
                        acfg: AdapterConfig, qcfg: QuantConfig, rules,
                        axis_sizes: dict, scale: float = 1.0) -> str:
    """Which fused forward THIS linear takes under a mesh whose axis sizes
    are ``axis_sizes`` ({mesh_axis: size}) and whose logical mapping is
    ``rules``: the single-device mode, demoted to 'unfused' when the method
    lacks the ``shards`` capability or the shapes cannot shard (the same
    ``check_sharding`` validation make_shard_context enforces).  Needs no
    devices, so benchmarks can emit the sharded plan on any host."""
    mode = linear_fusion_mode(name, d_in, d_out, acfg, qcfg, scale=scale)
    if mode == "unfused":
        return mode
    method = methods.get(acfg.kind)
    if not method.supports_sharding:
        return "unfused"

    def shards(logical):
        ax = rules.lookup(logical)
        if ax is None:
            return 1
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axes:
            n *= axis_sizes.get(a, 1)
        return n

    in_axis, out_axis = LINEAR_AXES.get(name, (None, None))
    try:
        method.check_sharding(name, d_in, d_out, acfg, qcfg,
                              k_shards=shards(in_axis),
                              n_shards=shards(out_axis))
    except (ValueError, NotImplementedError):
        return "unfused"
    return mode


def model_sharded_fusion_plan(cfg, acfg: AdapterConfig, qcfg: QuantConfig,
                              pcfg) -> dict:
    """Per-linear plan of the mesh-native fused path under ``pcfg``'s mesh
    (fused_tp rules): {name: mode}.  benchmarks/sharded_bench.py emits
    these as ``fusion_plan/sharded/*`` rows, so the check_fusion CI gate
    also fails when the SHARDED path would silently fall back to unfused
    (replicating W under the mesh is a scaling regression tests can't
    see)."""
    from repro.models.spec import rules_variant
    rules = rules_variant(pcfg, "fused_tp")
    axis_sizes = dict(zip(pcfg.mesh_axes, pcfg.mesh_shape))
    return {name: sharded_fusion_mode(name, di, do, acfg, qcfg, rules,
                                      axis_sizes)
            for name, (di, do) in layer_linear_shapes(cfg).items()}


def adapter_defs(name: str, d_in: int, d_out: int, acfg: AdapterConfig,
                 model_axis_size: int = 1):
    """Trainable adapter defs for one linear (None if not targeted), from
    the method's ``param_defs`` registry hook -- the per-method layout
    (OFT packed skew + TP block sharding, LoRA A/B, HOFT reflection
    vectors) lives with the method, not here."""
    if not ad.wants_adapter(name, acfg):
        return None
    return methods.get(acfg.kind).param_defs(name, d_in, d_out, acfg,
                                             model_axis_size)
