"""Dry-run cell setup: for one (arch x shape x mesh) cell build the model,
abstract inputs (ShapeDtypeStruct -- weak-type-correct, shardable, no device
allocation), and the matching sharding trees.

This module must be import-safe before jax device init (dryrun.py sets
XLA_FLAGS first); it only touches jax inside functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.config.base import (SHAPES, AdapterConfig, ModelConfig,
                               ParallelConfig, QuantConfig, RunConfig,
                               ShapePreset, TrainConfig)
from repro.configs import get_config
from repro.distributed.sharding import (axis_size, make_constrain,
                                        named_sharding_tree)
from repro.launch.mesh import production_parallel_config
from repro.models import build
from repro.models.model import Model
from repro.models.spec import default_rules, rules_variant
from repro.optim.adamw import AdamWState
from repro.train import state as state_lib
from repro.train.step import (make_serve_decode, make_serve_prefill,
                              make_train_step)

SDS = jax.ShapeDtypeStruct


def checked_spec(shape: Tuple[int, ...], spec: PartitionSpec,
                 mesh: Mesh) -> PartitionSpec:
    """Drop spec entries that don't divide the dim (e.g. batch=1 long_500k)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        n = axis_size(mesh, ax) if ax is not None else 1
        out.append(ax if (n > 1 and dim % n == 0 and dim >= n) else None)
    return PartitionSpec(*out)


def checked_sharding_tree(abstract: Any, specs: Any, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda a, s: NamedSharding(mesh, checked_spec(a.shape, s, mesh)),
        abstract, specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec) or x is None)


@dataclass
class Cell:
    arch: str
    shape: ShapePreset
    run: RunConfig
    model: Model
    step_fn: Callable
    abstract_args: tuple
    arg_shardings: tuple
    mode: str


def _batch_abstract(cfg: ModelConfig, shape: ShapePreset):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((b, 1), jnp.int32),
                "positions": SDS((b, 1), jnp.int32),
                "cache_index": SDS((b,), jnp.int32)}
    if cfg.frontend == "audio_frames":
        d = {"frames": SDS((b, s, cfg.frontend_dim), jnp.bfloat16),
             "labels": SDS((b, s), jnp.int32)}
        return d
    if cfg.frontend == "vision_patches":
        n = cfg.num_frontend_tokens
        return {"tokens": SDS((b, s - n), jnp.int32),
                "patches": SDS((b, n, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": SDS((b, s), jnp.int32)}


def _batch_specs(batch_abs, rules):
    lead = rules.lookup("batch")

    def spec(a):
        return PartitionSpec(lead, *([None] * (len(a.shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_abs)


def abstract_train_state(model: Model):
    params = model.abstract_params()
    adapter = params["adapter"]
    f32 = jax.tree_util.tree_map(lambda a: SDS(a.shape, jnp.float32), adapter)
    opt = AdamWState(step=SDS((), jnp.int32), mu=f32,
                     nu=jax.tree_util.tree_map(lambda x: x, f32))
    return state_lib.TrainState(step=SDS((), jnp.int32),
                                base=params["base"], adapter=adapter,
                                opt=opt, comp_err=None)


def train_state_specs(model: Model, rules):
    specs = model.param_specs(rules)
    aspec = specs["adapter"]
    opt = AdamWState(step=PartitionSpec(), mu=aspec,
                     nu=jax.tree_util.tree_map(lambda x: x, aspec))
    return state_lib.TrainState(step=PartitionSpec(), base=specs["base"],
                                adapter=aspec, opt=opt, comp_err=None)


def make_cell(arch: str, shape_name: str, mesh: Mesh, *, multi_pod: bool,
              adapter_kind: str = "oftv2", quant_kind: str = "none",
              microbatches: int = 4, remat: str = "full",
              overrides: Optional[dict] = None,
              global_batch_override: int = 0,
              rules_preset: str = "baseline") -> Cell:
    shape = SHAPES[shape_name]
    if global_batch_override:
        shape = dataclasses.replace(shape,
                                    global_batch=global_batch_override)
    pcfg = production_parallel_config(
        multi_pod=multi_pod,
        microbatches=microbatches if shape.kind == "train" else 1,
        remat=remat)
    model_axis = pcfg.model_axis_size
    cfg = get_config(arch).with_mesh_padding(model_axis)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=adapter_kind, block_size=32,
                              neumann_terms=5),
        quant=QuantConfig(kind=quant_kind),
        parallel=pcfg,
        train=TrainConfig(global_batch=shape.global_batch,
                          seq_len=shape.seq_len, steps=1000,
                          warmup_steps=100))
    rules = rules_variant(pcfg, rules_preset)
    model = build(run, constrain=make_constrain(rules, mesh))

    batch_abs = _batch_abstract(cfg, shape)
    batch_specs = _batch_specs(batch_abs, rules)
    batch_shardings = checked_sharding_tree(batch_abs, batch_specs, mesh)

    if shape.kind == "train":
        state_abs = abstract_train_state(model)
        state_specs = train_state_specs(model, rules)
        state_shardings = jax.tree_util.tree_map(
            lambda a, s: NamedSharding(mesh, checked_spec(a.shape, s, mesh)),
            state_abs, state_specs,
            is_leaf=lambda x: isinstance(x, (PartitionSpec,
                                             jax.ShapeDtypeStruct)))
        fn = make_train_step(model, run)
        return Cell(arch, shape, run, model, fn,
                    (state_abs, batch_abs), (state_shardings,
                                             batch_shardings), "train")

    params_abs = model.abstract_params()
    params_specs = model.param_specs(rules)
    params_shardings = checked_sharding_tree(params_abs, params_specs, mesh)

    if shape.kind == "prefill":
        fn = make_serve_prefill(model)
        return Cell(arch, shape, run, model, fn,
                    (params_abs, batch_abs),
                    (params_shardings, batch_shardings), "prefill")

    # decode
    caches_abs = model.make_caches(shape.global_batch, shape.seq_len,
                                   abstract=True)
    caches_specs = model.cache_specs(rules, shape.global_batch,
                                     shape.seq_len)
    caches_shardings = checked_sharding_tree(caches_abs, caches_specs, mesh)
    batch_abs["caches"] = caches_abs
    batch_shardings["caches"] = caches_shardings
    fn = make_serve_decode(model)
    return Cell(arch, shape, run, model, fn,
                (params_abs, batch_abs), (params_shardings,
                                          batch_shardings), "decode")
