import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is normal module code.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --jobs 3

Per cell it records (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * compiled.memory_analysis()  -- per-device bytes (proves it fits)
  * compiled.cost_analysis()    -- per-device FLOPs / HBM bytes
  * collective wire bytes       -- parsed from the post-SPMD optimized HLO
  * the three roofline terms + bottleneck + MODEL_FLOPS ratio
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _compile_cell(cell, mesh):
    import jax
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.arg_shardings)
        lowered = jitted.lower(*cell.abstract_args)
        compiled = lowered.compile()
    return lowered, compiled


def _cost_of(compiled):
    cost_raw = compiled.cost_analysis()
    if isinstance(cost_raw, list):
        cost_raw = cost_raw[0] if cost_raw else {}
    return {k: float(v) for k, v in cost_raw.items()
            if isinstance(v, (int, float))}


def run_probes(arch, shape_name, mesh, multi_pod, adapter, quant,
               microbatches, remat, overrides, n_dev,
               rules_preset="baseline"):
    """Two unrolled reduced-depth compiles (g=1, g=2): HLO cost analysis
    counts scan bodies once, so per-layer-group flops/bytes/collective
    deltas are recovered from unrolled probes and extrapolated to full
    depth (x microbatches for train). DESIGN.md §Roofline-method."""
    from repro.config.base import SHAPES
    from repro.configs import get_config
    from repro.launch.cells import make_cell
    from repro.roofline import analysis as ra

    shape = SHAPES[shape_name]
    cfg_full = get_config(arch)
    sb = max(cfg_full.scan_block, 1)
    n_groups = cfg_full.num_layers // sb
    m = microbatches if shape.kind == "train" else 1
    gb = shape.global_batch
    probe_batch, scale = 0, 1.0
    if shape.kind == "train":
        # probe at the per-microbatch batch, floored at the batch-shard
        # count (a smaller batch would replicate instead of shard and blow
        # up per-device numbers); `scale` renormalizes the batch-linear
        # quantities when the floor binds (only in batch-everywhere presets
        # where there are no weight-gather collectives to misattribute).
        from repro.distributed.sharding import axis_size
        from repro.models.spec import rules_variant
        from repro.launch.mesh import production_parallel_config
        pcfg_p = production_parallel_config(multi_pod=multi_pod)
        rules = rules_variant(pcfg_p, rules_preset)
        shards = min(axis_size(mesh, rules.lookup("batch")), gb)
        probe_batch = max(gb // m, shards)
        scale = (gb / m) / probe_batch

    stats = {}
    for g in (1, 2):
        ov = dict(overrides or {})
        ov.update(num_layers=sb * g, scan_layers=False)
        cellp = make_cell(arch, shape_name, mesh, multi_pod=multi_pod,
                          adapter_kind=adapter, quant_kind=quant,
                          microbatches=1, remat=remat, overrides=ov,
                          global_batch_override=probe_batch,
                          rules_preset=rules_preset)
        _, compiled = _compile_cell(cellp, mesh)
        cost = _cost_of(compiled)
        wire, _ = ra.parse_collectives(compiled.as_text(), n_dev)
        stats[g] = {"flops": cost.get("flops", 0.0),
                    "bytes": cost.get("bytes accessed", 0.0),
                    "wire": wire}

    out = {"probe_raw": stats, "n_groups": n_groups, "microbatches": m,
           "batch_scale": scale}
    for key in ("flops", "bytes", "wire"):
        body = max(stats[2][key] - stats[1][key], 0.0)
        base = max(stats[1][key] - body, 0.0)
        out[key] = m * scale * (base + body * n_groups)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             adapter: str = "oftv2", quant: str = "none",
             microbatches: int = 4, remat: str = "full",
             dump_hlo: bool = False, tag: str = "",
             overrides: dict | None = None, probes: bool = True,
             rules_preset: str = "baseline") -> dict:
    import jax
    from repro.config.base import SHAPES
    from repro.launch.cells import make_cell
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as ra
    from repro.roofline.hw import V5E

    multi_pod = mesh_kind == "multi"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cell = make_cell(arch, shape_name, mesh, multi_pod=multi_pod,
                     adapter_kind=adapter, quant_kind=quant,
                     microbatches=microbatches, remat=remat,
                     overrides=overrides, rules_preset=rules_preset)

    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.arg_shardings)
        lowered = jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- memory analysis (proves it fits) -------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
        print("memory_analysis:", mem)
    except Exception as e:                                    # noqa: BLE001
        mem = {"error": str(e)}
        print("memory_analysis unavailable:", e)

    # ---- cost analysis ---------------------------------------------------
    cost_raw = compiled.cost_analysis()
    if isinstance(cost_raw, list):
        cost_raw = cost_raw[0] if cost_raw else {}
    cost = {k: float(v) for k, v in cost_raw.items()
            if isinstance(v, (int, float))}
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    print(f"cost_analysis: flops={flops:.3e} bytes={bytes_acc:.3e}")

    # ---- collectives from post-SPMD HLO ---------------------------------
    hlo = compiled.as_text()
    wire_bytes, per_kind = ra.parse_collectives(hlo, n_dev)
    if dump_hlo:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        (ARTIFACTS / f"{arch}__{shape_name}__{mesh_kind}{tag}.hlo"
         ).write_text(hlo)

    # ---- probe calibration (scan bodies are cost-counted once) ----------
    shape = SHAPES[shape_name]
    probe = None
    cal_flops, cal_bytes, cal_wire = flops, bytes_acc, wire_bytes
    if probes:
        probe = run_probes(arch, shape_name, mesh, multi_pod, adapter,
                           quant, microbatches, remat, overrides, n_dev,
                           rules_preset=rules_preset)
        cal_flops, cal_bytes, cal_wire = (probe["flops"], probe["bytes"],
                                          probe["wire"])
        if cell.mode in ("train", "prefill"):
            # chunked-attention core runs under lax.scan -> add analytically
            from repro.distributed.sharding import axis_size
            from repro.models.spec import rules_variant
            rules = rules_variant(cell.run.parallel, rules_preset)
            batch_shards = min(axis_size(mesh, rules.lookup("batch")),
                               shape.global_batch)
            head_shards = axis_size(mesh, rules.lookup("heads"))
            corr = ra.attention_correction(
                cell.run.model, shape.seq_len, shape.global_batch,
                cell.mode, batch_shards, head_shards,
                microbatches=(microbatches if cell.mode == "train" else 1))
            cfgm = cell.run.model
            n_attn = sum(0 if cfgm.is_ssm_layer(i) else 1
                         for i in range(cfgm.num_layers))
            probe["attn_correction_per_layer"] = corr
            cal_flops += corr["flops"] * n_attn
            cal_bytes += corr["bytes"] * n_attn

    # ---- roofline --------------------------------------------------------
    terms = ra.roofline_terms(cal_flops, cal_bytes, cal_wire)
    tokens = shape.global_batch * (shape.seq_len if cell.mode == "train"
                                   else (shape.seq_len if cell.mode ==
                                         "prefill" else 1))
    mf = ra.model_flops(cell.run.model, tokens, cell.mode)
    mf_per_dev = mf / n_dev
    useful = mf_per_dev / cal_flops if cal_flops else 0.0

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": n_dev, "mode": cell.mode, "adapter": adapter,
        "quant": quant, "microbatches": microbatches, "remat": remat,
        "tag": tag, "overrides": overrides or {},
        "rules_preset": rules_preset,
        "adapter_params": cell.model.param_counts()["adapter"],
        "base_params": cell.model.param_counts()["base"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": {"flops_raw": flops, "bytes_raw": bytes_acc,
                          "flops": cal_flops, "bytes_accessed": cal_bytes},
        "collectives": {"wire_bytes_raw": wire_bytes,
                        "wire_bytes_per_device": cal_wire,
                        "per_kind": per_kind},
        "probe": probe,
        "roofline": terms,
        "model_flops": {"global": mf, "per_device": mf_per_dev,
                        "useful_fraction": useful},
        "hw": {"peak_flops": V5E.peak_flops_bf16, "hbm_bw": V5E.hbm_bw,
               "link_bw": V5E.ici_link_bw},
    }
    print(f"roofline: compute={terms['compute_s']:.4e}s "
          f"memory={terms['memory_s']:.4e}s "
          f"collective={terms['collective_s']:.4e}s "
          f"bottleneck={terms['bottleneck']} useful={useful:.2f}")
    return record


def cell_path(arch, shape, mesh_kind, tag="") -> Path:
    return ARTIFACTS / f"{arch}__{shape}__{mesh_kind}{tag}.json"


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    from repro.methods import available as _adapter_kinds
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--adapter", default="oftv2",
                   choices=list(_adapter_kinds()))
    p.add_argument("--quant", default="none")
    p.add_argument("--microbatches", type=int, default=4)
    p.add_argument("--remat", default="full")
    p.add_argument("--dump-hlo", action="store_true")
    p.add_argument("--no-probes", action="store_true",
                   help="skip calibration probes (multi-pod cells: the "
                        "roofline table is single-pod only)")
    p.add_argument("--tag", default="", help="artifact suffix for variants")
    p.add_argument("--rules", default="baseline",
                   choices=["baseline", "dp", "dp_fsdp", "ep_model"])
    p.add_argument("--override", action="append", default=[],
                   help="cfg overrides key=value (int/float/bool)")
    p.add_argument("--all", action="store_true",
                   help="run every runnable cell x both meshes (subprocesses)")
    p.add_argument("--jobs", type=int, default=2)
    p.add_argument("--cell-timeout", type=float, default=2400.0)
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import cells as cell_matrix
        todo = []
        for arch, shape, skip in cell_matrix():
            for mesh_kind in ("single", "multi"):
                path = cell_path(arch, shape, mesh_kind)
                if skip:
                    path.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh_kind,
                         "skipped": skip}, indent=1))
                    continue
                if path.exists() and not args.force:
                    continue
                todo.append((arch, shape, mesh_kind))
        # single-pod first: the roofline table depends on those
        todo.sort(key=lambda t: (t[2] != "single",))
        print(f"[dryrun] {len(todo)} cells to compile")
        procs: list = []
        fails = []
        while todo or procs:
            while todo and len(procs) < args.jobs:
                arch, shape, mesh_kind = todo.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh",
                       mesh_kind, "--microbatches", str(args.microbatches)]
                if mesh_kind == "multi":
                    cmd.append("--no-probes")
                print(f"[dryrun] start {arch} {shape} {mesh_kind}",
                      flush=True)
                procs.append(((arch, shape, mesh_kind), time.time(),
                              subprocess.Popen(
                                  cmd, stdout=subprocess.PIPE,
                                  stderr=subprocess.STDOUT, text=True)))
            still = []
            for key, t_start, proc in procs:
                if proc.poll() is None:
                    if time.time() - t_start > args.cell_timeout:
                        proc.kill()
                        fails.append(key)
                        print(f"[dryrun] TIMEOUT {key}", flush=True)
                    else:
                        still.append((key, t_start, proc))
                else:
                    out = proc.stdout.read()
                    ok = proc.returncode == 0
                    print(f"[dryrun] done {key} rc={proc.returncode} "
                          f"({time.time() - t_start:.0f}s)", flush=True)
                    if not ok:
                        fails.append(key)
                        (ARTIFACTS / ("FAIL__%s__%s__%s.log" % key)
                         ).write_text(out)
            procs = still
            time.sleep(2)
        print(f"[dryrun] complete; {len(fails)} failures: {fails}")
        return 1 if fails else 0

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = {"true": True, "false": False}.get(v, v)

    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       adapter=args.adapter, quant=args.quant,
                       microbatches=args.microbatches, remat=args.remat,
                       dump_hlo=args.dump_hlo, tag=args.tag,
                       overrides=overrides or None,
                       probes=not args.no_probes,
                       rules_preset=args.rules)
    except Exception:
        traceback.print_exc()
        return 1
    path = cell_path(args.arch, args.shape, args.mesh, args.tag)
    path.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
