"""Serving entrypoint: batched generation with (optionally quantized) frozen
base + unmerged OFTv2/LoRA adapters.

Single-adapter:

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --quant nf4 --batch 4 --prompt-len 16 --gen 16

Multi-tenant (--adapters N > 1): N adapters are registered against the one
frozen base in an AdapterPool and a continuous-batching ServingEngine
decodes a mixed-adapter batch -- every request row routed to its adapter's
rotation blocks inside the fused Pallas kernels:

    PYTHONPATH=src python -m repro.launch.serve --smoke --adapters 3

Mesh-native serving (--mesh data,model --mesh-shape 2,4): the slot batch
shards over `data`, W / NF4 state / r_stack shard over `model`, and the
multi-routing kernels run per-shard in shard_map:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --smoke --adapters 3 \
        --mesh data,model --mesh-shape 2,4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import methods, obs
from repro.config.base import (AdapterConfig, ParallelConfig, QuantConfig,
                               RunConfig)
from repro.configs import REGISTRY, get_config, get_smoke
from repro.models import build
from repro.models.linears import model_multi_fusion_plan
from repro.train.serving import generate


def _serve_single(model, params, args, cfg):
    from repro.serving import SamplingParams
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompts,
                   sampling=SamplingParams(
                       max_new_tokens=args.gen,
                       temperature=args.temperature or None),
                   jit=not args.no_jit)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name} {args.adapter}/{args.quant}: generated "
          f"{out.shape} in {dt:.1f}s ({tok_s:.1f} tok/s batched)")
    print(out[:, args.prompt_len:])


def _serve_multi(model, params, args, cfg):
    from repro.serving import AdapterPool, Request, SamplingParams, \
        ServingEngine, init_adapters

    pool = AdapterPool(model)
    for i, tree in enumerate(init_adapters(model, args.adapters,
                                           jax.random.PRNGKey(2))):
        pool.register(f"tenant-{i}", tree)
    counts = pool.param_counts()
    plan = model_multi_fusion_plan(cfg, model.run.adapter, model.run.quant)
    print(f"[serve] pool: {pool.n_adapters} adapters x "
          f"{counts['adapter_each']:,} params on one "
          f"{counts['base']:,}-param frozen base; "
          f"plan={{{', '.join(f'{k}:{v}' for k, v in sorted(plan.items()))}}}")

    key = jax.random.PRNGKey(1)
    sampling = SamplingParams(max_new_tokens=args.gen,
                              temperature=args.temperature or None)
    requests = []
    for i in range(args.batch):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (args.prompt_len,), 0,
            cfg.vocab_size))
        requests.append(Request(f"req-{i}", prompt,
                                adapter_id=i % args.adapters,
                                sampling=sampling))
    engine = ServingEngine(model, params, pool, n_slots=args.slots
                           or args.batch, jit=not args.no_jit,
                           mode=args.engine, page_size=args.page_size,
                           prefill_chunk=args.prefill_chunk)
    t0 = time.time()
    for req in requests:
        engine.submit(req)
    if args.chaos_seize > 0 and args.engine == "paged":
        # graceful-degradation smoke: steal KV blocks mid-flight, let the
        # engine preempt/requeue its way through, then lift the pressure
        results = {}
        for _ in range(3):
            for r in engine.step():
                results[r.rid] = r
        seized = engine.kv.seize(args.chaos_seize)
        print(f"[serve] chaos: seized {seized} KV blocks mid-flight")
        for _ in range(8):
            for r in engine.step():
                results[r.rid] = r
        print(f"[serve] health under pressure: {engine.health()}")
        engine.kv.release_seized()
        results.update(engine.drain())
        engine.kv.audit()
    else:
        results = engine.drain()
    dt = time.time() - t0
    total = sum(r.n_generated for r in results.values())
    print(f"[serve] {cfg.name} multi-tenant {args.adapter}/{args.quant} "
          f"({args.engine} engine): {len(requests)} requests over "
          f"{args.adapters} adapters, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s batched)")
    for req in requests:
        r = results[req.rid]
        print(f"  {r.rid} (adapter {req.adapter_id}, {r.finish_reason}, "
              f"ttft {r.ttft * 1e3:.0f}ms, latency {r.latency * 1e3:.0f}ms, "
              f"{r.prefix_blocks_shared} shared blocks): {r.tokens}")
    h = engine.health()
    print(f"[serve] health: inflight={h['inflight']} pending={h['pending']} "
          f"requeued={h['requeued']} counters={h['counters']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(REGISTRY))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--adapter", default="oftv2",
                    choices=list(methods.available()))
    ap.add_argument("--adapters", type=int, default=1,
                    help="serve N adapters against the one frozen base "
                         "(multi-tenant engine; implies --fuse)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode batch slots for the multi-tenant engine "
                         "(0 = one per request)")
    ap.add_argument("--quant", default="none",
                    choices=["none", "nf4", "awq", "int8"])
    ap.add_argument("--fuse", action="store_true",
                    help="fused Pallas linears for the OFTv2 path")
    ap.add_argument("--no-jit", action="store_true",
                    help="eager decode (debugging escape hatch)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine", default="paged", choices=["paged", "slots"],
                    help="multi-tenant data plane: paged KV cache with "
                         "chunked prefill + prefix sharing (v2, default) "
                         "or the fixed-slot v1 scheduler")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV block size (tokens) for --engine paged")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefilled per tick per request "
                         "for --engine paged")
    ap.add_argument("--chaos-seize", type=int, default=0,
                    help="chaos: seize N KV blocks mid-flight (paged "
                         "engine) to exercise the preempt/requeue "
                         "degradation path; implies a health printout")
    ap.add_argument("--mesh", default="none",
                    help="'none' | comma axis list (e.g. 'data,model') "
                         "with --mesh-shape: mesh-native serving")
    ap.add_argument("--mesh-shape", default="",
                    help="comma ints matching --mesh, e.g. '2,4'")
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry export dir: metrics.jsonl + "
                         "metrics.prom + spans.jsonl written on exit "
                         "(repro.obs)")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus-style GET /metrics on this "
                         "port for the run's duration (0 = ephemeral)")
    ap.add_argument("--profile-dir", default="",
                    help="bridge obs spans into a jax.profiler trace "
                         "written under this directory")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only architectures have no decode step")
    multi = args.adapters > 1
    if multi and not methods.get(args.adapter).supports_multi_tenant:
        raise SystemExit(
            f"--adapters N>1 needs an adapter method with multi-tenant "
            f"serving support; {args.adapter!r} has none (methods that "
            f"do: {list(methods.supporting('supports_multi_tenant'))})")

    mesh, rules = None, None
    pcfg = ParallelConfig()
    if args.mesh != "none":
        from repro.models.spec import rules_variant
        axes = tuple(a for a in args.mesh.split(",") if a)
        shape = tuple(int(s) for s in args.mesh_shape.split(",") if s)
        if len(shape) != len(axes):
            raise SystemExit("--mesh axes and --mesh-shape must match "
                             f"(got {axes} vs {shape})")
        mesh = jax.make_mesh(shape, axes)
        pcfg = ParallelConfig(mesh_shape=shape, mesh_axes=axes)
        cfg = cfg.with_mesh_padding(pcfg.model_axis_size)
        rules = rules_variant(pcfg, "fused_tp")

    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind=args.adapter,
                                          block_size=args.block_size,
                                          neumann_terms=5,
                                          fuse_linear=args.fuse or multi
                                          or mesh is not None),
                    quant=QuantConfig(kind=args.quant),
                    parallel=pcfg)
    server = None
    if args.metrics_port >= 0:
        server = obs.serve_metrics(args.metrics_port)
        print(f"[serve] metrics on "
              f"http://127.0.0.1:{server.port}/metrics")
    if args.profile_dir:
        obs.TRACER.start_profile(args.profile_dir)
    try:
        if mesh is not None:
            from repro.distributed.sharding import (fit_tree, make_constrain,
                                                    make_shard_context)
            shard_ctx = make_shard_context(mesh, rules, run)
            model = build(run, constrain=make_constrain(rules, mesh),
                          shard=shard_ctx)
            params = fit_tree(model.init(jax.random.PRNGKey(0)),
                              model.param_specs(rules), mesh)
            with mesh:
                if multi:
                    _serve_multi(model, params, args, cfg)
                else:
                    _serve_single(model, params, args, cfg)
            return
        model = build(run)
        params = model.init(jax.random.PRNGKey(0))
        if multi:
            _serve_multi(model, params, args, cfg)
        else:
            _serve_single(model, params, args, cfg)
    finally:
        if args.profile_dir:
            obs.TRACER.stop_profile()
        if args.metrics_dir:
            obs.dump(args.metrics_dir)
        if server is not None:
            server.close()


if __name__ == "__main__":
    main()
