"""Serving entrypoint: batched generation with (optionally quantized) frozen
base + unmerged OFTv2/LoRA adapters.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --quant nf4 --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config.base import AdapterConfig, QuantConfig, RunConfig
from repro.configs import REGISTRY, get_config, get_smoke
from repro.models import build
from repro.train.serving import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(REGISTRY))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--adapter", default="oftv2",
                    choices=["oftv2", "lora", "none"])
    ap.add_argument("--quant", default="none",
                    choices=["none", "nf4", "awq", "int8"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only architectures have no decode step")
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind=args.adapter, block_size=32,
                                          neumann_terms=5),
                    quant=QuantConfig(kind=args.quant))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompts, steps=args.gen,
                   temperature=args.temperature)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name} {args.adapter}/{args.quant}: generated "
          f"{out.shape} in {dt:.1f}s ({tok_s:.1f} tok/s batched)")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
