"""Production training entrypoint.

Single-host CPU (default) or on-mesh SPMD when --mesh is given.  On a real
multi-host TPU deployment each host runs this same binary (jax.distributed
initializes from the standard env vars; see run_multipod.sh) -- the loop,
checkpointing, preemption handling and data slicing are already
process-aware.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --smoke --adapter oftv2 --steps 50

--mesh also accepts an explicit axis list with --mesh-shape, e.g.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --smoke --fuse \
        --mesh data,model --mesh-shape 2,4

which runs the mesh-native fused path (fused_tp rules): batch data-sharded,
W / NF4 state / rotation blocks TP-sharded over `model`, fused kernels
per-shard in shard_map (README "Sharded execution").
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import methods, obs
from repro.config.base import (AdapterConfig, QuantConfig, RunConfig,
                               TrainConfig)
from repro.configs import REGISTRY, get_config, get_smoke
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticSpec
from repro.distributed.fault import PreemptionGuard
from repro.distributed.sharding import (fit_tree, make_constrain,
                                        make_shard_context)
from repro.launch.mesh import production_parallel_config
from repro.models import build
from repro.models.spec import rules_variant
from repro.train.loop import run_training


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list(REGISTRY))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--adapter", default="oftv2",
                    choices=list(methods.available()))
    ap.add_argument("--quant", default="none",
                    choices=["none", "nf4", "awq", "int8"])
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--neumann", type=int, default=5)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=4e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--mesh", default="none",
                    help="'none' | 'single' | 'multi' (production v5e "
                         "meshes) | explicit comma axis list, e.g. "
                         "'data,model' with --mesh-shape")
    ap.add_argument("--mesh-shape", default="",
                    help="comma ints matching an explicit --mesh axis "
                         "list, e.g. '2,4'")
    ap.add_argument("--fuse", action="store_true",
                    help="fused Pallas linears; on any mesh this selects "
                         "the mesh-native per-shard kernel path (fused_tp "
                         "rules + shard_map)")
    ap.add_argument("--chaos", default="",
                    help="fault-injection spec, e.g. 'preempt@3,"
                         "straggler@5:0.1,corrupt_latest@7' (see "
                         "repro.distributed.chaos); device_loss/save_crash "
                         "faults are absorbed by in-process restarts")
    ap.add_argument("--max-restarts", type=int, default=4,
                    help="restart budget for injected device_loss/"
                         "save_crash faults (with --chaos)")
    ap.add_argument("--metrics-dir", default="",
                    help="telemetry export dir: metrics.jsonl + "
                         "metrics.prom + spans.jsonl, appended at every "
                         "checkpoint and on exit (repro.obs) -- appends "
                         "survive chaos restarts, so one run's telemetry "
                         "stitches across attempts")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus-style GET /metrics on this "
                         "port for the run's duration (0 = ephemeral)")
    ap.add_argument("--profile-dir", default="",
                    help="bridge obs spans into a jax.profiler trace "
                         "written under this directory")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    preset = "baseline"
    if args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        pcfg = production_parallel_config(
            multi_pod=(args.mesh == "multi"),
            microbatches=args.microbatches,
            gradient_compression=args.grad_compression)
        cfg = cfg.with_mesh_padding(pcfg.model_axis_size)
    elif args.mesh != "none":
        from repro.config.base import ParallelConfig
        axes = tuple(a for a in args.mesh.split(",") if a)
        if not args.mesh_shape:
            raise SystemExit("an explicit --mesh axis list needs "
                             "--mesh-shape (e.g. --mesh data,model "
                             "--mesh-shape 2,4)")
        shape = tuple(int(s) for s in args.mesh_shape.split(",") if s)
        if len(shape) != len(axes):
            raise SystemExit(f"--mesh-shape {shape} does not match --mesh "
                             f"axes {axes}")
        mesh = jax.make_mesh(shape, axes)
        pcfg = ParallelConfig(mesh_shape=shape, mesh_axes=axes,
                              microbatches=args.microbatches,
                              gradient_compression=args.grad_compression)
        cfg = cfg.with_mesh_padding(pcfg.model_axis_size)
    else:
        from repro.config.base import ParallelConfig
        pcfg = ParallelConfig(microbatches=args.microbatches,
                              gradient_compression=args.grad_compression)
    if mesh is not None and args.fuse:
        # fused kernels on ANY mesh (explicit or production single/multi)
        # go through the mesh-native path: pallas_call is opaque to GSPMD,
        # so without the fused_tp layout + shard context the partitioner
        # would have to replicate W per call -- the exact regression the
        # fusion_plan/sharded/* gate exists to prevent
        preset = "fused_tp"

    run = RunConfig(
        model=cfg,
        adapter=AdapterConfig(kind=args.adapter, block_size=args.block_size,
                              neumann_terms=args.neumann, rank=args.rank,
                              fuse_linear=args.fuse),
        quant=QuantConfig(kind=args.quant),
        parallel=pcfg,
        train=TrainConfig(global_batch=args.batch, seq_len=args.seq,
                          steps=args.steps, learning_rate=args.lr,
                          warmup_steps=max(args.steps // 20, 1),
                          ckpt_every=max(args.steps // 4, 1), ckpt_keep=2,
                          log_every=10, ckpt_dir=args.ckpt_dir))

    rules = rules_variant(pcfg, preset)
    # mesh-native fused path: validated at config time -- methods without
    # the `shards` capability / non-dividing OFT blocks fail HERE, loudly
    shard_ctx = make_shard_context(mesh, rules, run) \
        if (mesh is not None and preset == "fused_tp") else None
    model = build(run, constrain=make_constrain(rules, mesh),
                  shard=shard_ctx)
    counts = model.param_counts()
    print(f"[train] {cfg.name}: base {counts['base'] / 1e6:.1f}M frozen, "
          f"adapter {counts['adapter'] / 1e6:.3f}M trainable")

    kind = ("audio" if cfg.frontend == "audio_frames" else
            "vlm" if cfg.frontend == "vision_patches" else "lm")
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         kind=kind, frontend_dim=cfg.frontend_dim,
                         num_frontend_tokens=cfg.num_frontend_tokens,
                         num_classes=cfg.vocab_size)
    loader = ShardedLoader(spec, global_batch=args.batch,
                           process_index=jax.process_index(),
                           process_count=jax.process_count(), seed=0)
    guard = PreemptionGuard(install=True)
    place_state = None
    if mesh is not None:
        specs = model.param_specs(rules)

        def place_state(state):
            placed = fit_tree({"base": state.base, "adapter": state.adapter},
                              specs, mesh)
            return state._replace(base=placed["base"],
                                  adapter=placed["adapter"])

    chaos = None
    if args.chaos:
        from repro.distributed.chaos import FaultSchedule
        chaos = FaultSchedule.parse(args.chaos, log=print)

    metrics_dir = args.metrics_dir or None

    def attempt():
        if mesh is not None:
            with mesh:
                return run_training(model, run, loader, guard=guard,
                                    place_state=place_state, chaos=chaos,
                                    metrics_dir=metrics_dir)
        return run_training(model, run, loader, guard=guard, chaos=chaos,
                            metrics_dir=metrics_dir)

    server = None
    if args.metrics_port >= 0:
        server = obs.serve_metrics(args.metrics_port)
        print(f"[train] metrics on "
              f"http://127.0.0.1:{server.port}/metrics")
    if args.profile_dir:
        obs.TRACER.start_profile(args.profile_dir)
    try:
        if chaos is not None:
            from repro.distributed.chaos import run_with_restarts
            out, restarts = run_with_restarts(
                attempt, max_restarts=args.max_restarts, log=print)
            if restarts:
                print(f"[train] recovered via {restarts} restart(s)")
        else:
            out = attempt()
    finally:
        if args.profile_dir:
            obs.TRACER.stop_profile()
        if metrics_dir:
            obs.dump(metrics_dir)
        if server is not None:
            server.close()
    if out["preempted"]:
        print(f"[train] preempted at step {out['last_step']}; checkpoint "
              f"flushed to {args.ckpt_dir} -- rerun to resume")
    print(f"[train] final loss "
          f"{float(np.mean(out['losses'][-5:])):.4f} at step "
          f"{out['last_step']}")


if __name__ == "__main__":
    main()
