"""Production mesh definitions (TPU v5e).

make_production_mesh is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state -- the dry-run sets
XLA_FLAGS before any jax init, tests run with 1 device.
"""
from __future__ import annotations

import jax

from repro.config.base import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_parallel_config(*, multi_pod: bool = False,
                               **overrides) -> ParallelConfig:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return ParallelConfig(mesh_shape=shape, mesh_axes=axes, **overrides)
