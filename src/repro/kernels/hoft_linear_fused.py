"""Pallas TPU kernel: fused HOFT linear -- the Householder-reflection chain
applied to the input tile feeding straight into the x @ W matmul.

Unfused, the HOFT hot path writes the reflected activations (T x K) to HBM
and reads them back for the frozen matmul.  Fused, each program keeps its
(TOKEN_TILE, K) activation tile in VMEM, applies the m reflections as
matvec + rank-1 updates (VPU work; a (TT, 1) dot per reflection on the
MXU), and contracts the result with its (K, N_TILE) weight tile:

  * grid = (token tiles, out tiles).  Unlike the OFT block-diagonal kernel
    there is NO k grid dim: every reflection vector spans the full feature
    width, coupling all of K, so each program owns a full-K activation
    stripe.  The reflection chain is recomputed per n tile -- O(m T K)
    VPU flops, cheap next to the O(T K N) matmul it feeds.
  * reflection rows are zero-padded to the sublane multiple by ops.py;
    the ||v||² guard (core/hoft.NORM_EPS, shared with the jnp oracle)
    makes a zero row an exact no-op.
  * HBM traffic per call: x + v + W + y once each; the reflected
    activations never exist in HBM -- the same "matrix-free" endpoint as
    oftv2_linear_fused, for a method with full-width generators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hoft import NORM_EPS
from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256


def _reflect_tile(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """(TT, K) x tile, (M, K) reflection vectors -> (TT, K) reflected tile.

    Python loop over the (static) reflection count: the chain is inherently
    sequential, so it unrolls into m matvec+axpy steps."""
    for i in range(v.shape[0]):
        vi = v[i:i + 1, :]                                        # (1, K)
        c = 2.0 / jnp.maximum(jnp.sum(vi * vi), NORM_EPS)
        dot = jnp.dot(x, vi.T, preferred_element_type=jnp.float32)  # (TT,1)
        x = x - c * dot * vi
    return x


def _kernel(x_ref, v_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)   # (TT, K)
    v = v_ref[...].astype(jnp.float32)   # (M, K)
    w = w_ref[...].astype(jnp.float32)   # (K, NT)
    o_ref[...] = jnp.dot(_reflect_tile(x, v), w,
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("token_tile", "n_tile",
                                             "interpret"))
def hoft_linear_fused_kernel(x2: jnp.ndarray, v: jnp.ndarray,
                             w: jnp.ndarray,
                             token_tile: int = DEFAULT_TOKEN_TILE,
                             n_tile: int = DEFAULT_N_TILE,
                             interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K) activations, v: (M, K) reflection vectors, w: (K, N) ->
    (T, N) fp32 (callers cast).  T % token_tile == N % n_tile == 0 (ops.py
    pads/picks); K is un-tiled (reflections couple the full width).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = w.shape[1]
    grid = (t // token_tile, n // n_tile)
    record_launch("hoft_linear_fused", grid,
                  {"token": token_tile, "n": n_tile},
                  t=t, k=k_dim, n=n, m=v.shape[0])
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec(v.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((k_dim, n_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, v, w)
