"""Pallas TPU kernel: fused QOFT linear -- NF4 dequant + block-diagonal
rotation + matmul in one pass.

The QOFT (quantized OFTv2) forward unfused is three kernels with two HBM
round-trips: nf4_dequant materializes the full-precision W (the single
largest HBM write in the step), block_oft_apply writes rotated activations,
then the matmul reads both back.  Fused, each program

  1. dequantizes one (K_TILE, N_TILE) weight tile from packed codes +
     absmax in VMEM (LUT gather on the VPU, shift/mask unpack, per-block
     absmax broadcast -- same math as nf4_dequant),
  2. rotates its (TOKEN_TILE, K_TILE) activation tile (batched small-matmul
     on the MXU, as in oftv2_linear_fused),
  3. feeds both straight into the fp32 matmul accumulator.

A full-precision W never exists in HBM -- the quantized path's memory story
(paper section 4: QOFT beats QLoRA on memory) holds on the wire, not just in
parameter storage.

K_TILE must be a multiple of lcm(2, absmax block, OFT block) so code pairs,
absmax blocks and rotation blocks never straddle a k tile (ops.py picks
tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.oftv2_linear_fused import _rotate_tile
from repro.kernels.runtime import record_launch, resolve_interpret
from repro.quant.nf4 import NF4_TABLE

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 128
DEFAULT_K_TILE = 512


def _dequant_tile(codes, absmax, table, block_size: int,
                  k_tile: int) -> jnp.ndarray:
    """(KT//2, NT) packed codes + (KT//bs, NT) absmax -> (KT, NT) f32 in
    VMEM: LUT gather, shift/mask unpack (row-interleaved code pairs),
    per-block absmax broadcast.  Shared by the fwd and bwd QOFT kernels so
    their numerics can't diverge."""
    nt = codes.shape[1]
    hi = (codes >> 4).astype(jnp.int32)
    lo = (codes & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=1).reshape(k_tile, nt)
    vals = jnp.take(table, idx.reshape(-1), axis=0).reshape(k_tile, nt)
    return (vals.reshape(k_tile // block_size, block_size, nt)
            * absmax[:, None, :]).reshape(k_tile, nt)


def _make_kernel(block_size: int, k_tile: int):
    def kernel(x_ref, r_ref, codes_ref, absmax_ref, table_ref, o_ref):
        x = x_ref[...].astype(jnp.float32)       # (TT, KT)
        r = r_ref[...].astype(jnp.float32)       # (KT//b, b, b)
        w = _dequant_tile(codes_ref[...], absmax_ref[...], table_ref[...],
                          block_size, k_tile)    # (KT, NT), VMEM only

        acc = jnp.dot(_rotate_tile(x, r), w,
                      preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += acc
    return kernel


@functools.partial(jax.jit, static_argnames=("block_size", "token_tile",
                                             "n_tile", "k_tile", "interpret"))
def qoft_linear_fused_kernel(x2: jnp.ndarray, r_blocks: jnp.ndarray,
                             codes: jnp.ndarray, absmax: jnp.ndarray,
                             block_size: int,
                             token_tile: int = DEFAULT_TOKEN_TILE,
                             n_tile: int = DEFAULT_N_TILE,
                             k_tile: int = DEFAULT_K_TILE,
                             interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K), r_blocks: (K//b, b, b), codes: (K//2, N) uint8,
    absmax: (K//block_size, N) f32 -> (T, N) fp32 (callers cast).

    T % token_tile == N % n_tile == K % k_tile == 0 and
    k_tile % lcm(2, block_size, b) == 0 (ops.py pads/picks).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = codes.shape[1]
    rb, b, _ = r_blocks.shape
    table = jnp.asarray(NF4_TABLE)
    grid = (t // token_tile, n // n_tile, k_dim // k_tile)
    record_launch("qoft_linear_fused", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b, quant_bs=block_size)
    return pl.pallas_call(
        _make_kernel(block_size, k_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((k_tile // 2, n_tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((k_tile // block_size, n_tile),
                         lambda i, j, k: (k, j)),
            pl.BlockSpec((16,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, r_blocks, codes, absmax, table)
