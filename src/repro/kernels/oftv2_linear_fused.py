"""Pallas TPU kernel: fused OFTv2 linear -- block-diagonal rotation of the
input tile feeding straight into the x @ W matmul accumulator.

Unfused, the OFTv2 hot path is two kernels with an HBM round-trip between
them: block_oft_apply writes the rotated activations (T x K) to HBM, then
the frozen matmul reads them back.  Fused, each program rotates its
(TOKEN_TILE, K_TILE) activation tile in VMEM/registers and immediately
contracts it with the matching (K_TILE, N_TILE) weight tile:

  * grid = (token tiles, out tiles, k tiles); k is innermost so the fp32
    output tile accumulates across k without leaving VMEM.
  * the rotation is a batched small-matmul on the MXU (block index as the
    dot_general batch dim, exactly as in block_oft_apply); its result is
    reshaped in-register into the (TOKEN_TILE, K_TILE) matmul operand.
  * HBM traffic per step: x + W + y once each.  The rotated activations
    never exist in HBM -- the paper's "matrix-free" input-centric transform
    taken to its logical endpoint (DESIGN.md section 4).

K_TILE must be a multiple of the OFT block size b so rotation blocks never
straddle a k tile (ops.py picks tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256
DEFAULT_K_TILE = 512


def _rotate_tile(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """(TT, KT) x tile, (KT//b, b, b) rotations -> (TT, KT) rotated tile."""
    tt, kt = x.shape
    kb, b, _ = r.shape
    xr = jax.lax.dot_general(
        x.reshape(tt, kb, b),
        r,
        # contract x's per-block feature dim with r's input dim; batch over
        # the OFT block index
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )                                    # (kb, tt, b)
    return xr.transpose(1, 0, 2).reshape(tt, kt)


def _kernel(x_ref, r_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)   # (TT, KT)
    r = r_ref[...].astype(jnp.float32)   # (KT//b, b, b)
    w = w_ref[...].astype(jnp.float32)   # (KT, NT)
    acc = jnp.dot(_rotate_tile(x, r), w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("token_tile", "n_tile", "k_tile",
                                             "interpret"))
def oftv2_linear_fused_kernel(x2: jnp.ndarray, r_blocks: jnp.ndarray,
                              w: jnp.ndarray,
                              token_tile: int = DEFAULT_TOKEN_TILE,
                              n_tile: int = DEFAULT_N_TILE,
                              k_tile: int = DEFAULT_K_TILE,
                              interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K) activations, r_blocks: (K//b, b, b), w: (K, N) -> (T, N)
    fp32 (callers cast).  T % token_tile == N % n_tile == K % k_tile == 0 and
    k_tile % b == 0 (ops.py pads/picks).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = w.shape[1]
    rb, b, _ = r_blocks.shape
    grid = (t // token_tile, n // n_tile, k_dim // k_tile)
    record_launch("oftv2_linear_fused", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((k_tile, n_tile), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, r_blocks, w)
