"""Pallas TPU kernel: multi-adapter fused QOFT linear -- NF4 dequant +
per-row rotation routing + matmul in one pass.

The quantized twin of ``oftv2_linear_multi``: the frozen base stays packed
NF4 in HBM and each program dequantizes its (K_TILE, N_TILE) weight tile in
VMEM (same ``_dequant_tile`` as the single-adapter QOFT kernels, so the
numerics cannot diverge), while each token row is rotated with the blocks
of ITS adapter, selected from ``r_stack: (A, K//b, b, b)`` by a per-row
``adapter_id``.  This is the paper's serving economics taken literally: one
NF4 base + hundreds of block-diagonal adapters fit where a single merged
bf16 weight would not, and a mixed-adapter batch needs neither a dense W
nor per-adapter weight copies in HBM -- ever.

Routing is the masked select over the static adapter axis described in
oftv2_linear_multi.py; per-row results are bitwise-identical to a
single-adapter ``qoft_linear_fused`` call with ``r_stack[a]``.

K_TILE must be a multiple of lcm(2, absmax block, OFT block) so code pairs,
absmax blocks and rotation blocks never straddle a k tile (ops.py picks
tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.oftv2_linear_multi import _route_rotate
from repro.kernels.qoft_linear_fused import _dequant_tile
from repro.kernels.runtime import record_launch, resolve_interpret
from repro.quant.nf4 import NF4_TABLE

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 128
DEFAULT_K_TILE = 512


def _make_kernel(block_size: int, k_tile: int):
    def kernel(x_ref, ids_ref, r_ref, codes_ref, absmax_ref, table_ref,
               o_ref):
        x = x_ref[...].astype(jnp.float32)       # (TT, KT)
        ids = ids_ref[...]                       # (TT, 1) int32
        w = _dequant_tile(codes_ref[...], absmax_ref[...], table_ref[...],
                          block_size, k_tile)    # (KT, NT), VMEM only
        acc = jnp.dot(_route_rotate(x, ids, r_ref), w,
                      preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += acc
    return kernel


@functools.partial(jax.jit, static_argnames=("block_size", "token_tile",
                                             "n_tile", "k_tile", "interpret"))
def qoft_linear_multi_kernel(x2: jnp.ndarray, ids2: jnp.ndarray,
                             r_stack: jnp.ndarray, codes: jnp.ndarray,
                             absmax: jnp.ndarray, block_size: int,
                             token_tile: int = DEFAULT_TOKEN_TILE,
                             n_tile: int = DEFAULT_N_TILE,
                             k_tile: int = DEFAULT_K_TILE,
                             interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K), ids2: (T, 1) int32 in [0, A), r_stack: (A, K//b, b, b),
    codes: (K//2, N) uint8, absmax: (K//block_size, N) f32 -> (T, N) fp32
    (callers cast).  T % token_tile == N % n_tile == K % k_tile == 0 and
    k_tile % lcm(2, block_size, b) == 0 (ops.py pads/picks).
    interpret=None auto-detects the backend."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = codes.shape[1]
    a, rb, b, _ = r_stack.shape
    table = jnp.asarray(NF4_TABLE)
    grid = (t // token_tile, n // n_tile, k_dim // k_tile)
    record_launch("qoft_linear_multi", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b, quant_bs=block_size, adapters=a)
    return pl.pallas_call(
        _make_kernel(block_size, k_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((token_tile, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((a, k_tile // b, b, b), lambda i, j, k: (0, k, 0, 0)),
            pl.BlockSpec((k_tile // 2, n_tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((k_tile // block_size, n_tile),
                         lambda i, j, k: (k, j)),
            pl.BlockSpec((16,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, ids2, r_stack, codes, absmax, table)
