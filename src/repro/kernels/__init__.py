"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; compiled path on real TPUs):

  block_oft_apply -- OFTv2's input-centric block-diagonal transform
  cayley_neumann  -- packed-skew -> rotation builder (the paper's CUDA
                     kernel, TPU-adapted)
  nf4_dequant     -- QOFT/QLoRA frozen-weight LUT dequantization
"""
from repro.kernels.ops import block_oft_apply, cayley_neumann, nf4_dequant

__all__ = ["block_oft_apply", "cayley_neumann", "nf4_dequant"]
