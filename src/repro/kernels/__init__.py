"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; compiled path on real TPUs):

  block_oft_apply    -- OFTv2's input-centric block-diagonal transform
                        (also home of the shared multi-stage
                        rotate-in-VMEM primitives the BOFT kernels use)
  cayley_neumann     -- packed-skew -> rotation builder (the paper's CUDA
                        kernel, TPU-adapted)
  nf4_dequant        -- QOFT/QLoRA frozen-weight LUT dequantization
  oftv2_linear_fused -- rotation + matmul in one kernel (no HBM round-trip
                        for the rotated activations)
  qoft_linear_fused  -- NF4 dequant + rotation + matmul in one kernel (no
                        full-precision W ever materialized in HBM)
  oftv2_linear_multi -- multi-adapter serving variant: per-row adapter_id
                        routes each token to its adapter's rotation blocks
  qoft_linear_multi  -- the same with in-kernel NF4 dequant of the shared
                        frozen base
  hoft_linear_fused  -- Householder-chain reflection + matmul in one kernel
                        (the HOFT method's fused forward)
  boft_linear_fused  -- log-depth butterfly stages + matmul in one kernel
                        (no intermediate stage ever exists in HBM);
                        boft_rotate is the rotate-only variant for the
                        sharded gather-rotate-slice path
  goft_linear_fused  -- brick-wall Givens passes + matmul in one kernel
                        (the sparse limit of the rotate-in-VMEM family)
"""
from repro.kernels.ops import (block_oft_apply, boft_linear_fused,
                               boft_rotate, cayley_neumann,
                               goft_linear_fused, hoft_linear_fused,
                               nf4_dequant, oftv2_linear_fused,
                               oftv2_linear_multi, qoft_linear_fused,
                               qoft_linear_multi)

__all__ = ["block_oft_apply", "boft_linear_fused", "boft_rotate",
           "cayley_neumann", "goft_linear_fused", "hoft_linear_fused",
           "nf4_dequant", "oftv2_linear_fused", "oftv2_linear_multi",
           "qoft_linear_fused", "qoft_linear_multi"]
