"""Pallas TPU kernel: packed skew params -> block rotations via the
Cayley-Neumann parameterization.

TPU adaptation of the paper's custom CUDA skew-unpack kernel (§3.3): rather
than a warp-level gather into HBM, each grid program unpacks a tile of
packed-Q vectors into (b x b) skew tiles *in VMEM* (one vectorized gather +
sign multiply), then runs the whole truncated Neumann recurrence

    P <- P @ Q ;  S <- S + P      (k-1 times, MXU batched small-matmul)
    R = (I + Q) @ (I + Q + ... + Q^k)

without writing any intermediate to HBM. HBM traffic is exactly
pack_dim(b) reads + b^2 writes per block -- the theoretical minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.skew import _unpack_gather_index, _unpack_sign, pack_dim
from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_BLOCK_TILE = 8


def _bmm(a, q):
    """(RT, b, b) @ (RT, b, b) batched over the leading dim (MXU)."""
    return jax.lax.dot_general(
        a, q, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _make_kernel(neumann_terms: int, b: int):
    def kernel(qp_ref, idx_ref, sign_ref, o_ref):
        qp = qp_ref[...].astype(jnp.float32)        # (RT, p)
        idx = idx_ref[...]                          # (b, b) int32
        sign = sign_ref[...].astype(jnp.float32)    # (b, b)
        rt = qp.shape[0]
        # unpack: gather packed values into the square tile, apply signs
        q = jnp.take(qp, idx.reshape(-1), axis=1).reshape(rt, b, b) * sign
        eye = jnp.broadcast_to(jnp.eye(b, dtype=jnp.float32), (rt, b, b))
        if neumann_terms <= 0:
            raise ValueError("kernel path requires neumann_terms >= 1")
        acc = eye + q
        power = q
        for _ in range(neumann_terms - 1):
            power = _bmm(power, q)
            acc = acc + power
        r = _bmm(eye + q, acc)
        o_ref[...] = r.astype(o_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("block_size", "neumann_terms",
                                             "block_tile", "interpret"))
def cayley_neumann_kernel(q_packed: jnp.ndarray, block_size: int,
                          neumann_terms: int,
                          block_tile: int = DEFAULT_BLOCK_TILE,
                          interpret: bool = None) -> jnp.ndarray:
    """q_packed: (r, pack_dim(b)) -> (r, b, b). r % block_tile == 0 (ops pads).

    interpret=None auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    rb, p = q_packed.shape
    b = block_size
    assert p == pack_dim(b)
    idx = jnp.asarray(_unpack_gather_index(b))
    sign = jnp.asarray(_unpack_sign(b))
    grid = (rb // block_tile,)
    record_launch("cayley_neumann", grid, {"block": block_tile},
                  rb=rb, b=b, terms=neumann_terms)
    return pl.pallas_call(
        _make_kernel(neumann_terms, b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tile, p), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_tile, b, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rb, b, b), q_packed.dtype),
        interpret=interpret,
    )(q_packed, idx, sign)
