"""Pallas TPU kernel: NF4 dequantization (packed 4-bit codes + per-block
absmax -> bf16/f32 weight tile).

Feeds the frozen matmul in QOFT/QLoRA. TPU adaptation of bitsandbytes'
CUDA LUT dequant: the 16-entry codebook lookup is a VMEM gather on the VPU;
unpacking (two codes per byte) is shift/mask; per-block absmax scaling is a
broadcast multiply. Tiles are chosen so a (IN_TILE x OUT_TILE) bf16 output
tile plus its codes (half) and scales fit comfortably in VMEM, and OUT_TILE
is lane-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import record_launch, resolve_interpret
from repro.quant.nf4 import NF4_TABLE

DEFAULT_IN_TILE = 256    # rows of the dequantized weight per program
DEFAULT_OUT_TILE = 128   # lane-aligned columns


def _make_kernel(block_size: int, in_tile: int):
    def kernel(codes_ref, absmax_ref, table_ref, o_ref):
        codes = codes_ref[...]                       # (IN/2, OUT) uint8
        absmax = absmax_ref[...]                     # (IN/bs, OUT) f32
        table = table_ref[...]                       # (16,) f32
        out = o_ref.shape                            # (IN, OUT)
        hi = (codes >> 4).astype(jnp.int32)
        lo = (codes & 0xF).astype(jnp.int32)
        idx = jnp.stack([hi, lo], axis=1).reshape(out)       # interleave rows
        vals = jnp.take(table, idx.reshape(-1), axis=0).reshape(out)
        scaled = (vals.reshape(in_tile // block_size, block_size, out[1])
                  * absmax[:, None, :])
        o_ref[...] = scaled.reshape(out).astype(o_ref.dtype)
    return kernel


@functools.partial(jax.jit, static_argnames=("block_size", "out_dtype",
                                             "in_tile", "out_tile",
                                             "interpret"))
def nf4_dequant_kernel(codes: jnp.ndarray, absmax: jnp.ndarray,
                       block_size: int, out_dtype=jnp.float32,
                       in_tile: int = DEFAULT_IN_TILE,
                       out_tile: int = DEFAULT_OUT_TILE,
                       interpret: bool = None) -> jnp.ndarray:
    """codes: (d_in//2, d_out) uint8, absmax: (d_in//bs, d_out) f32
    -> (d_in, d_out) out_dtype.  d_in % in_tile == 0, d_out % out_tile == 0,
    in_tile % (2*block_size) == 0 (ops.py pads/validates).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    d_in = codes.shape[0] * 2
    d_out = codes.shape[1]
    table = jnp.asarray(NF4_TABLE)
    grid = (d_in // in_tile, d_out // out_tile)
    record_launch("nf4_dequant", grid,
                  {"in": in_tile, "out": out_tile},
                  k=d_in, n=d_out, quant_bs=block_size)
    return pl.pallas_call(
        _make_kernel(block_size, in_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((in_tile // 2, out_tile), lambda i, j: (i, j)),
            pl.BlockSpec((in_tile // block_size, out_tile),
                         lambda i, j: (i, j)),
            pl.BlockSpec((16,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((in_tile, out_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), out_dtype),
        interpret=interpret,
    )(codes, absmax, table)
