"""Pallas TPU kernel: fused GOFT linear -- brick-wall Givens passes on
the input tile feeding straight into the x @ W matmul.

GOFT is the sparse limit of the rotate-in-VMEM family: each pass is d/2
independent 2x2 plane rotations, pure VPU work (two multiplies and an
add per lane), no MXU until the final matmul.  Unfused, every pass is a
(T x K) HBM round trip; fused, each program keeps its (TOKEN_TILE, K)
activation tile in VMEM and runs all p passes in registers:

  * the pair structure never reshapes the lane dim (TPU lane layouts
    punish (K/2, 2) views): the wrapper precomputes per-LANE coefficient
    rows cos_k and SIGNED sin_k, (p, K) each (``core.goft.
    expand_pass_coeffs``), so every lane uniformly computes
    ``new = cos_k*x + sin_k*partner``.
  * the pair partner is a +-1 lane roll selected by a parity mask from a
    2-D ``broadcasted_iota`` (TPU requires >= 2-D iota); rolls are
    concatenates of two static slices -- in-tile data movement only.
  * odd (offset) passes are conjugated by a wraparound lane roll:
    shift left, apply an even-aligned pass, shift back -- exactly the
    jnp oracle's formulation, so the two cannot disagree on brick
    layout.
  * grid = (token tiles, out tiles), full-K stripe like the HOFT/BOFT
    kernels; passes are recomputed per n tile -- O(p T K) VPU flops,
    cheap next to the O(T K N) matmul.  HBM traffic per call: x +
    coefficients + W + y once each; no intermediate pass exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256


def _roll_lanes(x: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Wraparound roll of the lane (last) dim by +-1, as two static
    slices + concatenate (jnp.roll's gather lowering is TPU-hostile)."""
    if shift == -1:
        return jnp.concatenate([x[:, 1:], x[:, :1]], axis=1)
    return jnp.concatenate([x[:, -1:], x[:, :-1]], axis=1)


def givens_passes_tile(x: jnp.ndarray, cos_k: jnp.ndarray,
                       sin_k: jnp.ndarray) -> jnp.ndarray:
    """(TT, K) tile through p brick-wall passes; cos_k/sin_k: (p, K).

    Python loop over the (static) pass count: the chain is inherently
    sequential, so it unrolls into p rotate steps, all VMEM-resident."""
    tt, k_dim = x.shape
    even = (jax.lax.broadcasted_iota(jnp.int32, (tt, k_dim), 1) % 2) == 0
    for k in range(cos_k.shape[0]):
        xv = _roll_lanes(x, -1) if k % 2 == 1 else x
        partner = jnp.where(even, _roll_lanes(xv, -1), _roll_lanes(xv, 1))
        xv = cos_k[k:k + 1, :] * xv + sin_k[k:k + 1, :] * partner
        x = _roll_lanes(xv, 1) if k % 2 == 1 else xv
    return x


def _kernel(x_ref, c_ref, s_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)   # (TT, K)
    c = c_ref[...].astype(jnp.float32)   # (P, K)
    s = s_ref[...].astype(jnp.float32)   # (P, K)
    w = w_ref[...].astype(jnp.float32)   # (K, NT)
    o_ref[...] = jnp.dot(givens_passes_tile(x, c, s), w,
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("token_tile", "n_tile",
                                             "interpret"))
def goft_linear_fused_kernel(x2: jnp.ndarray, cos_k: jnp.ndarray,
                             sin_k: jnp.ndarray, w: jnp.ndarray,
                             token_tile: int = DEFAULT_TOKEN_TILE,
                             n_tile: int = DEFAULT_N_TILE,
                             interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K) activations, cos_k/sin_k: (P, K) per-lane expanded
    coefficients, w: (K, N) -> (T, N) fp32 (callers cast).
    T % token_tile == N % n_tile == 0 (ops.py pads/picks); K is un-tiled
    (odd passes wrap around the full width).  interpret=None
    auto-detects: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = w.shape[1]
    grid = (t // token_tile, n // n_tile)
    record_launch("goft_linear_fused", grid,
                  {"token": token_tile, "n": n_tile},
                  t=t, k=k_dim, n=n, p=cos_k.shape[0])
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec(cos_k.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(sin_k.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((k_dim, n_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, cos_k, sin_k, w)
