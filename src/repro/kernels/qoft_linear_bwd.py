"""Pallas TPU kernel: fused QOFT linear backward -- in-kernel NF4 dequant
feeding g @ Wᵀ, the transposed rotation, and the dR token-contraction.

PR-1's QOFT backward (`ops._qlf_bwd`) re-materialized the FULL dense NF4
weight in HBM every microbatch before running the three unfused backward
stages -- the single weight-sized HBM write the forward fusion exists to
avoid, paid again on every grad-accum step.  Fused, each program

  1. dequantizes one (K_TILE, N_TILE) weight tile from packed codes +
     absmax in VMEM (LUT gather, shift/mask unpack, per-block absmax
     broadcast -- same math as qoft_linear_fused's forward),
  2. contracts it with the (TOKEN_TILE, N_TILE) cotangent tile into the
     VMEM gW accumulator (across the n grid dim),
  3. on the last n step applies Rᵀ for the dx tile and contracts gW with x
     into the in-place dR accumulator.

Neither a dense W nor the (T, K) gW intermediate ever exists in HBM, in
either direction -- the matrix-free property now holds for the full train
step, not just the forward.

Grid/accumulator layout matches oftv2_linear_bwd (k outermost so the dR
output tile stays VMEM-resident).  K_TILE must be a multiple of
lcm(2, absmax block, OFT block) so code pairs, absmax blocks and rotation
blocks never straddle a k tile (ops.py picks tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.oftv2_linear_bwd import _dr_partial, _gw_partial
from repro.kernels.oftv2_linear_fused import _rotate_tile
from repro.kernels.qoft_linear_fused import _dequant_tile
from repro.kernels.runtime import record_launch, resolve_interpret
from repro.quant.nf4 import NF4_TABLE

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 128
DEFAULT_K_TILE = 512


def _make_kernel(block_size: int, k_tile: int):
    def kernel(g_ref, x_ref, r_ref, codes_ref, absmax_ref, table_ref,
               dx_ref, dr_ref, gw_ref):
        # grid queries at top level (see oftv2_linear_bwd._kernel)
        n_id = pl.program_id(2)
        last_n = n_id == pl.num_programs(2) - 1
        first_token_tile = pl.program_id(1) == 0

        @pl.when(n_id == 0)
        def _init_gw():
            gw_ref[...] = jnp.zeros_like(gw_ref)

        g = g_ref[...].astype(jnp.float32)           # (TT, NT)
        w = _dequant_tile(codes_ref[...], absmax_ref[...], table_ref[...],
                          block_size, k_tile)        # (KT, NT), VMEM only
        gw_ref[...] += _gw_partial(g, w)

        @pl.when(last_n)
        def _finish():
            gw = gw_ref[...]                         # (TT, KT), complete
            r = r_ref[...].astype(jnp.float32)       # (KT//b, b, b)
            rt = jnp.swapaxes(r, -1, -2)
            dx_ref[...] = _rotate_tile(gw, rt)
            x = x_ref[...].astype(jnp.float32)       # (TT, KT)

            @pl.when(first_token_tile)
            def _init_dr():
                dr_ref[...] = jnp.zeros_like(dr_ref)

            dr_ref[...] += _dr_partial(x, gw, r.shape[1])
    return kernel


@functools.partial(jax.jit, static_argnames=("block_size", "token_tile",
                                             "n_tile", "k_tile", "interpret"))
def qoft_linear_bwd_kernel(g2: jnp.ndarray, x2: jnp.ndarray,
                           r_blocks: jnp.ndarray, codes: jnp.ndarray,
                           absmax: jnp.ndarray, block_size: int,
                           token_tile: int = DEFAULT_TOKEN_TILE,
                           n_tile: int = DEFAULT_N_TILE,
                           k_tile: int = DEFAULT_K_TILE,
                           interpret: bool = None):
    """g2: (T, N) cotangent, x2: (T, K), r_blocks: (K//b, b, b),
    codes: (K//2, N) uint8, absmax: (K//block_size, N) f32
    -> (dx (T, K) f32, dr (K//b, b, b) f32); callers cast/slice.

    T % token_tile == N % n_tile == K % k_tile == 0 and
    k_tile % lcm(2, block_size, b) == 0 (ops.py pads/picks).
    interpret=None auto-detects (runtime.py)."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = codes.shape[1]
    rb, b, _ = r_blocks.shape
    table = jnp.asarray(NF4_TABLE)
    grid = (k_dim // k_tile, t // token_tile, n // n_tile)
    record_launch("qoft_linear_bwd", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b, quant_bs=block_size)
    return pl.pallas_call(
        _make_kernel(block_size, k_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, n_tile), lambda k, i, j: (i, j)),
            pl.BlockSpec((token_tile, k_tile), lambda k, i, j: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda k, i, j: (k, 0, 0)),
            pl.BlockSpec((k_tile // 2, n_tile), lambda k, i, j: (k, j)),
            pl.BlockSpec((k_tile // block_size, n_tile),
                         lambda k, i, j: (k, j)),
            pl.BlockSpec((16,), lambda k, i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda k, i, j: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda k, i, j: (k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k_dim), jnp.float32),
            jax.ShapeDtypeStruct((rb, b, b), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((token_tile, k_tile), jnp.float32),
        ],
        interpret=interpret,
    )(g2, x2, r_blocks, codes, absmax, table)
