"""Pallas TPU kernel: fused BOFT linear -- the multi-stage butterfly
rotation pipeline applied to the input tile feeding straight into the
x @ W matmul.

Unfused, every butterfly stage writes its rotated activations (T x K) to
HBM and reads them back for the next stage -- s+1 round trips for an
s-stage butterfly.  Fused, each program keeps its (TOKEN_TILE, r, b)
activation tile in VMEM, runs ALL stages in registers via the shared
``multi_stage_rotate`` primitive (``block_oft_apply.py``: block-batched
MXU matmuls with reshape/transpose butterfly mixes between them -- the
permutation is free inside the tile), flattens, and contracts with its
(K, N_TILE) weight tile:

  * grid = (token tiles, out tiles).  Like the HOFT kernel there is NO
    k grid dim: the butterfly mixes across blocks, so each program owns
    a full-K activation stripe and the full (s, r, b, b) stage-rotation
    stack (small: s*K*b floats).  Stages are recomputed per n tile --
    O(s T K b) MXU flops, cheap next to the O(T K N) matmul they feed.
  * HBM traffic per call: x + rotations + W + y once each; NO
    intermediate stage ever exists in HBM -- asserted by the
    ``no-dense-w-in-hbm`` jaxpr rule on the fused train step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.block_oft_apply import multi_stage_rotate
from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256


def _kernel(strides, x_ref, r_ref, w_ref, o_ref):
    tt, k_dim = x_ref.shape
    s, rb, b, _ = r_ref.shape
    x3 = x_ref[...].astype(jnp.float32).reshape(tt, rb, b)
    xr = multi_stage_rotate(x3, r_ref[...], strides).reshape(tt, k_dim)
    o_ref[...] = jnp.dot(xr, w_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("strides", "token_tile",
                                             "n_tile", "interpret"))
def boft_linear_fused_kernel(x2: jnp.ndarray, rot_stages: jnp.ndarray,
                             w: jnp.ndarray, strides: tuple,
                             token_tile: int = DEFAULT_TOKEN_TILE,
                             n_tile: int = DEFAULT_N_TILE,
                             interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K) activations, rot_stages: (s, r, b, b) with r*b == K,
    strides: static tuple from ``core.boft.stage_strides``, w: (K, N) ->
    (T, N) fp32 (callers cast).  T % token_tile == N % n_tile == 0
    (ops.py pads/picks); K is un-tiled (the butterfly couples the full
    width).  interpret=None auto-detects: compiled on TPU, interpreted
    elsewhere."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = w.shape[1]
    s, rb, b, _ = rot_stages.shape
    grid = (t // token_tile, n // n_tile)
    record_launch("boft_linear_fused", grid,
                  {"token": token_tile, "n": n_tile},
                  t=t, k=k_dim, n=n, s=s, b=b)
    return pl.pallas_call(
        functools.partial(_kernel, strides),
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((s, rb, b, b), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((k_dim, n_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, rot_stages, w)
