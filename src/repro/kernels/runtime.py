"""Kernel execution-mode policy, shared by every Pallas entry point.

A kernel called with ``interpret=None`` (the default everywhere) resolves
the mode here: compiled on real TPU, interpreted elsewhere (CPU containers,
CI). Direct kernel callers therefore get the same auto-detection as the
jit'd wrappers in ``repro.kernels.ops`` -- previously the raw kernels
defaulted to ``interpret=True`` and silently ran interpreted on TPU.

This module must stay import-light (no ops/kernel imports) so the kernel
modules can use it without cycles.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True when Pallas should run in interpret mode (no TPU present)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> backend auto-detection; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)
