"""Kernel execution-mode policy, shared by every Pallas entry point.

A kernel called with ``interpret=None`` (the default everywhere) resolves
the mode here: compiled on real TPU, interpreted elsewhere (CPU containers,
CI). Direct kernel callers therefore get the same auto-detection as the
jit'd wrappers in ``repro.kernels.ops`` -- previously the raw kernels
defaulted to ``interpret=True`` and silently ran interpreted on TPU.

This module must stay import-light (no ops/kernel imports) so the kernel
modules can use it without cycles.  The launch-hook mechanism below keeps
that property: kernels call ``record_launch(...)`` (a no-op while no hook
is registered) and the telemetry layer (``repro.obs.kernels``) registers
its hook from the other side.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax


def default_interpret() -> bool:
    """True when Pallas should run in interpret mode (no TPU present)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> backend auto-detection; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)


# ------------------------------------------------------------- launch hooks --
# Hooks fire from inside each kernel entry's Python body, which runs at
# TRACE time (the entries are jit-wrapped): one firing per distinct-shape
# lowering, zero per steady-state executed call, and zero ops added to any
# jaxpr.  That is exactly the contract the telemetry layer wants -- launch
# *lowerings* are countable without perturbing the compiled hot path.
_launch_hooks: List[Callable[..., None]] = []


def register_launch_hook(hook: Callable[..., None]) -> None:
    """Register ``hook(kernel, grid, tiles, **shape)``; idempotent."""
    if hook not in _launch_hooks:
        _launch_hooks.append(hook)


def unregister_launch_hook(hook: Callable[..., None]) -> None:
    if hook in _launch_hooks:
        _launch_hooks.remove(hook)


def record_launch(kernel: str, grid: Tuple[int, ...], tiles: dict,
                  **shape) -> None:
    """Report one kernel lowering to whatever hooks are installed.  The
    empty-hook fast path is a single truthiness test, so uninstrumented
    processes pay nothing."""
    if not _launch_hooks:
        return
    for hook in list(_launch_hooks):
        hook(kernel, tuple(int(g) for g in grid), dict(tiles), **shape)
