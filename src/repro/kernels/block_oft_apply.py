"""Pallas TPU kernel: block-diagonal orthogonal transform of activations.

The OFTv2 hot loop: y[t, i, :] = x[t, i, :] @ R_i for every token t and OFT
block i.  TPU adaptation of the paper's input-centric matvec (DESIGN.md §4):

  * grid = (token tiles, block tiles); each program owns a
    (TOKEN_TILE, BLOCK_TILE, b) activation tile and the matching
    (BLOCK_TILE, b, b) rotation tile, both VMEM-resident.
  * the batched small-matmul maps to the MXU as a dot_general with the OFT
    block index as a batch dim; token tiles of 256 keep the operand matrix
    (256 x b) MXU-aligned for b in {16, 32, 64}.
  * x is never materialized in transformed form in HBM beyond the output
    tile -- matching the paper's "matrix-free" framing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_BLOCK_TILE = 8


def _kernel(x_ref, r_ref, o_ref):
    x = x_ref[...]          # (TT, RT, b)
    r = r_ref[...]          # (RT, b, b)
    o_ref[...] = jax.lax.dot_general(
        x.astype(jnp.float32),
        r.astype(jnp.float32),
        # contract x's last dim with r's middle dim; batch over the block dim
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile", "block_tile",
                                             "interpret"))
def block_oft_apply_kernel(x3: jnp.ndarray, r_blocks: jnp.ndarray,
                           token_tile: int = DEFAULT_TOKEN_TILE,
                           block_tile: int = DEFAULT_BLOCK_TILE,
                           interpret: bool = None) -> jnp.ndarray:
    """x3: (T, r, b) activations, r_blocks: (r, b, b) -> (T, r, b).

    T must be a multiple of token_tile and r of block_tile (ops.py pads).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere.
    """
    interpret = resolve_interpret(interpret)
    t, rb, b = x3.shape
    grid = (t // token_tile, rb // block_tile)
    record_launch("block_oft_apply", grid,
                  {"token": token_tile, "block": block_tile},
                  t=t, k=rb * b, b=b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, block_tile, b), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_tile, b, b), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, block_tile, b),
                               lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, rb, b), x3.dtype),
        interpret=interpret,
    )(x3, r_blocks)
