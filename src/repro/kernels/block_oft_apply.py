"""Pallas TPU kernels: (multi-stage) block-diagonal orthogonal transforms
of activations -- the shared rotate-in-VMEM primitive.

The OFTv2 hot loop is y[t, i, :] = x[t, i, :] @ R_i for every token t and
OFT block i; BOFT composes log-depth such stages with a butterfly
permutation between them.  Both are built from the same three value-level
helpers, which operate on VMEM-resident tiles and are reused verbatim by
``boft_linear_fused`` (so the fused kernels and this standalone one
cannot drift apart):

  * ``rotate_blocks``   -- the batched small-matmul on the MXU (block
    index as a dot_general batch dim);
  * ``butterfly_mix``   -- the stride-h butterfly involution as a
    reshape/transpose, free inside a tile (no HBM traffic, no gather);
  * ``multi_stage_rotate`` -- the statically-unrolled stage loop
    (permute - rotate - permute per stage).

TPU adaptation of the paper's input-centric matvec (DESIGN.md §4):

  * single-stage ``block_oft_apply_kernel``: grid = (token tiles, block
    tiles); each program owns a (TOKEN_TILE, BLOCK_TILE, b) activation
    tile and the matching (BLOCK_TILE, b, b) rotation tile.
  * multi-stage ``multi_stage_rotate_kernel``: the butterfly mixes
    across blocks, so each program owns the FULL feature dim --
    grid = (token tiles,), tiles (TOKEN_TILE, r, b) + (s, r, b, b);
    every intermediate rotated stage lives and dies in VMEM.
  * token tiles of 256 keep the operand matrix (256 x b) MXU-aligned for
    b in {16, 32, 64}; x is never materialized in transformed form in
    HBM beyond the output tile -- matching the "matrix-free" framing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_BLOCK_TILE = 8


# ---------------------------------------------------------------------------
# Shared value-level primitives (used inside kernel bodies; pure jnp/lax,
# so they also serve the jnp oracles' intuition -- see kernels/ref.py).
# ---------------------------------------------------------------------------
def rotate_blocks(x3, r_blocks):
    """(TT, r, b) @ per-block (r, b, b) -> (TT, r, b), fp32 on the MXU.

    Contract x's feature dim with r's input dim, batch over the block
    index; dot_general emits (r, TT, b), transpose back.
    """
    return jax.lax.dot_general(
        x3.astype(jnp.float32),
        r_blocks.astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)


def butterfly_mix(x3, h: int):
    """Stride-``h`` butterfly involution on a (TT, r, b) tile.

    View the block index as (g, pair, h) and the feature dim as
    (half, b/2); swapping the pair axis with the half axis exchanges
    half of each block's features with its stride-h partner block.
    P = P^T = P^-1 (a swap of two size-2 axes), and as a
    reshape/transpose it costs no HBM traffic inside the tile.
    """
    tt, r, b = x3.shape
    g = r // (2 * h)
    x6 = x3.reshape(tt, g, 2, h, 2, b // 2)
    return x6.transpose(0, 1, 4, 3, 2, 5).reshape(tt, r, b)


def multi_stage_rotate(x3, rot_stages, strides):
    """Statically-unrolled multi-stage rotate on a VMEM tile.

    x3: (TT, r, b); rot_stages: (s, r, b, b); strides: static tuple from
    ``core.boft.stage_strides`` (0 = unpermuted stage, h >= 1 = butterfly
    conjugation).  Every intermediate stays in registers/VMEM.
    """
    for k, h in enumerate(strides):
        if h:
            x3 = butterfly_mix(x3, h)
        x3 = rotate_blocks(x3, rot_stages[k])
        if h:
            x3 = butterfly_mix(x3, h)
    return x3


# ---------------------------------------------------------------------------
# Single-stage kernel (the OFTv2 standalone apply)
# ---------------------------------------------------------------------------
def _kernel(x_ref, r_ref, o_ref):
    o_ref[...] = rotate_blocks(x_ref[...], r_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile", "block_tile",
                                             "interpret"))
def block_oft_apply_kernel(x3: jnp.ndarray, r_blocks: jnp.ndarray,
                           token_tile: int = DEFAULT_TOKEN_TILE,
                           block_tile: int = DEFAULT_BLOCK_TILE,
                           interpret: bool = None) -> jnp.ndarray:
    """x3: (T, r, b) activations, r_blocks: (r, b, b) -> (T, r, b).

    T must be a multiple of token_tile and r of block_tile (ops.py pads).
    interpret=None auto-detects: compiled on TPU, interpreted elsewhere.
    """
    interpret = resolve_interpret(interpret)
    t, rb, b = x3.shape
    grid = (t // token_tile, rb // block_tile)
    record_launch("block_oft_apply", grid,
                  {"token": token_tile, "block": block_tile},
                  t=t, k=rb * b, b=b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, block_tile, b), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_tile, b, b), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, block_tile, b),
                               lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, rb, b), x3.dtype),
        interpret=interpret,
    )(x3, r_blocks)


# ---------------------------------------------------------------------------
# Multi-stage rotate-only kernel (BOFT's sharded path: rotate the gathered
# activations in VMEM, then slice + matmul against the local W shard)
# ---------------------------------------------------------------------------
def _multi_kernel(strides, x_ref, r_ref, o_ref):
    x3 = x_ref[...].astype(jnp.float32)        # (TT, r, b)
    o_ref[...] = multi_stage_rotate(x3, r_ref[...], strides).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("strides", "token_tile",
                                             "interpret"))
def multi_stage_rotate_kernel(x3: jnp.ndarray, rot_stages: jnp.ndarray,
                              strides: tuple,
                              token_tile: int = DEFAULT_TOKEN_TILE,
                              interpret: bool = None) -> jnp.ndarray:
    """x3: (T, r, b), rot_stages: (s, r, b, b) -> (T, r, b) through the
    full butterfly.  The cross-block mix means each program needs the
    whole feature dim: grid = (T // token_tile,), the stage rotations are
    broadcast to every program, and no intermediate stage touches HBM.
    """
    interpret = resolve_interpret(interpret)
    t, rb, b = x3.shape
    s = rot_stages.shape[0]
    grid = (t // token_tile,)
    record_launch("multi_stage_rotate", grid,
                  {"token": token_tile}, t=t, k=rb * b, b=b, s=s)
    return pl.pallas_call(
        functools.partial(_multi_kernel, strides),
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, rb, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((s, rb, b, b), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((token_tile, rb, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, rb, b), x3.dtype),
        interpret=interpret,
    )(x3, rot_stages)
