"""Pallas TPU kernel: multi-adapter fused OFTv2 linear -- per-row rotation
routing inside the rotate+matmul kernel.

The multi-tenant serving regime (repro.serving): N finetuned adapters share
ONE frozen base, and a single decode batch mixes requests for different
adapters.  An adapter is just a stack of tiny rotation blocks, so the whole
pool rides into the kernel as ``r_stack: (A, K//b, b, b)`` and each token
row picks its adapter's blocks by a per-row ``adapter_id``:

  * grid = (token tiles, out tiles, k tiles), k innermost, exactly as in
    oftv2_linear_fused -- the fp32 output tile accumulates in VMEM.
  * routing is a masked select over the (static, small) adapter axis: for
    each adapter a, the tile is rotated with R_a via the SAME ``_rotate_tile``
    the single-adapter kernel uses, and rows with ``adapter_id == a`` keep
    that result.  Per-row numerics are therefore bitwise-identical to a
    single-adapter kernel call with ``r_stack[a]`` -- the property the
    serving engine's "batched multi-adapter decode == N single-adapter
    runs" guarantee rests on (tests/test_serving_multi.py).
  * cost: the rotation (a b-wide batched small-matmul) runs A times per
    tile; the dominant x @ W contraction still runs once.  For serving pool
    sizes (A << N_TILE / b) the overhead is noise next to the matmul, and
    HBM traffic is unchanged: x + W + y once each, plus the tiny r_stack.

``adapter_id`` rides as a (T, 1) int32 array so the mask stays 2-D (TPU
lowering has no 1-D iota/compare).  K_TILE must be a multiple of the OFT
block size b (ops.py picks tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.oftv2_linear_fused import _rotate_tile
from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256
DEFAULT_K_TILE = 512


def _route_rotate(x, ids, r_ref):
    """Rotate each row of the (TT, KT) tile with its adapter's blocks.

    x: (TT, KT) fp32, ids: (TT, 1) int32, r_ref: (A, KT//b, b, b) ref.
    Masked select over the static adapter axis -- each branch reuses the
    single-adapter ``_rotate_tile`` so per-row results match it bitwise."""
    n_adapters = r_ref.shape[0]
    xr = jnp.zeros_like(x)
    for a in range(n_adapters):
        ra = r_ref[a].astype(jnp.float32)        # (KT//b, b, b)
        xr = jnp.where(ids == a, _rotate_tile(x, ra), xr)
    return xr


def _kernel(x_ref, ids_ref, r_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)           # (TT, KT)
    ids = ids_ref[...]                           # (TT, 1) int32
    w = w_ref[...].astype(jnp.float32)           # (KT, NT)
    acc = jnp.dot(_route_rotate(x, ids, r_ref), w,
                  preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("token_tile", "n_tile", "k_tile",
                                             "interpret"))
def oftv2_linear_multi_kernel(x2: jnp.ndarray, ids2: jnp.ndarray,
                              r_stack: jnp.ndarray, w: jnp.ndarray,
                              token_tile: int = DEFAULT_TOKEN_TILE,
                              n_tile: int = DEFAULT_N_TILE,
                              k_tile: int = DEFAULT_K_TILE,
                              interpret: bool = None) -> jnp.ndarray:
    """x2: (T, K) activations, ids2: (T, 1) int32 adapter ids in [0, A),
    r_stack: (A, K//b, b, b), w: (K, N) -> (T, N) fp32 (callers cast).
    T % token_tile == N % n_tile == K % k_tile == 0 and k_tile % b == 0
    (ops.py pads/picks).  interpret=None auto-detects the backend."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = w.shape[1]
    a, rb, b, _ = r_stack.shape
    grid = (t // token_tile, n // n_tile, k_dim // k_tile)
    record_launch("oftv2_linear_multi", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b, adapters=a)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((token_tile, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((a, k_tile // b, b, b), lambda i, j, k: (0, k, 0, 0)),
            pl.BlockSpec((k_tile, n_tile), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, n_tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(x2, ids2, r_stack, w)
