"""Pallas TPU kernel: fused OFTv2 linear backward -- g @ Wᵀ, the transposed
block-diagonal rotation, and the dR token-contraction in one pass.

With forward y = (x @ R_bd) @ W and cotangent g = dL/dy, the backward needs

    gW = g @ Wᵀ                       (cotangent of the rotated activations)
    dx = gW @ R_bdᵀ                   (blockwise: dx_i = gW_i @ R_iᵀ)
    dR_i = Σ_t x[t,i,:]ᵀ gW[t,i,:]    (token-contraction per OFT block)

Unfused (PR-1's `_fused_bwd_core`) that is three kernels with gW -- a full
(T, K) activation-sized tensor -- written to HBM once and read back twice.
Fused, each program accumulates its (TOKEN_TILE, K_TILE) gW tile in a VMEM
scratch across the n grid dim, and on the last n step applies Rᵀ (batched
small-matmul on the MXU, block index as the batch dim) to emit the dx tile
and contracts it with the matching x tile into the dR accumulator.  gW never
exists in HBM.

Grid = (k tiles, token tiles, n tiles), n innermost so the gW scratch
accumulates over the g @ Wᵀ contraction, k OUTERMOST so the dR output tile
(indexed by k alone) stays VMEM-resident across every (token, n) step that
feeds it -- dR is accumulated in-place with zero extra HBM traffic.

K_TILE must be a multiple of the OFT block size b so rotation blocks never
straddle a k tile (ops.py picks tiles accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.oftv2_linear_fused import _rotate_tile
from repro.kernels.runtime import record_launch, resolve_interpret

DEFAULT_TOKEN_TILE = 256
DEFAULT_N_TILE = 256
DEFAULT_K_TILE = 512


def _gw_partial(g: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(TT, NT) cotangent tile @ (KT, NT) weight tileᵀ -> (TT, KT)."""
    return jax.lax.dot_general(
        g, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _dr_partial(x: jnp.ndarray, gw: jnp.ndarray, b: int) -> jnp.ndarray:
    """Token-contraction dR_i = x_iᵀ @ gW_i per OFT block.

    x, gw: (TT, KT) -> (KT//b, b, b), contracting tokens with the block
    index as the dot_general batch dim."""
    tt, kt = x.shape
    return jax.lax.dot_general(
        x.reshape(tt, kt // b, b), gw.reshape(tt, kt // b, b),
        dimension_numbers=(((0,), (0,)), ((1,), (1,))),
        preferred_element_type=jnp.float32)


def _kernel(g_ref, x_ref, r_ref, w_ref, dx_ref, dr_ref, gw_ref):
    # grid queries stay at the top level: inside a pl.when body they would
    # be baked into the cond branch jaxpr, outside the interpreter's reach
    n_id = pl.program_id(2)
    last_n = n_id == pl.num_programs(2) - 1
    first_token_tile = pl.program_id(1) == 0

    @pl.when(n_id == 0)
    def _init_gw():
        gw_ref[...] = jnp.zeros_like(gw_ref)

    g = g_ref[...].astype(jnp.float32)       # (TT, NT)
    w = w_ref[...].astype(jnp.float32)       # (KT, NT)
    gw_ref[...] += _gw_partial(g, w)

    @pl.when(last_n)
    def _finish():
        gw = gw_ref[...]                     # (TT, KT), complete
        r = r_ref[...].astype(jnp.float32)   # (KT//b, b, b)
        rt = jnp.swapaxes(r, -1, -2)
        dx_ref[...] = _rotate_tile(gw, rt)
        x = x_ref[...].astype(jnp.float32)   # (TT, KT)

        @pl.when(first_token_tile)
        def _init_dr():
            dr_ref[...] = jnp.zeros_like(dr_ref)

        dr_ref[...] += _dr_partial(x, gw, r.shape[1])


@functools.partial(jax.jit, static_argnames=("token_tile", "n_tile", "k_tile",
                                             "interpret"))
def oftv2_linear_bwd_kernel(g2: jnp.ndarray, x2: jnp.ndarray,
                            r_blocks: jnp.ndarray, w: jnp.ndarray,
                            token_tile: int = DEFAULT_TOKEN_TILE,
                            n_tile: int = DEFAULT_N_TILE,
                            k_tile: int = DEFAULT_K_TILE,
                            interpret: bool = None):
    """g2: (T, N) cotangent, x2: (T, K), r_blocks: (K//b, b, b), w: (K, N)
    -> (dx (T, K) f32, dr (K//b, b, b) f32); callers cast/slice.

    T % token_tile == N % n_tile == K % k_tile == 0 and k_tile % b == 0
    (ops.py pads/picks).  interpret=None auto-detects (runtime.py)."""
    interpret = resolve_interpret(interpret)
    t, k_dim = x2.shape
    n = g2.shape[1]
    rb, b, _ = r_blocks.shape
    grid = (k_dim // k_tile, t // token_tile, n // n_tile)
    record_launch("oftv2_linear_bwd", grid,
                  {"token": token_tile, "n": n_tile, "k": k_tile},
                  t=t, k=k_dim, n=n, b=b)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, n_tile), lambda k, i, j: (i, j)),
            pl.BlockSpec((token_tile, k_tile), lambda k, i, j: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda k, i, j: (k, 0, 0)),
            pl.BlockSpec((k_tile, n_tile), lambda k, i, j: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((token_tile, k_tile), lambda k, i, j: (i, k)),
            pl.BlockSpec((k_tile // b, b, b), lambda k, i, j: (k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k_dim), jnp.float32),
            jax.ShapeDtypeStruct((rb, b, b), jnp.float32),
        ],
        scratch_shapes=[
            # gW accumulator: the (TT, KT) intermediate that never hits HBM
            pltpu.VMEM((token_tile, k_tile), jnp.float32),
        ],
        interpret=interpret,
    )(g2, x2, r_blocks, w)
