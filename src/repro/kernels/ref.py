"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert_allclose against these; the
model layers use the same math via repro.core / repro.quant, so the oracle
== framework numerics by construction.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cayley as _cayley
from repro.core import skew as _skew
from repro.quant.nf4 import NF4_TABLE


def block_oft_apply_ref(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d), r_blocks: (r, b, b) -> x @ blockdiag(R_1..R_r)."""
    rb, b, _ = r_blocks.shape
    lead = x.shape[:-1]
    xr = x.reshape(lead + (rb, b))
    yr = jnp.einsum("...rb,rbc->...rc", xr, r_blocks.astype(x.dtype))
    return yr.reshape(lead + (rb * b,))


def cayley_neumann_ref(q_packed: jnp.ndarray, block_size: int,
                       neumann_terms: int) -> jnp.ndarray:
    """(r, pack_dim(b)) -> (r, b, b) block rotations."""
    return _cayley.build_rotation(q_packed, block_size, neumann_terms)


def hoft_apply_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) through the Householder chain H_1..H_m, v: (m, d)."""
    from repro.core import hoft as _hoft
    return _hoft.hoft_apply(x, v)


def hoft_linear_ref(x: jnp.ndarray, v: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    """Fused HOFT linear oracle: (x @ H_1..H_m) @ W, fp32 accumulate."""
    xr = hoft_apply_ref(x.astype(jnp.float32), v.astype(jnp.float32))
    return (xr @ w.astype(jnp.float32)).astype(x.dtype)


def boft_apply_ref(x: jnp.ndarray, rot_stages: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) through the s-stage butterfly; rot_stages: (s, r, b, b)."""
    from repro.core import boft as _boft
    return _boft.boft_apply(x, rot_stages)


def boft_linear_ref(x: jnp.ndarray, rot_stages: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    """Fused BOFT linear oracle: (x @ B_1..B_s) @ W, fp32 accumulate."""
    xr = boft_apply_ref(x.astype(jnp.float32),
                        rot_stages.astype(jnp.float32))
    return (xr @ w.astype(jnp.float32)).astype(x.dtype)


def goft_apply_ref(x: jnp.ndarray, thetas: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) through p brick-wall Givens passes; thetas: (p, d/2)."""
    from repro.core import goft as _goft
    return _goft.goft_apply(x, thetas)


def goft_linear_ref(x: jnp.ndarray, thetas: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    """Fused GOFT linear oracle: (x @ G_1..G_p) @ W, fp32 accumulate."""
    xr = goft_apply_ref(x.astype(jnp.float32), thetas)
    return (xr @ w.astype(jnp.float32)).astype(x.dtype)


def oftv2_linear_ref(x: jnp.ndarray, r_blocks: jnp.ndarray,
                     w: jnp.ndarray) -> jnp.ndarray:
    """Fused OFTv2 linear oracle: (x @ blockdiag(R)) @ W, fp32 accumulate."""
    xr = block_oft_apply_ref(x.astype(jnp.float32),
                             r_blocks.astype(jnp.float32))
    return (xr @ w.astype(jnp.float32)).astype(x.dtype)


def qoft_linear_ref(x: jnp.ndarray, r_blocks: jnp.ndarray,
                    codes: jnp.ndarray, absmax: jnp.ndarray,
                    block_size: int) -> jnp.ndarray:
    """Fused QOFT linear oracle: dequant NF4 W, rotate x, matmul."""
    w = nf4_dequant_ref(codes, absmax, block_size, dtype=jnp.float32)
    return oftv2_linear_ref(x, r_blocks, w)


def _row_adapter_ids(adapter_id: jnp.ndarray, lead) -> jnp.ndarray:
    """Per-batch-row adapter ids -> per-token ids over the lead dims.

    adapter_id: scalar, (B,) (broadcast over trailing lead dims, e.g. seq),
    or already the full lead shape."""
    aid = jnp.asarray(adapter_id, jnp.int32)
    if aid.ndim == 0:
        return jnp.broadcast_to(aid, lead)
    if aid.shape != tuple(lead):
        aid = aid.reshape((-1,) + (1,) * (len(lead) - 1))
        aid = jnp.broadcast_to(aid, lead)
    return aid


def oftv2_linear_multi_ref(x: jnp.ndarray, r_stack: jnp.ndarray,
                           adapter_id: jnp.ndarray,
                           w: jnp.ndarray) -> jnp.ndarray:
    """Multi-adapter fused linear oracle: each token row is rotated with
    the blocks of ITS adapter (gathered from r_stack by adapter_id), then
    the shared frozen matmul.  x: (..., K), r_stack: (A, K//b, b, b),
    adapter_id: (B,) (or lead-shaped / scalar), w: (K, N)."""
    a, rb, b, _ = r_stack.shape
    lead = x.shape[:-1]
    ids = _row_adapter_ids(adapter_id, lead)
    r_rows = jnp.take(r_stack.astype(jnp.float32), ids, axis=0)
    x3 = x.astype(jnp.float32).reshape(lead + (rb, b))
    xr = jnp.einsum("...rb,...rbc->...rc", x3, r_rows)
    xr = xr.reshape(lead + (rb * b,))
    return (xr @ w.astype(jnp.float32)).astype(x.dtype)


def qoft_linear_multi_ref(x: jnp.ndarray, r_stack: jnp.ndarray,
                          adapter_id: jnp.ndarray, codes: jnp.ndarray,
                          absmax: jnp.ndarray,
                          block_size: int) -> jnp.ndarray:
    """Multi-adapter fused QOFT oracle: dequant NF4 W, per-row rotate,
    matmul."""
    w = nf4_dequant_ref(codes, absmax, block_size, dtype=jnp.float32)
    return oftv2_linear_multi_ref(x, r_stack, adapter_id, w)


def oftv2_linear_bwd_ref(g: jnp.ndarray, x: jnp.ndarray,
                         r_blocks: jnp.ndarray, w: jnp.ndarray):
    """Fused OFTv2 linear backward oracle: (dx, dr) from cotangent g.

    gW = g @ Wᵀ; dx = gW @ R_bdᵀ blockwise; dR the token-contraction of x
    with gW.  Matches the unfused three-stage math the bwd kernel fuses."""
    rb, b, _ = r_blocks.shape
    gw = jnp.einsum("...n,kn->...k", g.astype(jnp.float32),
                    w.astype(jnp.float32))
    dx = block_oft_apply_ref(gw, jnp.swapaxes(
        r_blocks.astype(jnp.float32), -1, -2)).astype(x.dtype)
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    x3 = x.reshape(t, rb, b).astype(jnp.float32)
    g3 = gw.reshape(t, rb, b)
    dr = jnp.einsum("trb,trc->rbc", x3, g3).astype(r_blocks.dtype)
    return dx, dr


def qoft_linear_bwd_ref(g: jnp.ndarray, x: jnp.ndarray,
                        r_blocks: jnp.ndarray, codes: jnp.ndarray,
                        absmax: jnp.ndarray, block_size: int):
    """Fused QOFT linear backward oracle: dequant NF4 W, then the OFTv2
    backward (codes/absmax are frozen -- no cotangent)."""
    w = nf4_dequant_ref(codes, absmax, block_size, dtype=jnp.float32)
    return oftv2_linear_bwd_ref(g, x, r_blocks, w)


def nf4_dequant_ref(codes: jnp.ndarray, absmax: jnp.ndarray,
                    block_size: int, dtype=jnp.float32) -> jnp.ndarray:
    """codes: (d_in//2, d_out) uint8 packed NF4, absmax: (d_in//bs, d_out)."""
    d_in = codes.shape[0] * 2
    d_out = codes.shape[1]
    hi = (codes >> 4).astype(jnp.int32)
    lo = (codes & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=1).reshape(d_in, d_out)
    vals = jnp.take(jnp.asarray(NF4_TABLE), idx, axis=0)
    w = vals.reshape(d_in // block_size, block_size, d_out) * absmax[:, None, :]
    return w.reshape(d_in, d_out).astype(dtype)
