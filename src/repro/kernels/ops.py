"""Public jit'd wrappers for the Pallas kernels: shape plumbing (leading-dim
flattening, tile padding), interpret-mode auto-detection (CPU container =>
interpret=True; real TPU => compiled), and custom VJPs so the kernels are
drop-in replacements for the jnp paths in repro.core / repro.quant.

Backward rules:
  * block_oft_apply: dx is another block-diagonal apply with R transposed
    (the same kernel, R^T); dR is a token-contraction einsum.
  * cayley_neumann: forward via kernel; backward reuses the forward's
    unpacked skew tiles (saved as residuals) -- the VJP differentiates the
    Neumann recurrence on Q directly and packs the cotangent with one
    triu extraction, never re-running the unpack gather or its transpose.
  * nf4_dequant: non-differentiable by design (frozen quantized weights).
  * oftv2_linear_fused: ONE fused bwd kernel (oftv2_linear_bwd) computes
    gW = g @ W^T, dx = gW rotated by R^T, and the dR token-contraction --
    gW never exists in HBM.  dW is only computed when the caller marks the
    base weight trainable (train_w); the frozen-base default skips the
    rotated-activation recompute and the dW matmul structurally.
  * qoft_linear_fused: same fused bwd with in-kernel NF4 dequant of each
    weight tile (qoft_linear_bwd) -- a dense W never exists in HBM in
    either direction; codes/absmax are frozen (zero cotangent).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cayley as _cayley
from repro.core import skew as _skew
from repro.kernels import ref as kref
from repro.kernels import runtime as _runtime
from repro.kernels.block_oft_apply import (block_oft_apply_kernel,
                                           multi_stage_rotate_kernel)
from repro.kernels.boft_linear_fused import boft_linear_fused_kernel
from repro.kernels.cayley_neumann import cayley_neumann_kernel
from repro.kernels.goft_linear_fused import goft_linear_fused_kernel
from repro.kernels.hoft_linear_fused import hoft_linear_fused_kernel
from repro.kernels.nf4_dequant import nf4_dequant_kernel
from repro.kernels.oftv2_linear_bwd import oftv2_linear_bwd_kernel
from repro.kernels.oftv2_linear_fused import oftv2_linear_fused_kernel
from repro.kernels.oftv2_linear_multi import oftv2_linear_multi_kernel
from repro.kernels.qoft_linear_bwd import qoft_linear_bwd_kernel
from repro.kernels.qoft_linear_fused import qoft_linear_fused_kernel
from repro.kernels.qoft_linear_multi import qoft_linear_multi_kernel


def _interpret() -> bool:
    """Single source of truth for the kernels' execution mode; the kernel
    entry points resolve their interpret=None defaults through the same
    policy (repro.kernels.runtime)."""
    return _runtime.default_interpret()


def _pick_tile(n: int, candidates) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return n


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# ------------------------------------------------------ block_oft_apply ----
def _block_apply_raw(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    rb, b, _ = r_blocks.shape
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    x3 = x.reshape(t, rb, b)
    token_tile = _pick_tile(t, [256, 128, 64, 32, 16, 8, 4, 2, 1])
    block_tile = _pick_tile(rb, [8, 4, 2, 1])
    y3 = block_oft_apply_kernel(x3, r_blocks, token_tile=token_tile,
                                block_tile=block_tile, interpret=_interpret())
    return y3.reshape(x.shape)


@jax.custom_vjp
def block_oft_apply(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) @ blockdiag(r_blocks): Pallas path of OFTv2's input
    transform."""
    return _block_apply_raw(x, r_blocks)


def _boa_fwd(x, r_blocks):
    return _block_apply_raw(x, r_blocks), (x, r_blocks)


def _boa_bwd(res, g):
    x, r_blocks = res
    rb, b, _ = r_blocks.shape
    dx = _block_apply_raw(g, jnp.swapaxes(r_blocks, -1, -2))
    lead = g.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    x3 = x.reshape(t, rb, b)
    g3 = g.reshape(t, rb, b)
    dr = jnp.einsum("trb,trc->rbc", x3.astype(jnp.float32),
                    g3.astype(jnp.float32)).astype(r_blocks.dtype)
    return dx, dr


block_oft_apply.defvjp(_boa_fwd, _boa_bwd)


# ------------------------------------------------------- cayley_neumann ----
def _cn_raw(q_packed: jnp.ndarray, block_size: int,
            neumann_terms: int) -> jnp.ndarray:
    if neumann_terms <= 0:
        # exact Cayley needs a solve -> no kernel path; use the oracle
        return kref.cayley_neumann_ref(q_packed, block_size, neumann_terms)
    rb = q_packed.shape[0]
    block_tile = _pick_tile(rb, [8, 4, 2, 1])
    return cayley_neumann_kernel(q_packed, block_size, neumann_terms,
                                 block_tile=block_tile,
                                 interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def cayley_neumann(q_packed: jnp.ndarray, block_size: int,
                   neumann_terms: int) -> jnp.ndarray:
    """Packed skew (r, p) -> rotations (r, b, b): Pallas CNP builder."""
    return _cn_raw(q_packed, block_size, neumann_terms)


def _cn_fwd(q_packed, block_size, neumann_terms):
    out = _cn_raw(q_packed, block_size, neumann_terms)
    # residual = the unpacked skew tiles, so the backward never redoes the
    # pack->square gather (or differentiates through it)
    return out, _skew.unpack_skew(q_packed, block_size)


def _cn_bwd(block_size, neumann_terms, q, g):
    if neumann_terms <= 0:
        rot = _cayley.cayley_exact
    else:
        def rot(qq):
            return _cayley.cayley_neumann(qq, neumann_terms)
    _, vjp = jax.vjp(rot, q)
    dq = vjp(g.astype(q.dtype))[0]
    # Q[i,j] = qp[k], Q[j,i] = -qp[k]  =>  dqp = triu(dQ - dQ^T)
    return (_skew.pack_skew(dq - jnp.swapaxes(dq, -1, -2)),)


cayley_neumann.defvjp(_cn_fwd, _cn_bwd)


# --------------------------------------------------- fused OFTv2 linears ----
def _flatten_tokens(x: jnp.ndarray):
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    return x.reshape(t, x.shape[-1]), lead, t


def _fused_tiles(t: int, k_dim: int, n: int, k_align: int):
    """(token_tile, t_padded, n_tile, k_tile) for the fused linear kernels.

    Tokens are zero-padded up to the next sublane multiple (8) -- never a
    full token tile, which could nearly double the work for t just past a
    tile boundary -- and the token tile is then picked among divisors of the
    padded count; n/k tiles must divide exactly, falling back to the full
    dim, with k_tile constrained to multiples of k_align (OFT block size,
    lcm'd with the quant block in the QOFT path) so no structure straddles
    a tile."""
    t_pad = _round_up(max(t, 1), 8)
    token_tile = _pick_tile(t_pad, [256, 128, 64, 32, 16, 8])
    n_tile = _pick_tile(n, [256, 128, 64, 32, 16, 8, 4, 2, 1])
    k_tile = _pick_tile(k_dim, [c for c in (512, 256, 128, 64, 32, 16, 8)
                                if c % k_align == 0])
    return token_tile, t_pad, n_tile, k_tile


def _oftv2_fused_raw(x: jnp.ndarray, r_blocks: jnp.ndarray,
                     w: jnp.ndarray) -> jnp.ndarray:
    rb, b, _ = r_blocks.shape
    x2, lead, t = _flatten_tokens(x)
    k_dim, n = w.shape
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, b)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    y2 = oftv2_linear_fused_kernel(x2, r_blocks, w, token_tile=token_tile,
                                   n_tile=n_tile, k_tile=k_tile,
                                   interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


def _bwd_flatten_pad(g, x, t_pad):
    """Flatten lead dims of (g, x) and zero-pad tokens to t_pad.  Zero rows
    contribute nothing to dR and their dx rows are sliced off."""
    g2, _, t = _flatten_tokens(g)
    x2, lead, _ = _flatten_tokens(x)
    if t_pad != t:
        g2 = jnp.pad(g2, ((0, t_pad - t), (0, 0)))
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    return g2, x2, lead, t


def _oftv2_bwd_raw(g, x, r_blocks, w):
    """Fused backward: (dx, dr) in one kernel -- the (T, K) gW intermediate
    never hits HBM (dW is the caller's concern, see _olf_bwd)."""
    rb, b, _ = r_blocks.shape
    k_dim, n = w.shape
    _, _, t = _flatten_tokens(x)
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, b)
    g2, x2, lead, t = _bwd_flatten_pad(g, x, t_pad)
    dx2, dr = oftv2_linear_bwd_kernel(g2, x2, r_blocks, w,
                                      token_tile=token_tile, n_tile=n_tile,
                                      k_tile=k_tile, interpret=_interpret())
    dx = dx2[:t].astype(x.dtype).reshape(lead + (k_dim,))
    return dx, dr.astype(r_blocks.dtype)


def _qoft_bwd_raw(g, x, r_blocks, codes, absmax, block_size):
    """Fused quantized backward: NF4 tiles dequantized in VMEM only -- a
    dense W never exists in HBM in the backward either."""
    rb, b, _ = r_blocks.shape
    k_dim = codes.shape[0] * 2
    n = codes.shape[1]
    align = int(np.lcm(np.lcm(2, block_size), b))
    _, _, t = _flatten_tokens(x)
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, align)
    g2, x2, lead, t = _bwd_flatten_pad(g, x, t_pad)
    dx2, dr = qoft_linear_bwd_kernel(g2, x2, r_blocks, codes, absmax,
                                     block_size, token_tile=token_tile,
                                     n_tile=n_tile, k_tile=k_tile,
                                     interpret=_interpret())
    dx = dx2[:t].astype(x.dtype).reshape(lead + (k_dim,))
    return dx, dr.astype(r_blocks.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def oftv2_linear_fused(x: jnp.ndarray, r_blocks: jnp.ndarray,
                       w: jnp.ndarray, train_w: bool = True) -> jnp.ndarray:
    """y = (x @ blockdiag(R)) @ W in one Pallas kernel: the rotated
    activations never touch HBM.  x: (..., K), r_blocks: (K//b, b, b),
    w: (K, N) -> (..., N).

    train_w=False (the adapted-linear path: base weights are frozen by the
    parameter-layout contract) skips the dW matmul AND the rotated-
    activation recompute in the backward structurally, rather than relying
    on XLA DCE to remove an einsum whose output is never consumed."""
    return _oftv2_fused_raw(x, r_blocks, w)


def _olf_fwd(x, r_blocks, w, train_w):
    return _oftv2_fused_raw(x, r_blocks, w), (x, r_blocks, w)


def _olf_bwd(train_w, res, g):
    x, r_blocks, w = res
    dx, dr = _oftv2_bwd_raw(g, x, r_blocks, w)
    if train_w:
        xr = _block_apply_raw(x, r_blocks)
        xr2, _, _ = _flatten_tokens(xr)
        g2, _, _ = _flatten_tokens(g)
        dw = jnp.einsum("tk,tn->kn", xr2.astype(jnp.float32),
                        g2.astype(jnp.float32)).astype(w.dtype)
    else:
        dw = jnp.zeros_like(w)   # frozen base: trivially DCE'd broadcast
    return dx, dr, dw


oftv2_linear_fused.defvjp(_olf_fwd, _olf_bwd)


def _qoft_fused_raw(x, r_blocks, codes, absmax, block_size):
    rb, b, _ = r_blocks.shape
    x2, lead, t = _flatten_tokens(x)
    k_dim = codes.shape[0] * 2
    n = codes.shape[1]
    # code pairs (2), absmax blocks and rotation blocks must all tile evenly
    align = int(np.lcm(np.lcm(2, block_size), b))
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, align)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    y2 = qoft_linear_fused_kernel(x2, r_blocks, codes, absmax, block_size,
                                  token_tile=token_tile, n_tile=n_tile,
                                  k_tile=k_tile, interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def qoft_linear_fused(x: jnp.ndarray, r_blocks: jnp.ndarray,
                      codes: jnp.ndarray, absmax: jnp.ndarray,
                      block_size: int) -> jnp.ndarray:
    """y = (x @ blockdiag(R)) @ dequant_nf4(codes, absmax) in one Pallas
    kernel: neither the rotated activations nor a full-precision W ever
    touch HBM.  x: (..., K), r_blocks: (K//b, b, b), codes: (K//2, N) uint8,
    absmax: (K//block_size, N) f32 -> (..., N)."""
    return _qoft_fused_raw(x, r_blocks, codes, absmax, block_size)


def _qlf_fwd(x, r_blocks, codes, absmax, block_size):
    out = _qoft_fused_raw(x, r_blocks, codes, absmax, block_size)
    return out, (x, r_blocks, codes, absmax)


def _qlf_bwd(block_size, res, g):
    x, r_blocks, codes, absmax = res
    # fused bwd kernel dequantizes NF4 tiles in VMEM: no full-weight
    # dequant to HBM, ever; frozen quant state gets zero cotangent.
    dx, dr = _qoft_bwd_raw(g, x, r_blocks, codes, absmax, block_size)
    d_codes = np.zeros(codes.shape, dtype=jax.dtypes.float0)
    return dx, dr, d_codes, jnp.zeros_like(absmax)


qoft_linear_fused.defvjp(_qlf_fwd, _qlf_bwd)


# ----------------------------------------------------- fused HOFT linear ----
def _hoft_fused_raw(x: jnp.ndarray, v: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    x2, lead, t = _flatten_tokens(x)
    k_dim, n = w.shape
    # k_align=1: the kernel takes the full K per program (reflections
    # couple the whole feature width), so the k tile is unused
    token_tile, t_pad, n_tile, _ = _fused_tiles(t, k_dim, n, 1)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    m_pad = _round_up(v.shape[0], 8)
    if m_pad != v.shape[0]:
        # zero reflection rows are exact no-ops (core/hoft.NORM_EPS guard)
        v = jnp.pad(v, ((0, m_pad - v.shape[0]), (0, 0)))
    y2 = hoft_linear_fused_kernel(x2, v, w, token_tile=token_tile,
                                  n_tile=n_tile, interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


@jax.custom_vjp
def hoft_linear_fused(x: jnp.ndarray, v: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """y = (x @ H_1..H_m) @ W in one Pallas kernel: the reflected
    activations never touch HBM.  x: (..., K), v: (m, K) Householder
    vectors, w: (K, N) -> (..., N).

    The backward is the jnp reference VJP (no fused bwd kernel yet --
    ``repro.methods`` reports supports_fused_vjp=False for hoft), so
    training works everywhere while only the forward hot path is fused."""
    return _hoft_fused_raw(x, v, w)


def _hlf_fwd(x, v, w):
    return _hoft_fused_raw(x, v, w), (x, v, w)


def _hlf_bwd(res, g):
    x, v, w = res
    _, vjp = jax.vjp(kref.hoft_linear_ref, x, v, w)
    return vjp(g)


hoft_linear_fused.defvjp(_hlf_fwd, _hlf_bwd)


# ----------------------------------------------------- fused BOFT linear ----
def _boft_strides(rot_stages: jnp.ndarray) -> tuple:
    from repro.core.boft import stage_strides
    return stage_strides(rot_stages.shape[0])


def _boft_fused_raw(x: jnp.ndarray, rot_stages: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    x2, lead, t = _flatten_tokens(x)
    k_dim, n = w.shape
    # k_align=1: the kernel takes the full K per program (the butterfly
    # couples all blocks), so the k tile is unused
    token_tile, t_pad, n_tile, _ = _fused_tiles(t, k_dim, n, 1)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    y2 = boft_linear_fused_kernel(x2, rot_stages, w,
                                  _boft_strides(rot_stages),
                                  token_tile=token_tile, n_tile=n_tile,
                                  interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


@jax.custom_vjp
def boft_linear_fused(x: jnp.ndarray, rot_stages: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """y = (x @ B_1..B_s) @ W in one Pallas kernel: every butterfly
    stage's rotated activations stay in VMEM, never HBM.  x: (..., K),
    rot_stages: (s, K//b, b, b), w: (K, N) -> (..., N).

    The backward is the jnp reference VJP (no fused bwd kernel --
    ``repro.methods`` reports supports_fused_vjp=False for boft), so
    training works everywhere while only the forward hot path is fused."""
    return _boft_fused_raw(x, rot_stages, w)


def _blf_fwd(x, rot_stages, w):
    return _boft_fused_raw(x, rot_stages, w), (x, rot_stages, w)


def _blf_bwd(res, g):
    x, rot_stages, w = res
    _, vjp = jax.vjp(kref.boft_linear_ref, x, rot_stages, w)
    return vjp(g)


boft_linear_fused.defvjp(_blf_fwd, _blf_bwd)


def boft_rotate(x: jnp.ndarray, rot_stages: jnp.ndarray) -> jnp.ndarray:
    """Rotate-only multi-stage butterfly on (..., K) -- the Pallas path of
    BOFT's sharded forward (rotate the gathered full-width activations in
    VMEM, then each shard slices its K-slab for the local matmul).  No
    custom VJP: the sharded method builds its own backward from the jnp
    oracle so its collective set stays exactly the declared budget."""
    s, rb, b, _ = rot_stages.shape
    x2, lead, t = _flatten_tokens(x)
    t_pad = _round_up(max(t, 1), 8)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    token_tile = _pick_tile(t_pad, [256, 128, 64, 32, 16, 8])
    y3 = multi_stage_rotate_kernel(x2.reshape(t_pad, rb, b), rot_stages,
                                   _boft_strides(rot_stages),
                                   token_tile=token_tile,
                                   interpret=_interpret())
    return y3.reshape(t_pad, rb * b)[:t].reshape(x.shape)


# ----------------------------------------------------- fused GOFT linear ----
def _goft_fused_raw(x: jnp.ndarray, thetas: jnp.ndarray,
                    w: jnp.ndarray) -> jnp.ndarray:
    from repro.core.goft import expand_pass_coeffs
    x2, lead, t = _flatten_tokens(x)
    k_dim, n = w.shape
    # k_align=1: full-K stripe (odd passes wrap around the whole width)
    token_tile, t_pad, n_tile, _ = _fused_tiles(t, k_dim, n, 1)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
    cos_k, sin_k = expand_pass_coeffs(thetas)
    y2 = goft_linear_fused_kernel(x2, cos_k, sin_k, w,
                                  token_tile=token_tile, n_tile=n_tile,
                                  interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


@jax.custom_vjp
def goft_linear_fused(x: jnp.ndarray, thetas: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """y = (x @ G_1..G_p) @ W in one Pallas kernel: every Givens pass
    stays in VMEM, never HBM.  x: (..., K), thetas: (p, K//2) angle
    params, w: (K, N) -> (..., N).

    The backward is the jnp reference VJP (supports_fused_vjp=False),
    differentiating through the trig-free coefficient expansion so
    d(theta) is exact."""
    return _goft_fused_raw(x, thetas, w)


def _glf_fwd(x, thetas, w):
    return _goft_fused_raw(x, thetas, w), (x, thetas, w)


def _glf_bwd(res, g):
    x, thetas, w = res
    _, vjp = jax.vjp(kref.goft_linear_ref, x, thetas, w)
    return vjp(g)


goft_linear_fused.defvjp(_glf_fwd, _glf_bwd)


# ------------------------------------------- multi-adapter fused linears ----
def _flat_row_ids(adapter_id, lead, t: int) -> jnp.ndarray:
    """(B,)/scalar/lead-shaped adapter ids -> (t, 1) int32 per-token column
    (2-D so the kernel's routing mask has a TPU-lowerable shape)."""
    return kref._row_adapter_ids(adapter_id, lead).reshape(t, 1)


def oftv2_linear_multi(x: jnp.ndarray, r_stack: jnp.ndarray, adapter_id,
                       w: jnp.ndarray) -> jnp.ndarray:
    """Multi-adapter fused OFTv2 linear: y[row] = (x[row] @
    blockdiag(r_stack[adapter_id[row]])) @ W in one Pallas kernel.

    x: (B, ..., K), r_stack: (A, K//b, b, b), adapter_id: (B,) int32 (or
    scalar / full-lead-shaped), w: (K, N) -> (B, ..., N).

    A Python-int ``adapter_id`` is the all-rows-same-adapter fast path: it
    lowers to the single-adapter ``oftv2_linear_fused`` (no routing work at
    all).  Serving is inference-only, so there is no custom VJP -- the train
    path keeps the single-adapter fused kernels."""
    if isinstance(adapter_id, int):
        return oftv2_linear_fused(x, r_stack[adapter_id], w, train_w=False)
    a, rb, b, _ = r_stack.shape
    x2, lead, t = _flatten_tokens(x)
    k_dim, n = w.shape
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, b)
    ids2 = _flat_row_ids(adapter_id, lead, t)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
        ids2 = jnp.pad(ids2, ((0, t_pad - t), (0, 0)))
    y2 = oftv2_linear_multi_kernel(x2, ids2, r_stack, w,
                                   token_tile=token_tile, n_tile=n_tile,
                                   k_tile=k_tile, interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


def qoft_linear_multi(x: jnp.ndarray, r_stack: jnp.ndarray, adapter_id,
                      codes: jnp.ndarray, absmax: jnp.ndarray,
                      block_size: int) -> jnp.ndarray:
    """Multi-adapter fused QOFT linear: per-row rotation routing + in-kernel
    NF4 dequant + matmul in one Pallas kernel (neither per-row rotated
    activations nor a dense W ever exist in HBM).

    x: (B, ..., K), r_stack: (A, K//b, b, b), adapter_id: (B,) int32 (or
    scalar / full-lead-shaped), codes: (K//2, N) uint8,
    absmax: (K//block_size, N) f32 -> (B, ..., N).  A Python-int
    ``adapter_id`` lowers to the single-adapter ``qoft_linear_fused``."""
    if isinstance(adapter_id, int):
        return qoft_linear_fused(x, r_stack[adapter_id], codes, absmax,
                                 block_size)
    a, rb, b, _ = r_stack.shape
    x2, lead, t = _flatten_tokens(x)
    k_dim = codes.shape[0] * 2
    n = codes.shape[1]
    align = int(np.lcm(np.lcm(2, block_size), b))
    token_tile, t_pad, n_tile, k_tile = _fused_tiles(t, k_dim, n, align)
    ids2 = _flat_row_ids(adapter_id, lead, t)
    if t_pad != t:
        x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
        ids2 = jnp.pad(ids2, ((0, t_pad - t), (0, 0)))
    y2 = qoft_linear_multi_kernel(x2, ids2, r_stack, codes, absmax,
                                  block_size, token_tile=token_tile,
                                  n_tile=n_tile, k_tile=k_tile,
                                  interpret=_interpret())
    return y2[:t].astype(x.dtype).reshape(lead + (n,))


# ---------------------------------------------------------- nf4_dequant ----
def nf4_dequant(codes: jnp.ndarray, absmax: jnp.ndarray, block_size: int,
                dtype=jnp.float32) -> jnp.ndarray:
    """Packed NF4 codes + absmax -> dense weight (Pallas path)."""
    d_in = codes.shape[0] * 2
    d_out = codes.shape[1]
    in_tile = _pick_tile(d_in, [c for c in (512, 256, 128, 64, 32, 16)
                                if c % block_size == 0 and c % 2 == 0])
    if d_in % in_tile or in_tile % block_size:
        in_tile = d_in
    out_tile = _pick_tile(d_out, [128, 64, 32, 16, 8, 4, 2, 1])
    return nf4_dequant_kernel(codes, absmax, block_size, out_dtype=dtype,
                              in_tile=in_tile, out_tile=out_tile,
                              interpret=_interpret())
