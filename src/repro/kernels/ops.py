"""Public jit'd wrappers for the Pallas kernels: shape plumbing (leading-dim
flattening, tile padding), interpret-mode auto-detection (CPU container =>
interpret=True; real TPU => compiled), and custom VJPs so the kernels are
drop-in replacements for the jnp paths in repro.core / repro.quant.

Backward rules:
  * block_oft_apply: dx is another block-diagonal apply with R transposed
    (the same kernel, R^T); dR is a token-contraction einsum.
  * cayley_neumann: forward via kernel, backward via jax.vjp of the jnp
    oracle (identical math, so gradients are exact).
  * nf4_dequant: non-differentiable by design (frozen quantized weights).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.block_oft_apply import block_oft_apply_kernel
from repro.kernels.cayley_neumann import cayley_neumann_kernel
from repro.kernels.nf4_dequant import nf4_dequant_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_tile(n: int, candidates) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return n


# ------------------------------------------------------ block_oft_apply ----
def _block_apply_raw(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    rb, b, _ = r_blocks.shape
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    x3 = x.reshape(t, rb, b)
    token_tile = _pick_tile(t, [256, 128, 64, 32, 16, 8, 4, 2, 1])
    block_tile = _pick_tile(rb, [8, 4, 2, 1])
    y3 = block_oft_apply_kernel(x3, r_blocks, token_tile=token_tile,
                                block_tile=block_tile, interpret=_interpret())
    return y3.reshape(x.shape)


@jax.custom_vjp
def block_oft_apply(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) @ blockdiag(r_blocks): Pallas path of OFTv2's input
    transform."""
    return _block_apply_raw(x, r_blocks)


def _boa_fwd(x, r_blocks):
    return _block_apply_raw(x, r_blocks), (x, r_blocks)


def _boa_bwd(res, g):
    x, r_blocks = res
    rb, b, _ = r_blocks.shape
    dx = _block_apply_raw(g, jnp.swapaxes(r_blocks, -1, -2))
    lead = g.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    x3 = x.reshape(t, rb, b)
    g3 = g.reshape(t, rb, b)
    dr = jnp.einsum("trb,trc->rbc", x3.astype(jnp.float32),
                    g3.astype(jnp.float32)).astype(r_blocks.dtype)
    return dx, dr


block_oft_apply.defvjp(_boa_fwd, _boa_bwd)


# ------------------------------------------------------- cayley_neumann ----
def _cn_raw(q_packed: jnp.ndarray, block_size: int,
            neumann_terms: int) -> jnp.ndarray:
    if neumann_terms <= 0:
        # exact Cayley needs a solve -> no kernel path; use the oracle
        return kref.cayley_neumann_ref(q_packed, block_size, neumann_terms)
    rb = q_packed.shape[0]
    block_tile = _pick_tile(rb, [8, 4, 2, 1])
    return cayley_neumann_kernel(q_packed, block_size, neumann_terms,
                                 block_tile=block_tile,
                                 interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def cayley_neumann(q_packed: jnp.ndarray, block_size: int,
                   neumann_terms: int) -> jnp.ndarray:
    """Packed skew (r, p) -> rotations (r, b, b): Pallas CNP builder."""
    return _cn_raw(q_packed, block_size, neumann_terms)


def _cn_fwd(q_packed, block_size, neumann_terms):
    return _cn_raw(q_packed, block_size, neumann_terms), q_packed


def _cn_bwd(block_size, neumann_terms, q_packed, g):
    _, vjp = jax.vjp(
        lambda q: kref.cayley_neumann_ref(q, block_size, neumann_terms),
        q_packed)
    return vjp(g)


cayley_neumann.defvjp(_cn_fwd, _cn_bwd)


# ---------------------------------------------------------- nf4_dequant ----
def nf4_dequant(codes: jnp.ndarray, absmax: jnp.ndarray, block_size: int,
                dtype=jnp.float32) -> jnp.ndarray:
    """Packed NF4 codes + absmax -> dense weight (Pallas path)."""
    d_in = codes.shape[0] * 2
    d_out = codes.shape[1]
    in_tile = _pick_tile(d_in, [c for c in (512, 256, 128, 64, 32, 16)
                                if c % block_size == 0 and c % 2 == 0])
    if d_in % in_tile or in_tile % block_size:
        in_tile = d_in
    out_tile = _pick_tile(d_out, [128, 64, 32, 16, 8, 4, 2, 1])
    return nf4_dequant_kernel(codes, absmax, block_size, out_dtype=dtype,
                              in_tile=in_tile, out_tile=out_tile,
                              interpret=_interpret())
