"""Continuous-batching scheduler: slot-based admission/eviction.

The engine's decode state is a fixed-size batch of ``n_slots`` rows.
Requests (each tagged with the adapter_id of its tenant) queue here; a
free slot admits the next pending request, a finished request evicts its
slot immediately, and the next pending request takes it on the following
tick -- so a long request never stalls the batch behind it, and requests
for DIFFERENT adapters interleave freely in one batch (the multi kernels
route each row to its adapter's rotations).

Pure Python, no jax: this is the control plane.  The data plane (caches,
decode step) lives in repro.serving.engine; under the paged engine a slot
is just a decode-batch row (its KV lives in block-granular pool pages,
see repro.serving.kv_cache), and admission is additionally gated by the
engine's block-capacity check (the ``can_admit`` hook).

``Request`` moved to ``repro.serving.api`` in serving v2; importing it
from here still works but warns.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Tuple

from repro.serving.api import Request as _Request


def __getattr__(name):
    if name == "Request":
        import warnings
        warnings.warn(
            "repro.serving.scheduler.Request moved to repro.serving.api "
            "(serving API v2); import it from repro.serving.api or "
            "repro.serving", DeprecationWarning, stacklevel=2)
        return _Request
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclass
class _Slot:
    request: Optional[_Request] = None
    generated: int = 0             # tokens produced so far


class Scheduler:
    """Slot admission/eviction bookkeeping for continuous batching."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self._pending: Deque[_Request] = deque()

    # ------------------------------------------------------------- intake --
    def submit(self, request: _Request) -> None:
        self._pending.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    def submit_front(self, request: _Request) -> None:
        """Queue-jump: a preempted request coming back from its backoff
        re-enters at the FRONT of the pending queue (it already waited;
        FIFO fairness is over arrival, not over re-arrivals)."""
        self._pending.appendleft(request)

    def remove_pending(self, rid: str) -> Optional[_Request]:
        """Drop (and return) the pending request with id ``rid``; None
        when it is not in the pending queue (active or unknown)."""
        for req in self._pending:
            if req.rid == rid:
                self._pending.remove(req)
                return req
        return None

    # ------------------------------------------------------------- queries --
    def has_work(self) -> bool:
        return bool(self._pending) or any(s.request for s in self._slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.request]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.request is None]

    def slot_request(self, slot: int) -> _Request:
        req = self._slots[slot].request
        assert req is not None, f"slot {slot} is free"
        return req

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------ admit / evict --
    def admit(self, can_admit: Optional[Callable[[_Request], bool]] = None
              ) -> List[Tuple[int, _Request]]:
        """Fill free slots from the pending queue (FIFO).  Returns the
        (slot, request) pairs admitted this tick; the engine prefills each
        into the slot.  ``can_admit`` (paged engine) gates each admission
        on resource capacity; admission stops at the first refusal so FIFO
        order is preserved (no small-request starvation of a big one)."""
        admitted = []
        for slot in self.free_slots():
            if not self._pending:
                break
            if can_admit is not None and not can_admit(self._pending[0]):
                break
            req = self._pending.popleft()
            self._slots[slot] = _Slot(request=req)
            admitted.append((slot, req))
        return admitted

    def record_token(self, slot: int, token: int) -> bool:
        """Count one generated token for `slot`; returns True when the
        request just finished (budget exhausted or EOS) -- the caller then
        evicts."""
        s = self._slots[slot]
        assert s.request is not None
        s.generated += 1
        done = s.generated >= s.request.max_new_tokens
        if s.request.eos_id is not None and token == s.request.eos_id:
            done = True
        return done

    def evict(self, slot: int) -> None:
        """Free the slot for the next admission (the paged engine also
        frees the request's KV blocks; the slots engine overwrites the
        slot's cache region wholesale on the next prefill scatter)."""
        self._slots[slot] = _Slot()
