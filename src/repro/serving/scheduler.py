"""Continuous-batching scheduler: slot-based KV-cache admission/eviction.

The engine's decode state is a fixed-size batch of ``n_slots`` cache
regions.  Requests (each tagged with the adapter_id of its tenant) queue
here; a free slot admits the next pending request, a finished request
evicts its slot immediately, and the next pending request takes it on the
following tick -- so a long request never stalls the batch behind it, and
requests for DIFFERENT adapters interleave freely in one batch (the multi
kernels route each row to its adapter's rotations).

Pure Python, no jax: this is the control plane.  The data plane (caches,
decode step) lives in repro.serving.engine.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple


@dataclass
class Request:
    """One generation request against one pooled adapter."""
    rid: str
    prompt: Sequence[int]          # prompt token ids
    adapter_id: int                # row index into the pool's r_stack
    max_new_tokens: int = 16
    eos_id: Optional[int] = None   # stop early on this token (None = never)

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid!r}: max_new_tokens < 1")


@dataclass
class _Slot:
    request: Optional[Request] = None
    generated: int = 0             # tokens produced so far


class Scheduler:
    """Slot admission/eviction bookkeeping for continuous batching."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self._pending: Deque[Request] = deque()

    # ------------------------------------------------------------- intake --
    def submit(self, request: Request) -> None:
        self._pending.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # ------------------------------------------------------------- queries --
    def has_work(self) -> bool:
        return bool(self._pending) or any(s.request for s in self._slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.request]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.request is None]

    def slot_request(self, slot: int) -> Request:
        req = self._slots[slot].request
        assert req is not None, f"slot {slot} is free"
        return req

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------ admit / evict --
    def admit(self) -> List[Tuple[int, Request]]:
        """Fill free slots from the pending queue (FIFO).  Returns the
        (slot, request) pairs admitted this tick; the engine prefills each
        and scatters its caches into the slot."""
        admitted = []
        for slot in self.free_slots():
            if not self._pending:
                break
            req = self._pending.popleft()
            self._slots[slot] = _Slot(request=req)
            admitted.append((slot, req))
        return admitted

    def record_token(self, slot: int, token: int) -> bool:
        """Count one generated token for `slot`; returns True when the
        request just finished (budget exhausted or EOS) -- the caller then
        evicts."""
        s = self._slots[slot]
        assert s.request is not None
        s.generated += 1
        done = s.generated >= s.request.max_new_tokens
        if s.request.eos_id is not None and token == s.request.eos_id:
            done = True
        return done

    def evict(self, slot: int) -> None:
        """Free the slot's cache region for the next admission (the KV cache
        itself is overwritten wholesale by the next prefill scatter)."""
        self._slots[slot] = _Slot()
