"""Multi-tenant OFT serving: one frozen (possibly NF4) base, N adapters,
mixed-adapter batches, paged KV cache with cross-request prefix sharing.

  api       -- the versioned request/response contract (API_VERSION = 2):
               SamplingParams, Request, GenerationResult
  pool      -- AdapterPool: register N adapters, stack their rotations into
               per-layer r_stack arrays (one Cayley--Neumann build total)
  kv_cache  -- PagedKVCache: block-pool KV storage, per-request block
               tables, copy-on-write prefix sharing, LRU prefix cache
  scheduler -- slot-based continuous-batching control plane
  engine    -- ServingEngine: submit()/step()/drain() (run() compat);
               chunked prefill + paged decode with per-row adapter routing
               inside the fused Pallas kernels

See README "Serving" for the data-flow map.
"""
from repro.serving.api import (API_VERSION, FINISH_CANCELLED,
                               FINISH_DEADLINE, FINISH_LENGTH, FINISH_STOP,
                               GenerationResult, Request, SamplingParams)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (BlockAllocator, BlockPoolExhausted,
                                    PagedKVCache)
from repro.serving.pool import AdapterPool, init_adapters
from repro.serving.scheduler import Scheduler

__all__ = ["API_VERSION", "AdapterPool", "BlockAllocator",
           "BlockPoolExhausted", "FINISH_CANCELLED", "FINISH_DEADLINE",
           "FINISH_LENGTH", "FINISH_STOP", "GenerationResult",
           "PagedKVCache", "Request", "SamplingParams", "Scheduler",
           "ServingEngine", "init_adapters"]
