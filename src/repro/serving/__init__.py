"""Multi-tenant OFT serving: one frozen (possibly NF4) base, N adapters,
mixed-adapter batches.

  pool      -- AdapterPool: register N adapters, stack their rotations into
               per-layer r_stack arrays (one Cayley--Neumann build total)
  scheduler -- Request + slot-based continuous-batching control plane
  engine    -- ServingEngine: jitted batched decode with per-row adapter
               routing inside the fused Pallas kernels

See README "Multi-tenant serving" for the data-flow map.
"""
from repro.serving.engine import ServingEngine
from repro.serving.pool import AdapterPool, init_adapters
from repro.serving.scheduler import Request, Scheduler

__all__ = ["AdapterPool", "ServingEngine", "Request", "Scheduler",
           "init_adapters"]
