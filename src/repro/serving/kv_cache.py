"""Paged KV cache for serving v2 (vLLM-style PagedAttention layout).

The decode cache stops being per-slot rectangles ``(B, s_max, KV, hd)``
and becomes one shared pool of fixed-size blocks per layer group::

    pool["pos_p"]["k"]   : (n_layers, num_blocks, block_size, KV, hd)
    pool["pos_p"]["v"]   : same
    pool["pos_p"]["pos"] : (n_layers, num_blocks, block_size)  int32, -1 invalid

Each request owns a *block table* -- a list of physical block ids, one per
``block_size`` span of its sequence.  Attention gathers the request's
blocks by table and masks by the stored absolute positions, so blocks are
exact-length: no padded-tail invalidation, no length bucketing.

Block 0 is the reserved *null block*: padded lanes in a chunk (and table
slots past a request's length) route their writes/gathers there, which
keeps every jit shape static.

Prefix sharing keys full blocks by the exact token chain that produced
them (nested tuples, so no hash collisions): ``key_i = (key_{i-1},
tokens_i)`` with root ``()``.  A new request walks the chain and adopts
matching full blocks zero-copy (refcounted -- they are never written
again, since writes only happen at positions >= the writer's own prompt
end).  A partially-filled prompt-tail block is shared by *copy*: the
copy-on-write happens eagerly at admission, keeping only the matched
prefix of the block valid, so the sharer can diverge freely.

Blocks whose refcount drops to zero but that are still indexed stay
resident as *cached* (evictable) blocks; the allocator reclaims them LRU
when the free list runs dry.  Freshly (re)allocated blocks carry stale
``pos`` lanes from their previous life, so allocation marks them dirty
and ``flush()`` resets those lanes on device before the next forward.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

import jax

from repro import obs
from repro.models import transformer as tfm
from repro.models.model import Model

NULL_BLOCK = 0


class BlockPoolExhausted(RuntimeError):
    """No free or evictable block is left in the pool.  Under normal
    operation the engine's worst-case admission accounting makes this
    unreachable; it fires when chaos ``seize()`` shrinks the pool under
    live requests (or on an engine accounting bug), and the engine's
    degradation policy answers it: preempt the youngest request, free its
    blocks, requeue it with bounded backoff."""


@jax.jit
def _copy_block_fn(pool, src, dst, keep):
    """Copy block ``src`` -> ``dst`` in every layer group, keeping only
    ``pos`` lanes ``< keep`` valid.  src/dst/keep are TRACED scalars: a
    Python-int block id would bake into the jaxpr as a constant and every
    distinct id would trigger its own XLA compile (measured: dominates an
    admission-heavy serving tick)."""
    out = {}
    for gkey, e in pool.items():
        lane = jnp.arange(e["pos"].shape[-1]) < keep
        out[gkey] = {
            "k": e["k"].at[:, dst].set(e["k"][:, src]),
            "v": e["v"].at[:, dst].set(e["v"][:, src]),
            "pos": e["pos"].at[:, dst].set(
                jnp.where(lane, e["pos"][:, src], -1)),
        }
    return out


@jax.jit
def _flush_fn(pool, stale):
    """Invalidate ``pos`` lanes of every block flagged in the fixed-shape
    ``(num_blocks,)`` bool mask (one compile regardless of how many
    blocks were recycled this tick)."""
    return {gkey: {"k": e["k"], "v": e["v"],
                   "pos": jnp.where(stale[None, :, None], -1, e["pos"])}
            for gkey, e in pool.items()}


class BlockAllocator:
    """Refcounted free-list allocator over blocks ``1..num_blocks-1``.

    Pure control plane (no device arrays) so the unit/property tests can
    hammer it.  The cached/evictable tier lives in :class:`PagedKVCache`;
    the allocator only distinguishes *free* from *referenced*."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free = deque(range(1, num_blocks))
        self._ref: Dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._ref)

    def ref(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.popleft()
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        if bid not in self._ref:
            raise ValueError(f"incref on unallocated block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; True when the block just became
        unreferenced (caller decides: cache it or ``release`` it)."""
        if bid not in self._ref:
            raise ValueError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            return True
        return False

    def resurrect(self, bid: int) -> None:
        """Re-reference an unreferenced-but-resident (cached) block."""
        if bid in self._ref or bid in self._free:
            raise ValueError(f"block {bid} is not cached")
        self._ref[bid] = 1

    def release(self, bid: int) -> None:
        """Return an unreferenced block to the free list."""
        if bid in self._ref:
            raise ValueError(f"release of referenced block {bid}")
        if bid in self._free:
            raise ValueError(f"double release of block {bid}")
        self._free.append(bid)


def _chain_keys(tokens: Sequence[int], block_size: int, namespace=0):
    """Chain keys for every *full* block of ``tokens``:
    ``[(key_prefix, block_tokens), ...]`` with root key ``(namespace,)``.

    The namespace is the adapter id: k/v projections are adapter-rotated,
    so identical prompts under different adapters produce different cache
    contents and must never share blocks."""
    out = []
    key: Tuple = (namespace,)
    for i in range(len(tokens) // block_size):
        tok = tuple(int(t) for t in tokens[i * block_size:(i + 1) * block_size])
        key = (key, tok)
        out.append(key)
    return out


class PagedKVCache:
    """Device block pool + per-request block tables + prefix index.

    Control-plane methods (``begin``/``ensure_capacity``/``commit_prefix``
    /``free``) run on the host per scheduler tick; the only device ops are
    ``flush()`` (reset stale ``pos`` lanes of recycled blocks) and the
    eager partial-block copy in ``begin``.  The engine threads ``.pool``
    through its jitted forwards and assigns the updated tree back."""

    def __init__(self, model: Model, num_blocks: int, block_size: int = 16,
                 max_seq_len: int = 256):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        cfg = model.cfg
        g, _ = tfm.group_structure(cfg)
        for p in range(g):
            if tfm.layer_kind(cfg, p) != "attn":
                raise NotImplementedError(
                    "paged KV serving covers attention-only stacks; "
                    f"layer group {p} is {tfm.layer_kind(cfg, p)!r}")
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_seq_len = max_seq_len
        # static block-table width: every request's table is padded to this
        self.blocks_per_seq = -(-max_seq_len // block_size)
        self.alloc = BlockAllocator(num_blocks)
        self.pool = self._make_pool(model)
        self.tables: Dict[str, List[int]] = {}
        self._prompts: Dict[str, Tuple[int, ...]] = {}
        self._namespaces: Dict[str, int] = {}
        # prefix index: full blocks by chain key; partial prompt tails by
        # (chain key of the preceding full blocks) -> {tail tokens: bid}
        self._full: Dict[Tuple, int] = {}
        self._partial: Dict[Tuple, Dict[Tuple[int, ...], int]] = {}
        self._meta: Dict[int, Tuple] = {}   # bid -> index entry (reverse)
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU order
        self._dirty: List[int] = []  # (re)allocated since last flush()
        self._seized: List[int] = []  # chaos-withheld (pressure injection)
        # prefix-sharing counters live in the repro.obs registry (one
        # source of truth), isolated per cache instance by label; the
        # `stats` property keeps the PR-6 dict shape as a read-only view
        lbl = {"cache": f"c{obs.next_index('cache')}"}
        self._stats = {
            "shared_full_blocks": obs.metric(
                "serving/kv/prefix_shared_blocks_total").labels(**lbl),
            "shared_partial_tokens": obs.metric(
                "serving/kv/prefix_partial_tokens_total").labels(**lbl),
            "cow_copies": obs.metric(
                "serving/kv/cow_copies_total").labels(**lbl),
            "evictions": obs.metric(
                "serving/kv/evictions_total").labels(**lbl),
        }

    @property
    def stats(self) -> Dict[str, int]:
        """Prefix-sharing stats, a dict view over the registry counters
        (same keys/values as the PR-6 ``self.stats`` dict)."""
        return {k: int(c.value) for k, c in self._stats.items()}

    # ---------------------------------------------------------------- pool
    def _make_pool(self, model: Model):
        cfg = model.cfg
        g, n = tfm.group_structure(cfg)
        dt = jnp.dtype(cfg.dtype)
        shape = (n, self.num_blocks, self.block_size,
                 cfg.num_kv_heads, cfg.head_dim)
        return {f"pos_{p}": {
            "k": jnp.zeros(shape, dt),
            "v": jnp.zeros(shape, dt),
            "pos": jnp.full((n, self.num_blocks, self.block_size), -1,
                            jnp.int32)}
            for p in range(g)}

    @property
    def capacity_blocks(self) -> int:
        """Blocks available to requests (block 0 and chaos-seized blocks
        excluded)."""
        return self.num_blocks - 1 - len(self._seized)

    @property
    def n_seized(self) -> int:
        return len(self._seized)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    # --------------------------------------------------- pressure injection
    def seize(self, n: int) -> int:
        """Chaos hook: withhold up to ``n`` free/evictable blocks from the
        pool (simulating a co-tenant burst or shrunk memory budget).
        Returns how many were actually seized -- referenced blocks are
        never stolen.  Seized blocks reduce ``capacity_blocks``, so
        admission refuses new work and already-admitted requests can hit
        :class:`BlockPoolExhausted` mid-flight -- exactly the condition
        the engine's preempt/requeue degradation path must absorb."""
        taken: List[int] = []
        for _ in range(n):
            if not self.alloc._free and not self._evict_cached():
                break
            taken.append(self.alloc._free.pop())
        self._seized.extend(taken)
        return len(taken)

    def release_seized(self) -> int:
        """Return every seized block to the free list (pressure over)."""
        n = len(self._seized)
        for bid in self._seized:
            self.alloc.release(bid)
        self._seized.clear()
        return n

    # ----------------------------------------------------------- allocation
    def _evict_cached(self) -> bool:
        """Drop the least-recently-indexed unreferenced block."""
        if not self._cached:
            return False
        bid, _ = self._cached.popitem(last=False)
        self._unindex(bid)
        self.alloc.release(bid)
        self._stats["evictions"].inc()
        return True

    def _take_block(self) -> int:
        bid = self.alloc.alloc()
        if bid is None:
            if not self._evict_cached():
                raise BlockPoolExhausted(
                    f"KV block pool exhausted ({self.n_seized} of "
                    f"{self.num_blocks - 1} blocks seized) -- the engine "
                    f"must preempt+requeue, or this is an admission-"
                    f"accounting bug")
            bid = self.alloc.alloc()
            assert bid is not None
        self._dirty.append(bid)
        return bid

    def _claim(self, bid: int) -> None:
        """Add a reference to an indexed block (live or cached)."""
        if self.alloc.ref(bid) > 0:
            self.alloc.incref(bid)
        else:
            del self._cached[bid]
            self.alloc.resurrect(bid)

    def _unindex(self, bid: int) -> None:
        entry = self._meta.pop(bid, None)
        if entry is None:
            return
        kind, key = entry[0], entry[1]
        if kind == "full":
            del self._full[key]
        else:
            tails = self._partial[key]
            del tails[entry[2]]
            if not tails:
                del self._partial[key]

    # ------------------------------------------------------------ lifecycle
    def begin(self, rid: str, prompt: Sequence[int],
              adapter_id: int = 0) -> Tuple[int, int]:
        """Open a table for ``rid``, adopting every cached prefix block
        prefilled under the SAME adapter (the prefix index namespace).

        Returns ``(start_pos, shared_blocks)``: prefill can skip positions
        ``< start_pos``; ``shared_blocks`` counts blocks reused from the
        prefix index (full adoptions + at most one copied partial)."""
        if rid in self.tables:
            raise ValueError(f"request {rid!r} already has a block table")
        prompt_t = tuple(int(t) for t in prompt)
        bs = self.block_size
        # never adopt past len-1: the LAST prompt token must go through
        # prefill -- its forward produces the logits the first generated
        # token is sampled from (a fully-cached prompt has no logits).
        adoptable = len(prompt_t) - 1
        table: List[int] = []
        matched = 0
        chain: Tuple = (adapter_id,)
        for key in _chain_keys(prompt_t, bs, adapter_id):
            if matched + bs > adoptable:
                break
            bid = self._full.get(key)
            if bid is None:
                break
            self._claim(bid)
            table.append(bid)
            chain = key
            matched += bs
        shared = len(table)
        self._stats["shared_full_blocks"].inc(shared)
        # longest-common-prefix match against cached partial tails under
        # the same chain; the winner is COPIED (eager copy-on-write) with
        # only the matched lanes kept valid, so both sides diverge freely.
        remainder = prompt_t[matched:]
        best_bid, best_m = -1, 0
        for tok, bid in self._partial.get(chain, {}).items():
            m = 0
            for a, b in zip(tok, remainder):
                if a != b:
                    break
                m += 1
            m = min(m, adoptable - matched)
            if m > best_m:
                best_bid, best_m = bid, m
        # a cached FULL block that would cover the prompt end is also a
        # copy source (keep all but the last token): exact-block prompts
        # still share all-but-one token of their final block.
        if len(remainder) >= bs:
            bid = self._full.get((chain, tuple(remainder[:bs])))
            if bid is not None and adoptable - matched > best_m:
                best_bid, best_m = bid, adoptable - matched
        if best_m > 0:
            if best_bid in self._cached:
                self._cached.move_to_end(best_bid)
            try:
                dst = self._take_block()
            except BlockPoolExhausted:
                # roll back the adoptions so the caller can requeue the
                # request without leaking references
                for bid in table:
                    if self.alloc.decref(bid):
                        self._retire(bid)
                raise
            self._copy_block(best_bid, dst, keep=best_m)
            # the copy overwrites every lane, no stale-pos flush needed
            self._dirty.remove(dst)
            table.append(dst)
            matched += best_m
            shared += 1
            self._stats["cow_copies"].inc()
            self._stats["shared_partial_tokens"].inc(best_m)
        self.tables[rid] = table
        self._prompts[rid] = prompt_t
        self._namespaces[rid] = adapter_id
        return matched, shared

    def ensure_capacity(self, rid: str, upto_pos: int) -> None:
        """Grow ``rid``'s table to cover position ``upto_pos`` (0-based)."""
        if upto_pos >= self.max_seq_len:
            raise ValueError(
                f"request {rid!r}: position {upto_pos} exceeds "
                f"max_seq_len={self.max_seq_len}")
        table = self.tables[rid]
        need = upto_pos // self.block_size + 1
        while len(table) < need:
            table.append(self._take_block())
        # defensive copy-on-write: by construction shared blocks are never
        # written (full blocks lie entirely before the sharer's start_pos;
        # partials are copied at begin()), but guard anyway.
        tail = table[need - 1]
        if self.alloc.ref(tail) > 1:
            dst = self._take_block()
            self._copy_block(tail, dst, keep=upto_pos % self.block_size)
            self._dirty.remove(dst)
            table[need - 1] = dst
            if self.alloc.decref(tail):   # pragma: no cover (defensive)
                self._retire(tail)
            self._stats["cow_copies"].inc()

    def commit_prefix(self, rid: str) -> None:
        """Index ``rid``'s prompt blocks for cross-request sharing.

        Called when prefill completes -- possibly while ``rid`` is still
        decoding, which is safe: full prompt blocks are never written
        again, and a partial prompt tail only ever gains lanes *beyond*
        the indexed length."""
        prompt = self._prompts[rid]
        assert len(self.tables[rid]) * self.block_size >= len(prompt), \
            f"commit_prefix({rid!r}) before its prompt blocks exist"
        self.commit_chain(rid, prompt)

    def commit_chain(self, rid: str, tokens: Sequence[int]) -> None:
        """Index the blocks holding ``tokens`` -- any WRITTEN token chain
        of ``rid`` (prompt, or prompt + generated-so-far) -- for adoption
        by a later request.

        This is the cheap-requeue path: the engine preempts ``rid``,
        commits the chain it has written, frees the request, and
        resubmits it with ``prompt = chain``; on readmission ``begin``
        re-adopts these (now cached) blocks instead of re-prefilling.
        Only pass tokens whose KV is actually on device: full blocks are
        indexed as shareable, a partial tail as a copy source."""
        table = self.tables[rid]
        bs = self.block_size
        tokens = tuple(int(t) for t in tokens)
        keys = _chain_keys(tokens, bs, self._namespaces[rid])
        for i, key in enumerate(keys):
            if i >= len(table):
                return
            bid = table[i]
            if key in self._full or bid in self._meta:
                continue   # content already indexed (or block is)
            self._full[key] = bid
            self._meta[bid] = ("full", key)
        tail = tokens[len(keys) * bs:]
        if tail and len(keys) < len(table):
            chain = keys[-1] if keys else (self._namespaces[rid],)
            bid = table[len(keys)]
            tails = self._partial.setdefault(chain, {})
            if tail not in tails and bid not in self._meta:
                tails[tail] = bid
                self._meta[bid] = ("partial", chain, tail)

    def free(self, rid: str) -> None:
        """Drop ``rid``'s references; indexed blocks stay cached (LRU)."""
        for bid in self.tables.pop(rid):
            if self.alloc.decref(bid):
                self._retire(bid)
        del self._prompts[rid]
        del self._namespaces[rid]

    def _retire(self, bid: int) -> None:
        if bid in self._meta:
            self._cached[bid] = None       # evictable, contents retained
            self._cached.move_to_end(bid)
        else:
            self.alloc.release(bid)

    # ------------------------------------------------------------ device ops
    def _copy_block(self, src: int, dst: int, keep: int) -> None:
        self.pool = _copy_block_fn(self.pool, jnp.int32(src),
                                   jnp.int32(dst), jnp.int32(keep))

    def flush(self) -> None:
        """Invalidate ``pos`` lanes of blocks recycled since last flush --
        they carry entries from a previous owner that would otherwise pass
        the position mask.  One fixed-shape device op per tick."""
        if not self._dirty:
            return
        stale = np.zeros((self.num_blocks,), bool)
        stale[sorted(set(self._dirty))] = True
        self.pool = _flush_fn(self.pool, jnp.asarray(stale))
        self._dirty.clear()

    def table_rows(self, rids: Sequence[Optional[str]]) -> np.ndarray:
        """Dense ``(len(rids), blocks_per_seq)`` int32 block-table batch;
        ``None`` rows and slots past a table's length hit the null block."""
        out = np.full((len(rids), self.blocks_per_seq), NULL_BLOCK, np.int32)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            t = self.tables[rid]
            out[i, :len(t)] = t
        return out

    # -------------------------------------------------------------- testing
    def audit(self) -> Dict[str, int]:
        """Check the no-leak/no-double-free invariants; raise on violation.

        free + referenced + cached + seized must partition blocks
        1..NB-1, and the total of allocator refcounts must equal the total
        of block-table entries (every reference is table-held)."""
        tiers = {"free": set(self.alloc._free), "used": set(self.alloc._ref),
                 "cached": set(self._cached), "seized": set(self._seized)}
        names = list(tiers)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                assert not tiers[a] & tiers[b], \
                    f"{a}/{b} overlap: {tiers[a] & tiers[b]}"
        free, used, cached = tiers["free"], tiers["used"], tiers["cached"]
        every = free | used | cached | tiers["seized"]
        expect = set(range(1, self.num_blocks))
        assert every == expect, \
            f"leaked: {expect - every}, phantom: {every - expect}"
        n_refs = sum(self.alloc._ref.values())
        n_held = sum(len(t) for t in self.tables.values())
        assert n_refs == n_held, \
            f"refcount total {n_refs} != table entries {n_held}"
        for bid in self._meta:
            assert bid in used or bid in cached, \
                f"indexed block {bid} neither referenced nor cached"
        for key, bid in self._full.items():
            assert self._meta.get(bid) == ("full", key)
        for chain, tails in self._partial.items():
            for tok, bid in tails.items():
                assert self._meta.get(bid) == ("partial", chain, tok)
        return {"free": len(free), "used": len(used), "cached": len(cached),
                "seized": len(tiers["seized"])}
