"""Adapter pool: N finetuned OFTv2/QOFT adapters registered against ONE
frozen (possibly NF4-quantized) base.

This is the paper's serving economics made concrete: an adapter is a stack
of tiny block rotations (b x b, b ~ 32), so hundreds of tenants fit in the
memory ONE merged weight copy would take.  The pool

  1. validates every registered adapter tree against the model's adapter
     layout (same treedef -- they were all finetuned from the same base),
  2. hands the trees to the method's ``stack_for_serving`` registry hook
     (``repro.methods``; OFTv2 stacks the packed-skew leaves along a new
     adapter axis and builds every Cayley--Neumann rotation of every
     adapter of every layer in ONE ``build_r`` call via the PR-2 hoisted
     path -- methods without the capability raise at pool construction),

yielding per-layer ``r_stack: (A, blocks, b, b)`` arrays that ride the
adapter tree through the layer scan exactly like the train-time hoisted
``r_blocks`` -- the multi-adapter Pallas kernels pick them up via the
per-row ``adapter_id`` the engine threads through the decode batch.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax

from repro import methods
from repro.config.base import AdapterConfig
from repro.models.model import Model


def _check_multi_servable(model: Model) -> None:
    """Config-shape problems raise ValueError; a method that genuinely
    lacks the multi-tenant capability (no ``stack_for_serving`` /
    ``route_multi`` hooks -- e.g. HOFT, LoRA) raises NotImplementedError
    at pool-construction time, loudly, instead of falling through to a
    wrong single-adapter path later."""
    cfg, acfg = model.cfg, model.run.adapter
    method = methods.get(acfg.kind)
    if not acfg.fuse_linear or not method.has_params:
        raise ValueError(
            "multi-tenant serving routes rotations inside the fused Pallas "
            "kernels: AdapterConfig(kind='oftv2', fuse_linear=True) required "
            f"(got kind={acfg.kind!r}, fuse_linear={acfg.fuse_linear})")
    if not method.supports_multi_tenant:
        raise NotImplementedError(
            f"adapter method {acfg.kind!r} does not support multi-tenant "
            f"serving (no stack_for_serving/route_multi capability; "
            f"methods that do: "
            f"{list(methods.supporting('supports_multi_tenant'))})")
    if cfg.is_encoder:
        raise ValueError("encoder-only architectures have no decode step")
    if cfg.num_experts > 0 or any(cfg.is_ssm_layer(i)
                                  for i in range(cfg.num_layers)):
        raise NotImplementedError(
            "multi-adapter routing is wired through the dense "
            "attention+MLP path; MoE/SSM layers are not served yet")


class AdapterPool:
    """Registry of N adapters sharing one frozen base.

    Usage:
        pool = AdapterPool(model)
        pool.register("tenant-a", params_a["adapter"])
        pool.register("tenant-b", params_b["adapter"])
        serving_params = pool.serving_params(base_params)
        # -> decode batches carry "adapter_id" rows indexing the pool
    """

    def __init__(self, model: Model):
        _check_multi_servable(model)
        self.model = model
        self.acfg: AdapterConfig = model.run.adapter
        self._method = methods.get(self.acfg.kind)
        self._names: List[str] = []
        self._trees: List[dict] = []
        self._pooled: Optional[dict] = None

    # ------------------------------------------------------------ registry --
    def register(self, name: str, adapter_tree: dict) -> int:
        """Add one finetuned adapter; returns its adapter_id (row index in
        every r_stack).  Invalidates any previously built stack."""
        if name in self._names:
            raise ValueError(f"adapter {name!r} already registered")
        if not adapter_tree:
            raise ValueError("empty adapter tree (was the model built with "
                             "an adapter config?)")
        if self._trees:
            want = jax.tree_util.tree_structure(self._trees[0])
            got = jax.tree_util.tree_structure(adapter_tree)
            if want != got:
                raise ValueError(
                    f"adapter {name!r} layout does not match the pool "
                    f"(all adapters must come from the same base/config)")
        self._trees.append(adapter_tree)
        self._names.append(name)
        self._pooled = None
        return len(self._names) - 1

    @property
    def n_adapters(self) -> int:
        return len(self._names)

    @property
    def names(self) -> List[str]:
        return list(self._names)

    def adapter_id(self, name: str) -> int:
        return self._names.index(name)

    # --------------------------------------------------------------- build --
    def build(self) -> dict:
        """Stack all registered adapters via the method's
        ``stack_for_serving`` hook (OFT: every rotation block of every
        adapter built in one Cayley--Neumann call, the PR-2 hoisted path).
        Returns (and caches) the pooled adapter tree with per-layer
        ``r_stack`` leaves."""
        if not self._trees:
            raise ValueError("no adapters registered")
        self._pooled = self._method.stack_for_serving(self._trees,
                                                      self.acfg)
        return self._pooled

    @property
    def pooled_adapter(self) -> dict:
        if self._pooled is None:
            self.build()
        return self._pooled

    def serving_params(self, params: dict) -> dict:
        """Full serving param tree: the shared frozen base + the pooled
        adapter stack.  ``params`` is any {"base": ...} tree (the adapter
        entry, if present, is replaced by the pool)."""
        return {"base": params["base"], "adapter": self.pooled_adapter}

    # --------------------------------------------------------------- stats --
    def param_counts(self) -> Dict[str, int]:
        """{"base": shared frozen params, "adapter_each": per-tenant
        trainable params} -- the multi-tenant memory story in two numbers."""
        counts = self.model.param_counts()
        return {"base": counts["base"], "adapter_each": counts["adapter"]}


def init_adapters(model: Model, n: int, key=None, scale: float = 0.05):
    """N distinct randomly-perturbed adapter trees for demos/benchmarks
    (real deployments register finetuned checkpoints).  scale=0 gives
    identity rotations (the OFT zero init)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    template = model.init(key)["adapter"]
    flat, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for i in range(n):
        ki = jax.random.fold_in(key, i)
        perturbed = [q + scale * jax.random.normal(jax.random.fold_in(ki, j),
                                                   q.shape, q.dtype)
                     for j, q in enumerate(flat)]
        out.append(jax.tree_util.tree_unflatten(treedef, perturbed))
    return out
