"""Serving API v2: the one request/response contract every serving
consumer speaks -- the paged scheduler, streaming `submit()/step()/drain()`
callers, the `run()` compatibility wrapper, the load generator, and
`train/serving.generate()` (a convenience wrapper over a single-request
engine call).

    SamplingParams   -- how to decode (budget, temperature, stop token)
    Request          -- rid + prompt + adapter + SamplingParams
    GenerationResult -- tokens, finish_reason, per-request timing

``Request`` lived in ``repro.serving.scheduler`` through PR 3-5; that
import path still works but emits a DeprecationWarning (the scheduler is a
control-plane detail, the API is the contract).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

API_VERSION = 2


@dataclass(frozen=True)
class SamplingParams:
    """How to decode one request.

    ``temperature=None`` defers to the engine-level default (greedy unless
    the engine was built with ``temperature > 0``)."""
    max_new_tokens: int = 16
    temperature: Optional[float] = None
    eos_id: Optional[int] = None   # stop early on this token (None = never)

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens < 1")


class Request:
    """One generation request against one pooled adapter.

    ``max_new_tokens=`` / ``eos_id=`` keyword arguments are the PR-3
    spelling; they still work (folded into ``sampling``) but new code
    should pass ``sampling=SamplingParams(...)``.

    ``deadline_s`` (optional): a per-request latency budget in seconds,
    measured from ``submit()``.  A request still unfinished when its
    deadline passes is cancelled by the engine (wherever it is: pending,
    requeued after a preemption, or mid-decode) and returned with
    ``finish_reason="deadline"`` and whatever tokens it produced."""

    __slots__ = ("rid", "prompt", "adapter_id", "sampling", "deadline_s")

    def __init__(self, rid: str, prompt: Sequence[int], adapter_id: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 max_new_tokens: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 deadline_s: Optional[float] = None):
        if len(prompt) == 0:
            raise ValueError(f"request {rid!r}: empty prompt")
        if sampling is None:
            sampling = SamplingParams(
                max_new_tokens=16 if max_new_tokens is None
                else max_new_tokens,
                eos_id=eos_id)
        elif max_new_tokens is not None or eos_id is not None:
            raise ValueError(
                f"request {rid!r}: pass either sampling= or the legacy "
                f"max_new_tokens=/eos_id= kwargs, not both")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"request {rid!r}: deadline_s must be > 0")
        self.rid = rid
        self.prompt = prompt
        self.adapter_id = adapter_id
        self.sampling = sampling
        self.deadline_s = deadline_s

    # PR-3 call sites read these off the request directly.
    @property
    def max_new_tokens(self) -> int:
        return self.sampling.max_new_tokens

    @property
    def eos_id(self) -> Optional[int]:
        return self.sampling.eos_id

    def __repr__(self):
        return (f"Request(rid={self.rid!r}, len={len(self.prompt)}, "
                f"adapter_id={self.adapter_id}, sampling={self.sampling})")


@dataclass
class GenerationResult:
    """What the engine returns per finished request.

    Timestamps are ``time.perf_counter()`` values stamped by the engine,
    so latencies mix freely with a load generator's own clock:

        ttft    = first_token_at - submitted_at   (queueing + prefill)
        latency = finished_at - submitted_at
    """
    rid: str
    tokens: np.ndarray             # generated ids, prompt excluded
    finish_reason: str             # "length" | "stop" | "deadline" | "cancelled"
    prompt_len: int
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    prefix_blocks_shared: int = 0  # KV blocks reused from the prefix cache
    retries: int = 0               # preempt/requeue cycles survived

    @property
    def n_generated(self) -> int:
        return int(len(self.tokens))

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.submitted_at

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at


FINISH_LENGTH = "length"
FINISH_STOP = "stop"
FINISH_DEADLINE = "deadline"     # per-request deadline_s expired
FINISH_CANCELLED = "cancelled"   # explicit engine.cancel(rid)
