"""Serving engine v2: paged KV cache + chunked prefill + prefix sharing
behind the versioned submit()/step()/drain() API (repro.serving.api).

Two data planes, one contract:

``mode="paged"`` (default) -- the KV cache is a shared pool of fixed-size
blocks (repro.serving.kv_cache); each slot's sequence lives in the blocks
its table points at.  Per tick:

  admit    -- free slots take pending requests, gated by block capacity
              (worst-case blocks of every active request always fit, so
              allocation never fails mid-flight).  Admission walks the
              prefix index: full blocks matching an earlier request's
              prompt are adopted zero-copy, a matching partial tail block
              is copied (eager copy-on-write) -- a shared system prompt
              is prefilled once, ever.
  prefill  -- ONE jitted multi-token forward advances every prefilling
              slot by one prompt chunk (positions=-1 padding routes to
              the null block), interleaved with decode so a long prompt
              never stalls the batch.  Blocks are exact-length: no
              length bucketing, no padded-tail invalidation.
  decode   -- ONE jitted step advances every decoding slot (S=1 chunk of
              the same paged path: scatter by table, gather by table,
              mask by stored absolute positions).
  finish   -- eviction frees the request's blocks; blocks indexed by the
              prefix cache stay resident (LRU-evicted under pressure).

``mode="slots"`` -- the PR-3..5 fixed-slot data plane, kept verbatim
(batch-1 bucketed prefill + `_invalidate_tail` + slot-scattered
rectangular caches) as the regression baseline the paged path must match
token-for-token, and as the `serving_bench --load` comparison point.

Greedy decoding is the bit-exactness contract: a mixed-adapter batch
produces token-for-token what N separate single-adapter runs produce
(tests/test_serving_multi.py, tests/test_serving_paged.py assert it).
temperature > 0 samples on the host from the returned logits
(per-request fold of the engine key).

Mesh-native serving (ISSUE-5): when the model was built with a
``MeshContext``, decode inputs are sharded over the `data` axes, the
pool's per-layer ``r_stack`` over `model` (method ``shard_specs``), and
the paged block pool is replicated -- greedy decode stays token-for-token
identical to the single-device engine (tests/test_sharded_fused.py).
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import methods, obs
from repro.models.model import Model
from repro.serving.api import (FINISH_CANCELLED, FINISH_DEADLINE,
                               FINISH_LENGTH, FINISH_STOP, GenerationResult,
                               Request, SamplingParams)
from repro.serving.kv_cache import BlockPoolExhausted, PagedKVCache
from repro.serving.pool import AdapterPool
from repro.serving.scheduler import Scheduler
from repro.train import serving as base_serving


def _invalidate_tail(model: Model, caches: dict, true_len: int) -> dict:
    """(slots mode only) Mark attention cache entries at positions >=
    true_len invalid (pos=-1): the k/v written there by a length-bucketed
    prefill's padding rows must never be attended.  The paged path needs
    none of this -- blocks are exact-length by construction."""
    from repro.models import transformer as tfm

    def fix(p, entry):
        if tfm.layer_kind(model.cfg, p) != "attn":
            return entry
        s = entry["pos"].shape[-1]
        tail = jnp.arange(s, dtype=jnp.int32)[None, None, :] >= true_len
        return {"k": entry["k"], "v": entry["v"],
                "pos": jnp.where(tail, -1, entry["pos"])}

    return {key: fix(int(key.split("_")[1]), val)
            for key, val in caches.items()}


def _scatter_slot(caches: dict, slot_caches: dict, slot: int) -> dict:
    """Write a batch-1 cache tree into row `slot` of the batched cache.
    Every cache leaf is (n_groups, B, ...): batch is axis 1 across
    attention k/v/pos AND SSM states by construction (Model._stack_cache)."""
    return jax.tree_util.tree_map(
        lambda big, one: jax.lax.dynamic_update_index_in_dim(
            big, one[:, 0].astype(big.dtype), slot, axis=1),
        caches, slot_caches)


class _EngineObs:
    """One engine's serving metrics, bound eagerly to registry children
    labeled ``engine="eN"`` so the full serving schema is present in the
    exposition from construction (not first use) and per-instance counts
    never collide between engines in one process.  This object IS the
    engine's counter state: ``health()`` is a read-only view over it."""

    def __init__(self, engine_id: str):
        self.engine_id = engine_id
        lbl = {"engine": engine_id}
        m = obs.metric
        self.ticks = m("serving/ticks_total").labels(**lbl)
        self.tick_seconds = m("serving/tick_seconds").labels(**lbl)
        self.tick_utilization = m("serving/tick_utilization").labels(**lbl)
        self.ttft = m("serving/ttft_seconds").labels(**lbl)
        self.latency = m("serving/latency_seconds").labels(**lbl)
        self.queue_wait = m("serving/queue_wait_seconds").labels(**lbl)
        self.submitted = m("serving/requests_submitted_total").labels(**lbl)
        self.tokens = m("serving/tokens_generated_total").labels(**lbl)
        self.prefill_rows = m("serving/prefill_rows_total").labels(**lbl)
        self.decode_rows = m("serving/decode_rows_total").labels(**lbl)
        self.inflight = m("serving/inflight").labels(**lbl)
        self.pending = m("serving/pending").labels(**lbl)
        self.requeued = m("serving/requeued").labels(**lbl)
        self.preemptions = m("serving/preemptions_total").labels(**lbl)
        self.retries = m("serving/retries_total").labels(**lbl)
        self.cancelled = m("serving/cancelled_total").labels(**lbl)
        self.deadline_expired = \
            m("serving/deadline_expired_total").labels(**lbl)
        self.pool = {
            "free": m("serving/kv/blocks_free").labels(**lbl),
            "used": m("serving/kv/blocks_used").labels(**lbl),
            "cached": m("serving/kv/blocks_cached").labels(**lbl),
            "seized": m("serving/kv/blocks_seized").labels(**lbl),
            "committed": m("serving/kv/blocks_committed").labels(**lbl),
            "capacity": m("serving/kv/capacity_blocks").labels(**lbl),
        }
        self._finished = m("serving/requests_finished_total")

    def finished(self, reason: str) -> None:
        self._finished.labels(engine=self.engine_id, reason=reason).inc()

    def counters(self) -> Dict[str, int]:
        """The legacy ``health()['counters']`` dict, read back from the
        registry (exact old shape and key names)."""
        return {"preemptions": int(self.preemptions.value),
                "retries": int(self.retries.value),
                "cancelled": int(self.cancelled.value),
                "deadline_expired": int(self.deadline_expired.value)}


class ServingEngine:
    """Continuous-batching engine over one frozen base and (optionally)
    an adapter pool, speaking the v2 request/response API:

        engine = ServingEngine(model, params, pool, n_slots=8)
        engine.submit(Request("r0", prompt, adapter_id=2,
                              sampling=SamplingParams(max_new_tokens=32)))
        finished = engine.step()      # one scheduler tick
        results = engine.drain()      # {rid: GenerationResult}

    ``run(requests) -> {rid: np.ndarray}`` is the v1-compatible wrapper.
    ``pool=None`` serves a single adapter tree (params as given, no
    per-row routing) -- that is what ``train.serving.generate`` wraps.
    """

    def __init__(self, model: Model, params: dict,
                 pool: Optional[AdapterPool] = None,
                 n_slots: int = 4, s_max: Optional[int] = None,
                 temperature: float = 0.0, jit: bool = True, key=None,
                 mode: str = "paged", page_size: int = 16,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 requeue_backoff: int = 1, requeue_backoff_max: int = 8):
        if mode not in ("paged", "slots"):
            raise ValueError(f"mode must be 'paged' or 'slots', got {mode!r}")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.model = model
        self.pool = pool
        self._base_params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.temperature = temperature
        self.jit = jit
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.mode = mode
        self.page_size = page_size
        self.num_blocks = num_blocks
        self.prefill_chunk = prefill_chunk
        self.shard = model.shard     # MeshContext or None (off-mesh)
        self._sched = Scheduler(n_slots)
        self._step_fn = self._make_step()
        self._decode = self._make_decode()
        # per-request bookkeeping, keyed by rid while unreaped
        self._gen: Dict[str, List[int]] = {}
        self._meta: Dict[str, dict] = {}
        # degradation machinery (ISSUE-7): preempted requests wait here
        # as (ready_tick, shadow Request) until their backoff elapses
        self._tick = 0
        self._admit_seq = 0
        self._requeue: List[tuple] = []
        self._backoff_base = max(1, int(requeue_backoff))
        self._backoff_max = max(self._backoff_base, int(requeue_backoff_max))
        self.obs = _EngineObs(f"e{obs.next_index('engine')}")
        # lazily-built data plane (needs the capacity, known at first step)
        self._state: Optional[dict] = None
        self._resolved: Optional[dict] = None
        self._resolved_key: Optional[int] = None

    @property
    def _counters(self) -> Dict[str, int]:
        """Deprecated alias: the degradation counters live in the metrics
        registry now (labeled ``engine="eN"``).  Read
        ``health()["counters"]`` instead; this property keeps old callers
        working (same dict shape) under a DeprecationWarning."""
        warnings.warn(
            "ServingEngine._counters is deprecated; the counters are "
            "registry-backed -- read health()['counters']",
            DeprecationWarning, stacklevel=2)
        return self.obs.counters()

    # -------------------------------------------------------------- params --
    @property
    def params(self) -> dict:
        """Serving tree resolved against the pool's CURRENT stack, so
        tenants registered after engine construction are served (the pool
        caches the built stack; registration invalidates it).  On-mesh,
        the pooled tree is placed per the method's ``shard_specs`` --
        every ``r_stack`` block-sharded over `model` with its weight.
        ``pool=None``: the constructor params, as given."""
        if self.pool is None:
            return self._base_params
        pooled = self.pool.pooled_adapter
        if self._resolved is not None and self._resolved_key == id(pooled):
            return self._resolved
        p = {"base": self._base_params["base"], "adapter": pooled}
        if self.shard is not None:
            from repro.distributed.sharding import fit_tree
            method = methods.get(self.pool.acfg.kind)
            specs = method.shard_specs(p["adapter"], self.shard)
            p = {"base": p["base"],
                 "adapter": fit_tree(p["adapter"], specs, self.shard.mesh)}
        self._resolved, self._resolved_key = p, id(pooled)
        return p

    def _place_batch(self, x):
        """Shard a decode input's slot dim over the data axes (dropped when
        n_slots does not divide them)."""
        if self.shard is None:
            return jnp.asarray(x)
        from repro.distributed.sharding import fit_placed
        from jax.sharding import PartitionSpec
        spec = PartitionSpec(self.shard.data_axes,
                             *([None] * (np.ndim(x) - 1)))
        return fit_placed(jnp.asarray(x), spec, self.shard.mesh)

    # ---------------------------------------------------------------- intake --
    def submit(self, request: Request) -> None:
        """Queue one request; it is admitted on a later ``step()`` when a
        slot and (paged mode) enough KV blocks are free."""
        rid = request.rid
        if rid in self._gen:
            raise ValueError(f"duplicate request ids: {[rid]}")
        if self.pool is not None:
            n_pool = self.pool.n_adapters
            if not 0 <= request.adapter_id < n_pool:
                raise ValueError(
                    f"request {rid!r}: adapter_id {request.adapter_id} "
                    f"outside the pool (n_adapters={n_pool}) -- the kernels "
                    f"would silently rotate its rows to zero")
        elif request.adapter_id != 0:
            raise ValueError(
                f"request {rid!r}: adapter_id {request.adapter_id} without "
                f"an adapter pool (single-adapter engine serves id 0 only)")
        need = len(request.prompt) + request.max_new_tokens
        if self._state is not None and need > self._state["s_cap"] \
                and self._sched.active_slots():
            raise ValueError(
                f"request {rid!r} needs {need} positions but the engine "
                f"was sized for {self._state['s_cap']} and is mid-flight; "
                f"construct the engine with s_max={need} (or larger)")
        now = time.perf_counter()
        self._gen[rid] = []
        self._meta[rid] = {"req": request, "submitted": now,
                           "first": None, "shared": 0, "blocks": 0,
                           "plen": len(request.prompt), "retries": 0,
                           "deadline": (None if request.deadline_s is None
                                        else now + request.deadline_s)}
        self._sched.submit(request)
        self.obs.submitted.inc()

    def has_work(self) -> bool:
        return self._sched.has_work() or bool(self._requeue)

    @property
    def kv(self) -> Optional[PagedKVCache]:
        """The paged block pool (None before the first step / slots
        mode) -- the chaos entry point: ``engine.kv.seize(n)`` injects
        pool pressure, ``engine.kv.release_seized()`` lifts it."""
        st = self._state
        return st["kv"] if (st is not None and self.mode == "paged") else None

    # ----------------------------------------------------------- data plane --
    def _required_cap(self) -> int:
        need = [m["req"] for m in self._meta.values()]
        return max((len(r.prompt) + r.max_new_tokens for r in need),
                   default=0)

    def _ensure_state(self) -> None:
        required = self._required_cap()
        if self._state is not None:
            if required <= self._state["s_cap"]:
                return
            # grow: only safe between flights (nothing holds cache state)
            assert not self._sched.active_slots(), \
                "submit() should have rejected an over-size mid-flight request"
            self._state = None
        # slots mode honors an explicit s_max verbatim (v1 semantics); the
        # paged table width must cover the longest request regardless.
        s_cap = (self.s_max or required) if self.mode == "slots" \
            else max(self.s_max or 0, required)
        st: dict = {"s_cap": s_cap}
        if self.mode == "paged":
            bps = -(-s_cap // self.page_size)
            nb = self.num_blocks or (self.n_slots * bps + bps + 1)
            kv = PagedKVCache(self.model, num_blocks=nb,
                              block_size=self.page_size,
                              max_seq_len=bps * self.page_size)
            if self.shard is not None:
                # the block pool is replicated over the mesh (tables and
                # tokens are the data-sharded inputs)
                from repro.distributed.sharding import fit_placed
                from jax.sharding import PartitionSpec as P
                kv.pool = jax.tree_util.tree_map(
                    lambda a: fit_placed(a, P(), self.shard.mesh), kv.pool)
            st["kv"] = kv
            st["committed"] = 0
            st["prefill"] = {}       # slot -> next prompt position to write
        else:
            caches = self.model.make_caches(self.n_slots, s_cap)
            if self.shard is not None:
                from repro.distributed.sharding import fit_tree
                caches = fit_tree(
                    caches, self.model.cache_specs(self.shard.rules,
                                                   self.n_slots, s_cap),
                    self.shard.mesh)
            st["caches"] = caches
        st["tok"] = np.zeros((self.n_slots, 1), np.int32)
        st["pos"] = np.full((self.n_slots,), -1, np.int32)
        st["aid"] = np.zeros((self.n_slots,), np.int32)
        st["age"] = np.zeros((self.n_slots,), np.int64)  # admission seq no.
        self._state = st

    # ------------------------------------------------------------- forwards --
    def _make_step(self):
        """One jitted forward for BOTH paged prefill chunks and paged
        decode (S=1 is just the smallest chunk): scatter k/v by block
        table, gather by table, mask by stored positions."""
        model = self.model
        routed = self.pool is not None

        def step(params, pool, tok, pos, tables, aid):
            batch = {"tokens": tok, "positions": pos,
                     "cache_index": pos[:, 0],
                     "caches": pool, "block_tables": tables}
            if routed:
                batch["adapter_id"] = aid
            logits, pool = model.decode_step(params, batch)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return greedy, logits, pool

        name = "paged_step_multi" if routed else "paged_step"
        return base_serving.model_jit_fn(model, name, step, jit=self.jit)

    def _make_decode(self):
        """Slots-mode batched decode (the v1 data plane)."""
        model = self.model
        routed = self.pool is not None

        def step(params, caches, tok, pos, aid):
            batch = {"tokens": tok,
                     "positions": pos[:, None],
                     "cache_index": pos,
                     "caches": caches}
            if routed:
                batch["adapter_id"] = aid
            logits, caches = model.decode_step(params, batch)
            logits = logits[:, 0]                       # (n_slots, V)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return greedy, logits, caches

        name = "serving_decode" if routed else "serving_decode_single"
        return base_serving.model_jit_fn(model, name, step, jit=self.jit)

    def _prefill_slots(self, req: Request, s_max: int, params: dict):
        """(slots mode) Batch-1 prefill through the multi kernels
        (adapter_id routes the single row); returns (last-real-token
        logits, slot caches at s_max).

        The prompt is zero-padded to a multiple of 8 before the jitted
        prefill so heterogeneous traffic compiles O(s_max/8) prefill
        variants, not one per distinct prompt length.  Causality keeps the
        real rows' logits exact; the padded tail's cache entries are
        invalidated (pos=-1, same convention as pad_caches) so decode
        attention never sees them."""
        true_len = len(req.prompt)
        pad_to = min(s_max, -(-true_len // 8) * 8)
        prompt = jnp.asarray(req.prompt, jnp.int32)
        if pad_to > true_len:
            prompt = jnp.pad(prompt, (0, pad_to - true_len))
        batch = {"tokens": prompt[None, :]}
        if self.pool is not None:
            batch["adapter_id"] = jnp.full((1,), req.adapter_id, jnp.int32)
        logits, caches = base_serving.prefill_fn(self.model, jit=self.jit)(
            params, batch)
        caches = base_serving.pad_caches(self.model, caches, s_max)
        if pad_to > true_len:
            caches = _invalidate_tail(self.model, caches, true_len)
        return logits[0, true_len - 1], caches

    # -------------------------------------------------------------- sampling --
    def _sample(self, logits, req: Request, step: int) -> int:
        t = req.sampling.temperature
        if t is None:
            t = self.temperature
        if t <= 0:
            return int(jnp.argmax(logits, axis=-1))
        import zlib
        k = jax.random.fold_in(jax.random.fold_in(
            self.key, zlib.crc32(req.rid.encode()) % (2 ** 31)), step)
        return int(jax.random.categorical(
            k, logits.astype(jnp.float32) / t, axis=-1))

    def _greedy_all(self, req: Request) -> bool:
        t = req.sampling.temperature
        return (self.temperature if t is None else t) <= 0

    # ------------------------------------------------------------ lifecycle --
    def _record(self, slot: int, req: Request, token: int,
                finished: List[GenerationResult]) -> None:
        meta = self._meta[req.rid]
        now = time.perf_counter()
        if meta["first"] is None:
            meta["first"] = now
            self.obs.ttft.observe(now - meta["submitted"])
        self._gen[req.rid].append(token)
        self.obs.tokens.inc()
        if self._sched.record_token(slot, token):
            self._finish(slot, req, token, finished, now)

    def _finish(self, slot: int, req: Request, last_token: int,
                finished: List[GenerationResult], now: float) -> None:
        meta = self._meta.pop(req.rid)
        tokens = np.asarray(self._gen.pop(req.rid), np.int32)
        reason = FINISH_STOP if (req.eos_id is not None
                                 and last_token == req.eos_id) \
            else FINISH_LENGTH
        self._sched.evict(slot)
        st = self._state
        st["pos"][slot] = -1
        if self.mode == "paged":
            st["kv"].free(req.rid)
            st["committed"] -= meta["blocks"]
            st["prefill"].pop(slot, None)
        # meta["plen"] not len(req.prompt): after a preempt/requeue cycle
        # the slot's request is a shadow whose prompt includes generated
        # tokens -- the result must report the ORIGINAL prompt length
        self.obs.latency.observe(now - meta["submitted"])
        self.obs.finished(reason)
        finished.append(GenerationResult(
            rid=req.rid, tokens=tokens, finish_reason=reason,
            prompt_len=meta["plen"], submitted_at=meta["submitted"],
            first_token_at=meta["first"], finished_at=now,
            prefix_blocks_shared=meta["shared"], retries=meta["retries"]))

    # ----------------------------------------------------------------- step --
    def step(self) -> List[GenerationResult]:
        """One scheduler tick: expire deadlines, readmit requeued
        (previously preempted) requests whose backoff elapsed, admit what
        fits, advance every prefilling slot by one prompt chunk, advance
        every decoding slot by one token.  Returns the requests that
        finished this tick (including deadline-cancelled ones)."""
        o = self.obs
        t0 = time.perf_counter()
        with obs.span("engine.step", engine=o.engine_id,
                      tick=self._tick + 1):
            finished = self._step_inner()
        o.ticks.inc()
        o.tick_seconds.observe(time.perf_counter() - t0)
        inflight = len(self._sched.active_slots())
        o.inflight.set(inflight)
        o.pending.set(self._sched.pending_count)
        o.requeued.set(len(self._requeue))
        o.tick_utilization.set(inflight / self.n_slots)
        self._sync_pool_gauges()
        return finished

    def _step_inner(self) -> List[GenerationResult]:
        finished: List[GenerationResult] = []
        self._tick += 1
        now = time.perf_counter()
        for rid in [r for r, m in self._meta.items()
                    if m["deadline"] is not None and now > m["deadline"]]:
            self.obs.deadline_expired.inc()
            finished.append(self._cancel_rid(rid, FINISH_DEADLINE))
        if self._requeue:
            ready = [r for t, r in self._requeue if t <= self._tick]
            self._requeue = [(t, r) for t, r in self._requeue
                             if t > self._tick]
            # reversed: the oldest preemptee ends up at the queue front
            for req in reversed(ready):
                self._sched.submit_front(req)
                self.obs.retries.inc()
        if not self._sched.has_work():
            return finished
        self._ensure_state()
        params = self.params
        if self.mode == "paged":
            self._tick_paged(params, finished)
        else:
            self._tick_slots(params, finished)
        return finished

    def _sync_pool_gauges(self) -> None:
        """Mirror the live block-pool pressure into the registry gauges
        (the pool dict in ``health()`` is read back from these)."""
        st = self._state
        if self.mode != "paged" or st is None:
            return
        kv: PagedKVCache = st["kv"]
        p = self.obs.pool
        p["free"].set(kv.alloc.n_free)
        p["used"].set(kv.alloc.n_used)
        p["cached"].set(len(kv._cached))
        p["seized"].set(kv.n_seized)
        p["committed"].set(st["committed"])
        p["capacity"].set(kv.capacity_blocks)

    def cancel(self, rid: str) -> GenerationResult:
        """Cancel an unfinished request wherever it is (pending, requeued
        after a preemption, prefilling, or decoding); frees its KV blocks
        and returns a result with the tokens produced so far and
        ``finish_reason="cancelled"``."""
        if rid not in self._meta:
            raise KeyError(f"unknown or already-finished request {rid!r}")
        self.obs.cancelled.inc()
        return self._cancel_rid(rid, FINISH_CANCELLED)

    def health(self) -> dict:
        """Degradation-visible engine snapshot: queue/inflight depths,
        preempt/retry/cancel counters, and (paged) block-pool pressure.
        Every number is a view over the metrics registry (the engine's
        labeled children) -- the same state ``/metrics`` exports -- so the
        dict shape stays what PR-7 callers expect with zero double
        bookkeeping."""
        h = {"mode": self.mode, "tick": self._tick,
             "inflight": len(self._sched.active_slots()),
             "pending": self._sched.pending_count,
             "requeued": len(self._requeue),
             "counters": self.obs.counters()}
        st = self._state
        if self.mode == "paged" and st is not None:
            self._sync_pool_gauges()
            h["pool"] = {k: int(g.value) for k, g in self.obs.pool.items()}
            h["kv_stats"] = dict(st["kv"].stats)
        return h

    def _cancel_rid(self, rid: str, reason: str) -> GenerationResult:
        st = self._state
        slot = next((s for s in self._sched.active_slots()
                     if self._sched.slot_request(s).rid == rid), None)
        meta = self._meta.pop(rid)
        if slot is not None:                 # active -> st exists
            self._sched.evict(slot)
            st["pos"][slot] = -1
            if self.mode == "paged":
                st["kv"].free(rid)
                st["committed"] -= meta["blocks"]
                st["prefill"].pop(slot, None)
        else:
            self._sched.remove_pending(rid)
            self._requeue = [(t, r) for t, r in self._requeue
                             if r.rid != rid]
        tokens = np.asarray(self._gen.pop(rid), np.int32)
        now = time.perf_counter()
        return GenerationResult(
            rid=rid, tokens=tokens, finish_reason=reason,
            prompt_len=meta["plen"], submitted_at=meta["submitted"],
            first_token_at=(meta["first"] if meta["first"] is not None
                            else now),
            finished_at=now, prefix_blocks_shared=meta["shared"],
            retries=meta["retries"])

    def drain(self) -> Dict[str, GenerationResult]:
        """Step until idle; returns {rid: GenerationResult} for everything
        that finished along the way."""
        out: Dict[str, GenerationResult] = {}
        # self.has_work(), not self._sched.has_work(): requests backing off
        # in the requeue list after a preemption are live work too -- the
        # scheduler only learns about them when their backoff elapses
        while self.has_work():
            for res in self.step():
                out[res.rid] = res
        return out

    def run(self, requests: Sequence[Request]) -> Dict[str, np.ndarray]:
        """v1-compatible batch interface: serve all requests to
        completion; returns {rid: generated token ids} (prompt excluded).
        New code should use submit()/step()/drain() and GenerationResult."""
        if not requests:
            return {}
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            dup = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(f"duplicate request ids: {dup}")
        for r in requests:
            self.submit(r)
        results = self.drain()
        return {rid: results[rid].tokens for rid in rids}

    # ---------------------------------------------------------- degradation --
    def _requeue_request(self, req: Request) -> None:
        """Park ``req`` until its exponential backoff elapses (bounded by
        ``requeue_backoff_max`` ticks)."""
        meta = self._meta[req.rid]
        meta["retries"] += 1
        delay = min(self._backoff_base * (2 ** (meta["retries"] - 1)),
                    self._backoff_max)
        self._requeue.append((self._tick + delay, req))

    def _preempt_slot(self, slot: int) -> None:
        """Evict the request in ``slot`` under pool pressure: commit the
        token chain it has written (so the retry re-adopts those blocks
        through the prefix cache instead of re-prefilling), free its
        blocks + reservation, and requeue a shadow request whose prompt is
        prompt+generated-so-far and whose budget is what is left.  No
        token is lost: generation resumes exactly where it stopped."""
        st = self._state
        kv: PagedKVCache = st["kv"]
        req = self._sched.slot_request(slot)
        rid = req.rid
        meta = self._meta[rid]
        orig = meta["req"]
        full = [int(t) for t in orig.prompt] + self._gen[rid]
        # positions < written are on device: prefill wrote prompt[:done];
        # a decoding slot has written everything before st["pos"] (the
        # pending token at pos itself is written by the NEXT forward)
        written = (st["prefill"][slot] if slot in st["prefill"]
                   else int(st["pos"][slot]))
        if written > 0:
            kv.commit_chain(rid, full[:written])
        kv.free(rid)
        st["committed"] -= meta["blocks"]
        meta["blocks"] = 0
        self._sched.evict(slot)
        st["pos"][slot] = -1
        st["prefill"].pop(slot, None)
        self.obs.preemptions.inc()
        obs.event("engine.preempt", engine=self.obs.engine_id, rid=rid,
                  tick=self._tick)
        remaining = max(orig.max_new_tokens - len(self._gen[rid]), 1)
        shadow = Request(rid, full, adapter_id=orig.adapter_id,
                         sampling=SamplingParams(
                             max_new_tokens=remaining,
                             temperature=orig.sampling.temperature,
                             eos_id=orig.sampling.eos_id))
        self._requeue_request(shadow)

    # ------------------------------------------------------------ paged tick --
    def _tick_paged(self, params, finished: List[GenerationResult]) -> None:
        st = self._state
        kv: PagedKVCache = st["kv"]

        def can_admit(req: Request) -> bool:
            # reserves on True: the scheduler admits exactly the requests
            # this returns True for, one call each, so committing here
            # keeps the worst-case block count honest WITHIN one tick's
            # admission sweep (not just across ticks).
            need = kv.blocks_for(len(req.prompt) + req.max_new_tokens)
            if need > kv.num_blocks - 1:
                raise ValueError(
                    f"request {req.rid!r} alone needs {need} KV blocks but "
                    f"the pool holds {kv.num_blocks - 1}; raise num_blocks "
                    f"or s_max")
            if st["committed"] + need > kv.capacity_blocks:
                return False
            st["committed"] += need
            return True

        for slot, req in self._sched.admit(can_admit):
            need = kv.blocks_for(len(req.prompt) + req.max_new_tokens)
            try:
                start, shared = kv.begin(req.rid, req.prompt, req.adapter_id)
            except BlockPoolExhausted:
                # reservation raced a seized pool; undo and back off
                st["committed"] -= need
                self._sched.evict(slot)
                self._requeue_request(req)
                continue
            meta = self._meta[req.rid]
            self.obs.queue_wait.observe(time.perf_counter()
                                        - meta["submitted"])
            meta["shared"] += shared
            meta["blocks"] = need
            st["aid"][slot] = req.adapter_id
            st["pos"][slot] = -1          # not decoding until prefill done
            st["prefill"][slot] = start
            st["age"][slot] = self._admit_seq
            self._admit_seq += 1

        # ---- capacity phase, oldest admission first: grow every active
        # slot's table for this tick BEFORE building the batch.  Under
        # chaos-seized pool pressure this is where BlockPoolExhausted
        # surfaces; the degradation policy is preempt-youngest: the newest
        # admission loses its slot (its written blocks indexed for cheap
        # retry) and the oldest requests keep streaming tokens.
        while True:
            active = self._sched.active_slots()
            if not active:
                return
            C = self.prefill_chunk if st["prefill"] else 1
            try:
                for slot in sorted(active, key=lambda s: st["age"][s]):
                    req = self._sched.slot_request(slot)
                    if slot in st["prefill"]:
                        done = st["prefill"][slot]
                        c = min(C, len(req.prompt) - done)
                        kv.ensure_capacity(req.rid, done + c - 1)
                    else:
                        kv.ensure_capacity(req.rid, int(st["pos"][slot]))
                break
            except BlockPoolExhausted:
                victim = max(active, key=lambda s: st["age"][s])
                self._preempt_slot(victim)

        def slot_rids():
            rids: List[Optional[str]] = [None] * self.n_slots
            for s in self._sched.active_slots():
                rids[s] = self._sched.slot_request(s).rid
            return rids

        # ---- ONE unified forward per tick: every prefilling slot advances
        # one prompt chunk and every decoding slot one token, in the SAME
        # batch (decode rows ride lane 0 of the chunk, lanes 1..C-1 are -1
        # padding into the null block).  Mixed prefill/decode ticks cost
        # one jitted call, not two -- under churny open-loop traffic most
        # ticks are mixed, and this is where the paged engine's saturation
        # throughput comes from.  Pure-decode ticks shrink to C=1.
        decoding = [s for s in self._sched.active_slots()
                    if s not in st["prefill"]]
        if not st["prefill"] and not decoding:
            return
        C = self.prefill_chunk if st["prefill"] else 1
        tok = np.zeros((self.n_slots, C), np.int32)
        pos = np.full((self.n_slots, C), -1, np.int32)
        spans = {}
        # (block capacity for every span below was ensured in the
        # capacity phase above, before any preemption decisions)
        for slot, done in st["prefill"].items():
            req = self._sched.slot_request(slot)
            c = min(C, len(req.prompt) - done)
            tok[slot, :c] = req.prompt[done:done + c]
            pos[slot, :c] = np.arange(done, done + c)
            spans[slot] = (req, done, c)
        for slot in decoding:
            tok[slot, 0] = st["tok"][slot, 0]
            pos[slot, 0] = st["pos"][slot]
        self.obs.prefill_rows.inc(len(spans))
        self.obs.decode_rows.inc(len(decoding))
        kv.flush()
        tables = kv.table_rows(slot_rids())
        greedy, logits, kv.pool = self._step_fn(
            params, kv.pool, self._place_batch(tok),
            self._place_batch(pos), self._place_batch(tables),
            self._place_batch(st["aid"]))
        greedy_np = np.asarray(greedy)
        logits_np = None
        for slot, (req, done, c) in spans.items():
            if done + c >= len(req.prompt):
                del st["prefill"][slot]
                kv.commit_prefix(req.rid)
                if self._greedy_all(req):
                    first = int(greedy_np[slot, c - 1])
                else:
                    if logits_np is None:
                        logits_np = np.asarray(logits)
                    # step index = tokens generated so far, NOT 0: after a
                    # preempt/requeue cycle this prefill completion samples
                    # mid-generation and must reuse the same fold-in index
                    # an uninterrupted run would have used
                    first = self._sample(
                        jnp.asarray(logits_np[slot, c - 1]), req,
                        len(self._gen[req.rid]))
                st["tok"][slot, 0] = first
                st["pos"][slot] = len(req.prompt)
                self._record(slot, req, first, finished)
            else:
                st["prefill"][slot] = done + c
        for slot in decoding:
            req = self._sched.slot_request(slot)
            if self._greedy_all(req):
                token = int(greedy_np[slot, 0])
            else:
                if logits_np is None:
                    logits_np = np.asarray(logits)
                token = self._sample(jnp.asarray(logits_np[slot, 0]), req,
                                     len(self._gen[req.rid]))
            st["pos"][slot] += 1
            self._record(slot, req, token, finished)
            if req.rid in self._gen:       # still running
                st["tok"][slot, 0] = token

    # ------------------------------------------------------------ slots tick --
    def _tick_slots(self, params, finished: List[GenerationResult]) -> None:
        st = self._state
        decode = getattr(self, "_decode", None)
        if decode is None:
            decode = self._decode = self._make_decode()

        for slot, req in self._sched.admit():
            self.obs.queue_wait.observe(
                time.perf_counter() - self._meta[req.rid]["submitted"])
            self.obs.prefill_rows.inc()
            logits_last, slot_caches = self._prefill_slots(
                req, st["s_cap"], params)
            st["caches"] = _scatter_slot(st["caches"], slot_caches, slot)
            first = self._sample(logits_last, req, 0)
            st["tok"][slot, 0] = first
            st["pos"][slot] = len(req.prompt)
            st["aid"][slot] = req.adapter_id
            self._record(slot, req, first, finished)

        active = self._sched.active_slots()
        if not active:
            return
        self.obs.decode_rows.inc(len(active))

        # rows of free slots compute garbage and are ignored (row
        # independence is what the kernel tests pin down, bitwise); their
        # pos rides at 0, not -1, exactly as in the v1 engine.
        pos = np.maximum(st["pos"], 0)
        greedy, logits, st["caches"] = decode(
            params, st["caches"], self._place_batch(st["tok"]),
            self._place_batch(pos), self._place_batch(st["aid"]))
        greedy_np = np.asarray(greedy)
        logits_np = None
        for slot in active:
            req = self._sched.slot_request(slot)
            if self._greedy_all(req):
                token = int(greedy_np[slot])
            else:
                if logits_np is None:
                    logits_np = np.asarray(logits)
                token = self._sample(jnp.asarray(logits_np[slot]), req,
                                     len(self._gen[req.rid]))
            st["pos"][slot] += 1
            self._record(slot, req, token, finished)
            if req.rid in self._gen:
                st["tok"][slot, 0] = token
