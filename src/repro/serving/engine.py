"""Multi-tenant serving engine: continuous batching over one frozen base
and an adapter pool, with per-request rotation routing in the fused Pallas
kernels.

Data plane per tick:

  admit   -- free slots take pending requests; each new request is
             prefilled (batch-1 forward through the SAME multi-routing
             kernels, adapter_id = its tenant) and its caches scattered
             into the slot's region of the batched decode cache.  The
             prefill logits directly yield the first generated token -- the
             prompt is never forwarded twice.
  decode  -- ONE jitted decode step advances every active slot: tokens
             (n_slots, 1), per-slot positions/cache_index, and the per-slot
             adapter_id vector that the multi kernels use to gather each
             row's rotation blocks.  Rows of free slots compute garbage and
             are ignored (row independence is what the kernel tests pin
             down, bitwise).
  evict   -- finished requests free their slot; the next pending request
             takes it on the following tick.

Greedy decoding is the bit-exactness contract: a mixed-adapter batch
produces token-for-token what N separate single-adapter runs produce
(tests/test_serving_multi.py asserts it).  temperature > 0 samples on the
host from the returned logits (per-request fold of the engine key).

Mesh-native serving (ISSUE-5): when the model was built with a
``MeshContext`` (repro.distributed.sharding.make_shard_context), the engine
shards the slot batch over the `data` axes and the pool's per-layer
``r_stack`` over `model` (via the method's ``shard_specs`` hook, blocks
co-sharded with the weight), and the batched decode runs the multi-routing
kernels per-shard inside shard_map -- greedy decode stays token-for-token
identical to the single-device engine (tests/test_sharded_fused.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import methods
from repro.models.model import Model
from repro.serving.pool import AdapterPool
from repro.serving.scheduler import Request, Scheduler
from repro.train import serving as base_serving


def _invalidate_tail(model: Model, caches: dict, true_len: int) -> dict:
    """Mark attention cache entries at positions >= true_len invalid
    (pos=-1): the k/v written there by a length-bucketed prefill's padding
    rows must never be attended (decode overwrites slot true_len first)."""
    from repro.models import transformer as tfm

    def fix(p, entry):
        if tfm.layer_kind(model.cfg, p) != "attn":
            return entry
        s = entry["pos"].shape[-1]
        tail = jnp.arange(s, dtype=jnp.int32)[None, None, :] >= true_len
        return {"k": entry["k"], "v": entry["v"],
                "pos": jnp.where(tail, -1, entry["pos"])}

    return {key: fix(int(key.split("_")[1]), val)
            for key, val in caches.items()}


def _scatter_slot(caches: dict, slot_caches: dict, slot: int) -> dict:
    """Write a batch-1 cache tree into row `slot` of the batched cache.
    Every cache leaf is (n_groups, B, ...): batch is axis 1 across
    attention k/v/pos AND SSM states by construction (Model._stack_cache)."""
    return jax.tree_util.tree_map(
        lambda big, one: jax.lax.dynamic_update_index_in_dim(
            big, one[:, 0].astype(big.dtype), slot, axis=1),
        caches, slot_caches)


class ServingEngine:
    """Slot-batched decode over a pooled multi-adapter model.

    engine = ServingEngine(model, params, pool, n_slots=8)
    outputs = engine.run([Request("r0", prompt, adapter_id=2, ...), ...])
    # outputs: {rid: np.ndarray of generated token ids}
    """

    def __init__(self, model: Model, params: dict, pool: AdapterPool,
                 n_slots: int = 4, s_max: Optional[int] = None,
                 temperature: float = 0.0, jit: bool = True,
                 key=None):
        self.model = model
        self.pool = pool
        self._base_params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.temperature = temperature
        self.jit = jit
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.shard = model.shard     # MeshContext or None (off-mesh)
        self._decode = self._make_decode()

    @property
    def params(self) -> dict:
        """Serving tree resolved against the pool's CURRENT stack, so
        tenants registered after engine construction are served (the pool
        caches the built stack; registration invalidates it).  On-mesh,
        the pooled tree is placed per the method's ``shard_specs`` --
        every ``r_stack`` block-sharded over `model` with its weight."""
        p = self.pool.serving_params(self._base_params)
        if self.shard is not None:
            from repro.distributed.sharding import fit_tree
            method = methods.get(self.pool.acfg.kind)
            specs = method.shard_specs(p["adapter"], self.shard)
            p = {"base": p["base"],
                 "adapter": fit_tree(p["adapter"], specs, self.shard.mesh)}
        return p

    def _place_batch(self, x):
        """Shard a decode input's slot dim over the data axes (dropped when
        n_slots does not divide them)."""
        if self.shard is None:
            return jnp.asarray(x)
        from repro.distributed.sharding import fit_placed
        from jax.sharding import PartitionSpec
        spec = PartitionSpec(self.shard.data_axes,
                             *([None] * (np.ndim(x) - 1)))
        return fit_placed(jnp.asarray(x), spec, self.shard.mesh)

    # ------------------------------------------------------------- decode --
    def _make_decode(self):
        model = self.model

        def step(params, caches, tok, pos, aid):
            batch = {"tokens": tok,
                     "positions": pos[:, None],
                     "cache_index": pos,
                     "caches": caches,
                     "adapter_id": aid}
            logits, caches = model.decode_step(params, batch)
            logits = logits[:, 0]                       # (n_slots, V)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return greedy, logits, caches

        return base_serving.model_jit_fn(model, "serving_decode", step,
                                         jit=self.jit)

    def _prefill(self, req: Request, s_max: int, params: dict):
        """Batch-1 prefill through the multi kernels (adapter_id routes the
        single row); returns (last-real-token logits, slot caches at s_max).

        The prompt is zero-padded to a multiple of 8 before the jitted
        prefill so heterogeneous traffic compiles O(s_max/8) prefill
        variants, not one per distinct prompt length.  Causality keeps the
        real rows' logits exact; the padded tail's cache entries are
        invalidated (pos=-1, same convention as pad_caches) so decode
        attention never sees them."""
        true_len = len(req.prompt)
        pad_to = min(s_max, -(-true_len // 8) * 8)
        prompt = jnp.asarray(req.prompt, jnp.int32)
        if pad_to > true_len:
            prompt = jnp.pad(prompt, (0, pad_to - true_len))
        aid = jnp.full((1,), req.adapter_id, jnp.int32)
        logits, caches = base_serving.prefill_fn(self.model, jit=self.jit)(
            params, {"tokens": prompt[None, :], "adapter_id": aid})
        caches = base_serving.pad_caches(self.model, caches, s_max)
        if pad_to > true_len:
            caches = _invalidate_tail(self.model, caches, true_len)
        return logits[0, true_len - 1], caches

    def _sample(self, logits, rid: str, step: int) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits, axis=-1))
        import zlib
        k = jax.random.fold_in(jax.random.fold_in(
            self.key, zlib.crc32(rid.encode()) % (2 ** 31)), step)
        return int(jax.random.categorical(
            k, logits.astype(jnp.float32) / self.temperature, axis=-1))

    # ---------------------------------------------------------------- run --
    def run(self, requests: Sequence[Request]) -> Dict[str, np.ndarray]:
        """Serve all requests to completion with continuous batching;
        returns {rid: generated token ids} (prompt excluded)."""
        if not requests:
            return {}
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            dup = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(f"duplicate request ids: {dup}")
        n_pool = self.pool.n_adapters
        for r in requests:
            if not 0 <= r.adapter_id < n_pool:
                raise ValueError(
                    f"request {r.rid!r}: adapter_id {r.adapter_id} outside "
                    f"the pool (n_adapters={n_pool}) -- the kernels would "
                    f"silently rotate its rows to zero")
        sched = Scheduler(self.n_slots)
        sched.submit_all(requests)
        s_max = self.s_max or max(len(r.prompt) + r.max_new_tokens
                                  for r in requests)
        params = self.params      # resolve the pool stack once per run

        caches = self.model.make_caches(self.n_slots, s_max)
        if self.shard is not None:
            # decode caches: slot dim over `data` (and, when enabled and
            # divisible, the cache seq dim over `model` -- split-KV decode)
            from repro.distributed.sharding import fit_tree
            caches = fit_tree(
                caches, self.model.cache_specs(self.shard.rules,
                                               self.n_slots, s_max),
                self.shard.mesh)
        tok = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        aid = np.zeros((self.n_slots,), np.int32)
        out: Dict[str, List[int]] = {r.rid: [] for r in requests}

        while sched.has_work():
            # ---- admission: prefill into free slots -----------------------
            for slot, req in sched.admit():
                logits_last, slot_caches = self._prefill(req, s_max, params)
                caches = _scatter_slot(caches, slot_caches, slot)
                first = self._sample(logits_last, req.rid, 0)
                out[req.rid].append(first)
                tok[slot, 0] = first
                pos[slot] = len(req.prompt)
                aid[slot] = req.adapter_id
                if sched.record_token(slot, first):
                    sched.evict(slot)

            active = sched.active_slots()
            if not active:
                continue     # everything admitted this tick already finished

            # ---- one batched decode tick for every active slot ------------
            greedy, logits, caches = self._decode(
                params, caches, self._place_batch(tok),
                self._place_batch(pos), self._place_batch(aid))
            greedy_np = np.asarray(greedy)
            logits_np = None if self.temperature <= 0 else np.asarray(logits)
            for slot in active:
                req = sched.slot_request(slot)
                step_i = len(out[req.rid])
                if self.temperature <= 0:
                    token = int(greedy_np[slot])
                else:
                    token = self._sample(jnp.asarray(logits_np[slot]),
                                         req.rid, step_i)
                out[req.rid].append(token)
                tok[slot, 0] = token
                pos[slot] += 1
                if sched.record_token(slot, token):
                    sched.evict(slot)

        return {rid: np.asarray(toks, np.int32) for rid, toks in out.items()}
