"""HOFT (Householder-product orthogonal finetuning) as a registered
``AdapterMethod`` -- the method added to PROVE the registry API: one
module, zero framework edits.

Math in ``repro.core.hoft``; fused forward kernel in
``repro.kernels.hoft_linear_fused`` (its VJP is the jnp reference, so
``supports_fused_vjp`` stays False).  No hoisted-rotation or multi-tenant
capability yet: routing a HOFT model into the serving pool raises
``NotImplementedError`` at pool-construction time via the base hooks.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import hoft as hoft_lib
from repro.methods.base import AdapterMethod, register

# built lazily: repro.models imports repro.core.adapter (which imports this
# package), so models.spec cannot be a module-level import here
_HOFT_VDEF_CLS = None


def _hoft_vdef_cls():
    global _HOFT_VDEF_CLS
    if _HOFT_VDEF_CLS is None:
        from repro.models.spec import CompositeDef, ParamDef

        class _HoftVDef(CompositeDef):
            """CompositeDef for the (m, d_in) reflection stack: the paired
            duplicate-rows identity init is not expressible as an
            elementwise ``ParamDef`` initializer (rows 2i and 2i+1 must
            START equal, then train apart)."""

            def __init__(self, d_in: int, m: int):
                self.d_in, self.m = d_in, m
                self._def = ParamDef((m, d_in), ("hoft_refl", None),
                                     "normal")

            def expand_defs(self):
                return self._def

            def init(self, key, param_dtype):
                return hoft_lib.hoft_init(key, self.d_in, self.m)["hh_v"]

        _HOFT_VDEF_CLS = _HoftVDef
    return _HOFT_VDEF_CLS


@register
class HOFTMethod(AdapterMethod):
    kind = "hoft"
    stochastic_init = True        # paired random vectors (identity product)
    supports_fused_forward = True   # hoft_linear_fused (dense W)
    supports_fused_vjp = False      # backward = jnp reference VJP
    supports_hoisted_rotations = False
    supports_multi_tenant = False

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        return hoft_lib.hoft_init(key, d_in,
                                  hoft_lib.num_reflections(acfg),
                                  dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return hoft_lib.hoft_param_count(d_in,
                                         hoft_lib.num_reflections(acfg))

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        return {"hh_v": _hoft_vdef_cls()(d_in,
                                         hoft_lib.num_reflections(acfg))}

    def apply(self, x, w, adapter, acfg):
        return hoft_lib.hoft_linear(x, adapter, acfg, w)

    def fusion_mode(self, acfg, qcfg, qstate_keys=()) -> str:
        # the HOFT kernel reflects over a DENSE weight tile: quantized
        # bases are dequantized first (no in-kernel dequant variant yet),
        # so the mode does not depend on the quant state.
        return "hoft_fused" if acfg.fuse_linear else "unfused"

    def merge(self, w, adapter, acfg):
        return hoft_lib.hoft_merge(w, adapter, acfg)
