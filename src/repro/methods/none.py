"""The no-adapter passthrough, registered like any other method so the
framework never special-cases ``kind == "none"``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.methods.base import AdapterMethod, register


@register
class NoneMethod(AdapterMethod):
    kind = "none"
    has_params = False
    supports_merge = True

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        return None

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return 0

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        return None

    def apply(self, x, w, adapter, acfg):
        return x @ w

    def merge(self, w, adapter, acfg):
        return w
