"""OFTv2 (the paper's input-centric method, + QOFT over NF4 bases) and the
OFTv1 weight-centric baseline, as registered ``AdapterMethod``s.

Every OFT-specific branch the framework used to take on ``acfg.kind``
lives here now: the fused-kernel dispatch (``fusion_mode`` / ``forward``),
the PR-2 once-per-step rotation hoisting capability, and the PR-3
multi-tenant stack/route hooks.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.core import oft as oft_lib
from repro.core import skew
from repro.methods.base import AdapterMethod, register


class _OFTBase(AdapterMethod):
    """Shared packed-skew parameterization (v1/v2 differ in dataflow, not
    params -- same math, tests assert it)."""

    stochastic_init = False   # zero-init => R = I => starts at pretrained

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        # key accepted (uniform signature) and unused: deterministic init
        return oft_lib.oft_init(d_in, acfg.block_size, dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return oft_lib.oft_param_count(d_in, acfg.block_size)

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        from repro.models.spec import ParamDef
        b = acfg.block_size
        r = d_in // b
        # OFT block sharding: when the host linear's input features are
        # model-sharded (down/o projections under TP) and the shard boundary
        # is block-aligned, the block dim gets the 'oft_block_sharded'
        # logical axis so the transform stays collective-free (DESIGN.md §3).
        sharded_input = name in ("o", "down", "fc2", "out_proj")
        aligned = (model_axis_size > 1 and r % model_axis_size == 0
                   and (d_in // model_axis_size) % b == 0)
        block_axis = "oft_block_sharded" if (sharded_input and aligned) \
            else "oft_block"
        return {"q_packed": ParamDef((r, skew.pack_dim(b)),
                                     (block_axis, None), "zeros")}

    def merge(self, w, adapter, acfg):
        return oft_lib.oft_merge(w, adapter, acfg)


@register
class OFTv2Method(_OFTBase):
    """Input-centric OFT: y = (x @ R_bd) @ W -- activations only, the
    paper's entire scalability claim.  QOFT = the same over an NF4 base,
    dequantized inside the fused kernel."""

    kind = "oftv2"
    supports_fused_forward = True
    supports_fused_vjp = True          # oftv2_linear_bwd / qoft_linear_bwd
    supports_hoisted_rotations = True  # core/rotations once-per-step build
    supports_multi_tenant = True       # r_stack pooling + per-row routing

    def apply(self, x, w, adapter, acfg):
        return oft_lib.oftv2_linear(x, adapter, acfg, w)

    def fusion_mode(self, acfg, qcfg, qstate_keys=()) -> str:
        """'qoft_fused' (NF4 dequant + rotate + matmul, one kernel),
        'oftv2_fused' (rotate + matmul, one kernel), or 'unfused'.

        The NF4 predicate is explicit: the QOFT kernel is picked only when
        the quant state actually CARRIES packed codes.  A genuinely empty
        (or raw-``w``) qstate under an nf4 QuantConfig -- unquantizable
        layers, callers probing a config -- takes the dense fused path."""
        if not acfg.fuse_linear:
            return "unfused"
        if qcfg.kind == "nf4" and "nf4_codes" in qstate_keys:
            return "qoft_fused"
        return "oftv2_fused"

    def forward(self, x, qstate, adapter, acfg, qcfg):
        if self.fusion_mode(acfg, qcfg, qstate.keys()) == "qoft_fused":
            from repro.kernels import ops as kops
            from repro.quant import nf4
            # hoisted per-step rotations when present (core/rotations.py),
            # built on the spot otherwise
            r_blocks = oft_lib.get_r(adapter, acfg)
            return kops.qoft_linear_fused(x, r_blocks, qstate["nf4_codes"],
                                          nf4.absmax_fp32(qstate, qcfg),
                                          qcfg.block_size)
        # dense path: apply() routes through oftv2_linear, which itself
        # takes the fused rotate+matmul kernel under acfg.fuse_linear
        from repro.quant.common import dequantize_linear
        return self.apply(x, dequantize_linear(qstate, qcfg, x.dtype),
                          adapter, acfg)

    # ---------------------------------------------- multi-tenant serving --
    def stack_for_serving(self, trees: List[dict], acfg) -> dict:
        """N adapter trees -> pooled tree with per-layer ``r_stack``
        (A, blocks, b, b): stack every ``q_packed`` leaf along a new
        adapter axis, build EVERY rotation of every adapter in ONE
        Cayley--Neumann call (the PR-2 hoisted path), and rename the
        result to the explicit multi-adapter marker."""
        from repro.core import rotations as rot_lib
        stacked = _stack_oft_leaves(trees)
        augmented = rot_lib.with_rotations(stacked, acfg)
        return _to_r_stack(augmented)

    def route_multi(self, x, qstate, adapter, adapter_id, acfg, qcfg):
        from repro.kernels import ops as kops
        mode = self.fusion_mode(acfg, qcfg, qstate.keys())
        if mode == "unfused":
            raise ValueError(
                "multi-adapter serving requires the fused OFTv2 path "
                "(AdapterConfig(kind='oftv2', fuse_linear=True))")
        if mode == "qoft_fused":
            from repro.quant import nf4
            return kops.qoft_linear_multi(x, adapter["r_stack"], adapter_id,
                                          qstate["nf4_codes"],
                                          nf4.absmax_fp32(qstate, qcfg),
                                          qcfg.block_size)
        from repro.quant.common import dequantize_linear
        w = dequantize_linear(qstate, qcfg, x.dtype)
        return kops.oftv2_linear_multi(x, adapter["r_stack"], adapter_id, w)


@register
class OFTv1Method(_OFTBase):
    """Weight-centric baseline: materializes (and backprops through) the
    transformed d_in x d_out weight every call -- the paper's bottleneck.
    No fused kernels, no hoisting (it rebuilds R inside the weight
    transform), no multi-tenant serving."""

    kind = "oftv1"

    def apply(self, x, w, adapter, acfg):
        return x @ oft_lib.oftv1_transform_weight(w, adapter, acfg)


# ---------------------------------------------------------------------------
# pooled-tree helpers (moved verbatim from serving/pool.py)
# ---------------------------------------------------------------------------
def _stack_oft_leaves(trees: List[dict]):
    """Mirror the adapter-tree structure; stack each ``q_packed`` leaf along
    a new adapter axis inserted just before the block dim -- AFTER any scan
    lead dims, so the layer scan still slices layers on axis 0 and each
    scanned layer sees (A, blocks, pack_dim)."""
    head = trees[0]
    if isinstance(head, dict):
        if "q_packed" in head:
            qs = [t["q_packed"] for t in trees]
            return {"q_packed": jnp.stack(qs, axis=qs[0].ndim - 2)}
        return {k: _stack_oft_leaves([t[k] for t in trees]) for k in head}
    raise ValueError(f"unexpected adapter-tree node: {type(head)!r}")


def _to_r_stack(tree):
    """Rename the hoisted ``r_blocks`` entries (built by with_rotations over
    the stacked tree) to ``r_stack`` -- the explicit multi-adapter marker
    ``adapted_linear`` dispatches on, so a pooled tree can never be
    mistaken for single-adapter hoisted params."""
    if isinstance(tree, dict):
        return {("r_stack" if k == "r_blocks" else k): _to_r_stack(v)
                for k, v in tree.items()}
    return tree
