"""OFTv2 (the paper's input-centric method, + QOFT over NF4 bases) and the
OFTv1 weight-centric baseline, as registered ``AdapterMethod``s.

Every OFT-specific branch the framework used to take on ``acfg.kind``
lives here now: the fused-kernel dispatch (``fusion_mode`` / ``forward``),
the PR-2 once-per-step rotation hoisting capability, the PR-3 multi-tenant
stack/route hooks, and the ISSUE-5 ``shards`` capability -- the mesh-native
execution of the fused kernels.

Why block-diagonal OFTv2 shards for free: each b x b rotation block touches
only its own b input features, so the rotation tensor partitions along the
block dim EXACTLY like the weight partitions along its in-feature dim (and
the NF4 codes/absmax along theirs, quant/nf4.py layout).  A K-sharded
linear (o/down under TP) therefore runs ``(x_local @ R_local) @ W_local``
per shard with ONE psum on the partial output -- no resharding of W, codes,
or rotations, ever.  Butterfly-structured OFT (BOFT) mixes features across
blocks and would need an all-to-all here; that is precisely what this
method never does (jaxpr-asserted in tests/test_sharded_fused.py).
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import oft as oft_lib
from repro.core import skew
from repro.methods.base import AdapterMethod, register

# Linears whose INPUT features are model-sharded under the baseline/fused_tp
# TP rules -- their OFT blocks carry the 'oft_block_sharded' logical axis
# (param_defs below) and their rotations shard over `model` with the weight.
SHARDED_INPUT_LINEARS = ("o", "down", "fc2", "out_proj")


class _OFTBase(AdapterMethod):
    """Shared packed-skew parameterization (v1/v2 differ in dataflow, not
    params -- same math, tests assert it)."""

    stochastic_init = False   # zero-init => R = I => starts at pretrained

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        # key accepted (uniform signature) and unused: deterministic init
        return oft_lib.oft_init(d_in, acfg.block_size, dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return oft_lib.oft_param_count(d_in, acfg.block_size)

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        from repro.models.spec import ParamDef
        b = acfg.block_size
        r = d_in // b
        # OFT block sharding: when the host linear's input features are
        # model-sharded (down/o projections under TP) and the shard boundary
        # is block-aligned, the block dim gets the 'oft_block_sharded'
        # logical axis so the transform stays collective-free (DESIGN.md §3).
        sharded_input = name in SHARDED_INPUT_LINEARS
        aligned = (model_axis_size > 1 and r % model_axis_size == 0
                   and (d_in // model_axis_size) % b == 0)
        block_axis = "oft_block_sharded" if (sharded_input and aligned) \
            else "oft_block"
        return {"q_packed": ParamDef((r, skew.pack_dim(b)),
                                     (block_axis, None), "zeros")}

    def merge(self, w, adapter, acfg):
        return oft_lib.oft_merge(w, adapter, acfg)


@register
class OFTv2Method(_OFTBase):
    """Input-centric OFT: y = (x @ R_bd) @ W -- activations only, the
    paper's entire scalability claim.  QOFT = the same over an NF4 base,
    dequantized inside the fused kernel."""

    kind = "oftv2"
    supports_fused_forward = True
    supports_fused_vjp = True          # oftv2_linear_bwd / qoft_linear_bwd
    supports_hoisted_rotations = True  # core/rotations once-per-step build
    supports_multi_tenant = True       # r_stack pooling + per-row routing
    supports_sharding = True           # mesh-native shard_map fused path
    # the K-sharded partial-y / dx / dR reductions; NO gathers -- kernels
    # consume local W / quant-state / rotation shards (DESIGN.md §3)
    shard_collectives = ("psum",)

    def apply(self, x, w, adapter, acfg):
        return oft_lib.oftv2_linear(x, adapter, acfg, w)

    def fusion_mode(self, acfg, qcfg, qstate_keys=()) -> str:
        """'qoft_fused' (NF4 dequant + rotate + matmul, one kernel),
        'oftv2_fused' (rotate + matmul, one kernel), or 'unfused'.

        The NF4 predicate is explicit: the QOFT kernel is picked only when
        the quant state actually CARRIES packed codes.  A genuinely empty
        (or raw-``w``) qstate under an nf4 QuantConfig -- unquantizable
        layers, callers probing a config -- takes the dense fused path."""
        if not acfg.fuse_linear:
            return "unfused"
        if qcfg.kind == "nf4" and "nf4_codes" in qstate_keys:
            return "qoft_fused"
        return "oftv2_fused"

    def forward(self, x, qstate, adapter, acfg, qcfg):
        if self.fusion_mode(acfg, qcfg, qstate.keys()) == "qoft_fused":
            from repro.kernels import ops as kops
            from repro.quant import nf4
            # hoisted per-step rotations when present (core/rotations.py),
            # built on the spot otherwise
            r_blocks = oft_lib.get_r(adapter, acfg)
            return kops.qoft_linear_fused(x, r_blocks, qstate["nf4_codes"],
                                          nf4.absmax_fp32(qstate, qcfg),
                                          qcfg.block_size)
        # dense path: apply() routes through oftv2_linear, which itself
        # takes the fused rotate+matmul kernel under acfg.fuse_linear
        from repro.quant.common import dequantize_linear
        return self.apply(x, dequantize_linear(qstate, qcfg, x.dtype),
                          adapter, acfg)

    # ---------------------------------------------- multi-tenant serving --
    def stack_for_serving(self, trees: List[dict], acfg) -> dict:
        """N adapter trees -> pooled tree with per-layer ``r_stack``
        (A, blocks, b, b): stack every ``q_packed`` leaf along a new
        adapter axis, build EVERY rotation of every adapter in ONE
        Cayley--Neumann call (the PR-2 hoisted path), and rename the
        result to the explicit multi-adapter marker."""
        from repro.core import rotations as rot_lib
        stacked = _stack_oft_leaves(trees)
        augmented = rot_lib.with_rotations(stacked, acfg)
        return _to_r_stack(augmented)

    def route_multi(self, x, qstate, adapter, adapter_id, acfg, qcfg,
                    shard=None):
        from repro.kernels import ops as kops
        mode = self.fusion_mode(acfg, qcfg, qstate.keys())
        if mode == "unfused":
            raise ValueError(
                "multi-adapter serving requires the fused OFTv2 path "
                "(AdapterConfig(kind='oftv2', fuse_linear=True))")
        if shard is not None:
            return self._route_multi_sharded(x, qstate, adapter, adapter_id,
                                             acfg, qcfg, shard, mode)
        if mode == "qoft_fused":
            from repro.quant import nf4
            return kops.qoft_linear_multi(x, adapter["r_stack"], adapter_id,
                                          qstate["nf4_codes"],
                                          nf4.absmax_fp32(qstate, qcfg),
                                          qcfg.block_size)
        from repro.quant.common import dequantize_linear
        w = dequantize_linear(qstate, qcfg, x.dtype)
        return kops.oftv2_linear_multi(x, adapter["r_stack"], adapter_id, w)

    def _route_multi_sharded(self, x, qstate, adapter, adapter_id, acfg,
                             qcfg, shard, mode):
        """Per-shard multi-adapter routing: the slot batch is data-sharded,
        ``r_stack`` is model-sharded on its block dim, and every shard holds
        ALL adapters' blocks for ITS block range -- per-row routing needs no
        collective; only a K-sharded linear psums its partial output."""
        r_stack = adapter["r_stack"]
        if isinstance(adapter_id, int):
            # all-rows-same-adapter fast path -> single-adapter sharded path
            return self.shard_forward(x, qstate,
                                      {"r_blocks": r_stack[adapter_id]},
                                      acfg, qcfg, shard)
        mesh = shard.mesh
        data = _fit_axis(mesh, shard.data, x.shape[0])
        ids = jnp.asarray(adapter_id, jnp.int32)
        if mode == "qoft_fused":
            from repro.quant import nf4
            codes = qstate["nf4_codes"]
            k_dim, n_dim = codes.shape[0] * 2, codes.shape[1]
            align = int(np.lcm(np.lcm(2, qcfg.block_size), acfg.block_size))
            k_ax = _fit_k(mesh, shard.k, k_dim, align)
            n_ax = _fit_axis(mesh, shard.n, n_dim)
            fn = _sharded_qoft_multi(mesh, data, k_ax, n_ax, x.ndim,
                                     qcfg.block_size)
            return fn(x, ids, r_stack, codes, nf4.absmax_fp32(qstate, qcfg))
        from repro.quant.common import dequantize_linear
        w = dequantize_linear(qstate, qcfg, x.dtype)
        k_ax = _fit_k(mesh, shard.k, w.shape[0], acfg.block_size)
        n_ax = _fit_axis(mesh, shard.n, w.shape[1])
        fn = _sharded_oftv2_multi(mesh, data, k_ax, n_ax, x.ndim)
        return fn(x, ids, r_stack, w)

    # ------------------------------------------- mesh-sharded execution --
    def check_sharding(self, name, d_in, d_out, acfg, qcfg, k_shards,
                       n_shards):
        b = acfg.block_size
        blocks = d_in // b
        if k_shards > 1:
            if blocks % k_shards:
                raise ValueError(
                    f"{name}: OFTv2 blocks must divide evenly across the "
                    f"model axis: {blocks} blocks (d_in={d_in}, "
                    f"block_size={b}) over {k_shards} shards")
            local = d_in // k_shards
            quantized = (qcfg.kind == "nf4" and d_in % 2 == 0
                         and d_in % qcfg.block_size == 0)
            if quantized:
                align = int(np.lcm(2, qcfg.block_size))
                if local % align:
                    raise ValueError(
                        f"{name}: NF4 code/absmax tiles must divide evenly "
                        f"across the model axis: local in-features {local} "
                        f"not a multiple of {align}")
        if n_shards > 1 and d_out % n_shards:
            raise ValueError(
                f"{name}: out-features {d_out} not divisible by the "
                f"{n_shards}-way model axis")

    def shard_forward(self, x, qstate, adapter, acfg, qcfg, shard,
                      adapter_id=None):
        mode = self.fusion_mode(acfg, qcfg, qstate.keys())
        if mode == "unfused":
            # jnp path: GSPMD partitions plain einsums/matmuls fine
            return self.forward(x, qstate, adapter, acfg, qcfg)
        r_blocks = oft_lib.get_r(adapter, acfg)
        mesh = shard.mesh
        data = _fit_axis(mesh, shard.data, x.shape[0])
        if mode == "qoft_fused":
            from repro.quant import nf4
            codes = qstate["nf4_codes"]
            k_dim, n_dim = codes.shape[0] * 2, codes.shape[1]
            align = int(np.lcm(np.lcm(2, qcfg.block_size), acfg.block_size))
            k_ax = _fit_k(mesh, shard.k, k_dim, align)
            n_ax = _fit_axis(mesh, shard.n, n_dim)
            fn = _sharded_qoft_fused(mesh, data, k_ax, n_ax, x.ndim,
                                     qcfg.block_size)
            return fn(x, r_blocks, codes, nf4.absmax_fp32(qstate, qcfg))
        from repro.quant.common import dequantize_linear
        w = dequantize_linear(qstate, qcfg, x.dtype)
        k_ax = _fit_k(mesh, shard.k, w.shape[0], acfg.block_size)
        n_ax = _fit_axis(mesh, shard.n, w.shape[1])
        fn = _sharded_oftv2_fused(mesh, data, k_ax, n_ax, x.ndim)
        return fn(x, r_blocks, w)

    def shard_rotations(self, name, r, shard):
        """Constrain a hoisted rotation leaf to its TP layout: the block dim
        (axis -3 of ``(..., blocks, b, b)``) shards over `model` exactly for
        the linears whose input features are model-sharded."""
        if name not in SHARDED_INPUT_LINEARS:
            return r
        k_ax = shard.linear(name).k
        if k_ax is None:
            return r
        from repro.distributed.sharding import axis_size
        if r.shape[-3] % axis_size(shard.mesh, k_ax):
            return r
        spec = P(*([None] * (r.ndim - 3)), k_ax, None, None)
        return jax.lax.with_sharding_constraint(
            r, NamedSharding(shard.mesh, spec))

    def shard_specs(self, tree, shard):
        """PartitionSpec tree for an OFT adapter tree -- single, hoisted
        (``r_blocks``), or pooled (``r_stack``): the block dim shards over
        `model` for model-sharded-input linears, everything else replicates
        (adapter params are tiny; only the block structure matters)."""
        from repro.distributed.sharding import axis_size

        def leaf_spec(key, leaf, k_ax):
            blocks_axis = leaf.ndim - (2 if key == "q_packed" else 3)
            ax = k_ax
            if ax is not None and (
                    blocks_axis < 0
                    or leaf.shape[blocks_axis] % axis_size(shard.mesh, ax)):
                ax = None
            spec = [None] * leaf.ndim
            if 0 <= blocks_axis < leaf.ndim:
                spec[blocks_axis] = ax
            return P(*spec)

        def walk(node, name):
            if not isinstance(node, dict):
                return None
            if any(k in node for k in ("q_packed", "r_blocks", "r_stack")):
                k_ax = shard.linear(name).k \
                    if name in SHARDED_INPUT_LINEARS else None
                return {k: leaf_spec(k, v, k_ax) for k, v in node.items()}
            return {k: walk(v, k) for k, v in node.items()}

        return walk(tree, "")


@register
class OFTv1Method(_OFTBase):
    """Weight-centric baseline: materializes (and backprops through) the
    transformed d_in x d_out weight every call -- the paper's bottleneck.
    No fused kernels, no hoisting (it rebuilds R inside the weight
    transform), no multi-tenant serving."""

    kind = "oftv1"

    def apply(self, x, w, adapter, acfg):
        return x @ oft_lib.oftv1_transform_weight(w, adapter, acfg)


# ---------------------------------------------------------------------------
# mesh-sharded fused linears (the `shards` capability, ISSUE-5)
#
# Each factory returns one function that runs the corresponding Pallas
# kernel per-shard inside shard_map.  The factories are lru_cached on the
# (mesh, resolved axes, rank, ...) key so repeated traces -- every adapted
# linear of every scanned layer -- reuse ONE callable and jax's tracing
# caches see a stable identity.
#
# Collective budget (the whole point of input-centric block-diagonal OFT):
#   K-sharded linear (o/down):  fwd  = 1 psum of the partial y
#                               bwd  = 0 model psums (dx, dR born local)
#   N-sharded linear (q/up/..): fwd  = 0 collectives
#                               bwd  = 1 psum each for dx and dR
#   token-sharded dR           : 1 psum over the data axes (tiny: (r, b, b))
# Never: an all-gather of W / NF4 codes / rotation blocks, or any
# all-to-all (tests/test_sharded_fused.py asserts this on the jaxpr).
# ---------------------------------------------------------------------------
def _fit_axis(mesh, ax, dim: int):
    """ax if the shared drop-don't-fail policy
    (distributed.sharding.axis_fits) lets it shard dim, else None --
    resolved statically here so the shard_map specs are exact."""
    from repro.distributed.sharding import axis_fits
    return ax if axis_fits(mesh, ax, dim) else None


def _fit_k(mesh, ax, k_dim: int, align: int):
    """The in-feature axis additionally needs every structural tile (OFT
    block, NF4 code pair, absmax block) to land whole on one shard."""
    from repro.distributed.sharding import axis_fits, axis_size
    if not axis_fits(mesh, ax, k_dim):
        return None
    return ax if (k_dim // axis_size(mesh, ax)) % align == 0 else None


def _zeros_codes(codes):
    # frozen quantized state: int operands take a float0 cotangent
    return np.zeros(codes.shape, dtype=jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _sharded_oftv2_fused(mesh, data, k_ax, n_ax, nd: int):
    """(x, r_blocks, w) -> y with the fused rotate+matmul kernel running on
    local shards; differentiable (frozen W) via per-shard bwd kernels."""
    from repro.kernels import ops as kops
    mid = (None,) * (nd - 2)
    xs, rs = P(data, *mid, k_ax), P(k_ax, None, None)
    ws, ys = P(k_ax, n_ax), P(data, *mid, n_ax)

    def fwd_body(x, r, w):
        y = kops._oftv2_fused_raw(x, r, w)
        return jax.lax.psum(y, k_ax) if k_ax is not None else y

    fwd = shard_map(fwd_body, mesh=mesh, in_specs=(xs, rs, ws),
                    out_specs=ys, check_rep=False)

    def bwd_body(g, x, r, w):
        dx, dr = kops._oftv2_bwd_raw(g, x, r, w)
        if n_ax is not None:
            dx = jax.lax.psum(dx, n_ax)
            dr = jax.lax.psum(dr, n_ax)
        if data is not None:
            dr = jax.lax.psum(dr, data)
        return dx, dr

    bwd = shard_map(bwd_body, mesh=mesh, in_specs=(ys, xs, rs, ws),
                    out_specs=(xs, rs), check_rep=False)

    @jax.custom_vjp
    def fused(x, r, w):
        return fwd(x, r, w)

    def fused_fwd(x, r, w):
        return fwd(x, r, w), (x, r, w)

    def fused_bwd(res, g):
        x, r, w = res
        dx, dr = bwd(g, x, r, w)
        return dx, dr, jnp.zeros_like(w)   # frozen base

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


@functools.lru_cache(maxsize=None)
def _sharded_qoft_fused(mesh, data, k_ax, n_ax, nd: int, block_size: int):
    """QOFT variant: NF4 codes/absmax shard exactly like the weight and are
    dequantized tile-by-tile inside the local kernel -- a dense W never
    exists anywhere, on any shard, in either direction."""
    from repro.kernels import ops as kops
    mid = (None,) * (nd - 2)
    xs, rs = P(data, *mid, k_ax), P(k_ax, None, None)
    cs, as_ = P(k_ax, n_ax), P(k_ax, n_ax)
    ys = P(data, *mid, n_ax)

    def fwd_body(x, r, codes, absmax):
        y = kops._qoft_fused_raw(x, r, codes, absmax, block_size)
        return jax.lax.psum(y, k_ax) if k_ax is not None else y

    fwd = shard_map(fwd_body, mesh=mesh, in_specs=(xs, rs, cs, as_),
                    out_specs=ys, check_rep=False)

    def bwd_body(g, x, r, codes, absmax):
        dx, dr = kops._qoft_bwd_raw(g, x, r, codes, absmax, block_size)
        if n_ax is not None:
            dx = jax.lax.psum(dx, n_ax)
            dr = jax.lax.psum(dr, n_ax)
        if data is not None:
            dr = jax.lax.psum(dr, data)
        return dx, dr

    bwd = shard_map(bwd_body, mesh=mesh, in_specs=(ys, xs, rs, cs, as_),
                    out_specs=(xs, rs), check_rep=False)

    @jax.custom_vjp
    def fused(x, r, codes, absmax):
        return fwd(x, r, codes, absmax)

    def fused_fwd(x, r, codes, absmax):
        return fwd(x, r, codes, absmax), (x, r, codes, absmax)

    def fused_bwd(res, g):
        x, r, codes, absmax = res
        dx, dr = bwd(g, x, r, codes, absmax)
        return dx, dr, _zeros_codes(codes), jnp.zeros_like(absmax)

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


@functools.lru_cache(maxsize=None)
def _sharded_oftv2_multi(mesh, data, k_ax, n_ax, nd: int):
    """Multi-adapter serving kernel per-shard: slot rows data-sharded, the
    (A, blocks, b, b) r_stack model-sharded on blocks.  Inference-only."""
    from repro.kernels import ops as kops
    mid = (None,) * (nd - 2)
    specs = (P(data, *mid, k_ax), P(data), P(None, k_ax, None, None),
             P(k_ax, n_ax))

    def body(x, ids, r_stack, w):
        y = kops.oftv2_linear_multi(x, r_stack, ids, w)
        return jax.lax.psum(y, k_ax) if k_ax is not None else y

    return shard_map(body, mesh=mesh, in_specs=specs,
                     out_specs=P(data, *mid, n_ax), check_rep=False)


@functools.lru_cache(maxsize=None)
def _sharded_qoft_multi(mesh, data, k_ax, n_ax, nd: int, block_size: int):
    from repro.kernels import ops as kops
    mid = (None,) * (nd - 2)
    specs = (P(data, *mid, k_ax), P(data), P(None, k_ax, None, None),
             P(k_ax, n_ax), P(k_ax, n_ax))

    def body(x, ids, r_stack, codes, absmax):
        y = kops.qoft_linear_multi(x, r_stack, ids, codes, absmax,
                                   block_size)
        return jax.lax.psum(y, k_ax) if k_ax is not None else y

    return shard_map(body, mesh=mesh, in_specs=specs,
                     out_specs=P(data, *mid, n_ax), check_rep=False)


# ---------------------------------------------------------------------------
# pooled-tree helpers (moved verbatim from serving/pool.py)
# ---------------------------------------------------------------------------
def _stack_oft_leaves(trees: List[dict]):
    """Mirror the adapter-tree structure; stack each ``q_packed`` leaf along
    a new adapter axis inserted just before the block dim -- AFTER any scan
    lead dims, so the layer scan still slices layers on axis 0 and each
    scanned layer sees (A, blocks, pack_dim)."""
    head = trees[0]
    if isinstance(head, dict):
        if "q_packed" in head:
            qs = [t["q_packed"] for t in trees]
            return {"q_packed": jnp.stack(qs, axis=qs[0].ndim - 2)}
        return {k: _stack_oft_leaves([t[k] for t in trees]) for k in head}
    raise ValueError(f"unexpected adapter-tree node: {type(head)!r}")


def _to_r_stack(tree):
    """Rename the hoisted ``r_blocks`` entries (built by with_rotations over
    the stacked tree) to ``r_stack`` -- the explicit multi-adapter marker
    ``adapted_linear`` dispatches on, so a pooled tree can never be
    mistaken for single-adapter hoisted params."""
    if isinstance(tree, dict):
        return {("r_stack" if k == "r_blocks" else k): _to_r_stack(v)
                for k, v in tree.items()}
    return tree
