"""The ``AdapterMethod`` protocol + registry: the single place adapter-kind
dispatch is allowed to live.

Everything the framework needs from an adapter method is a hook on this
class -- init / param_count / param_defs (model building), forward / apply
(the adapted linear, fused or not), merge + requant_report (deployment),
and the capability flags that gate the PR-2 rotation hoisting and the PR-3
multi-tenant serving paths.  ``repro.core.adapter``, ``repro.models.
linears``, ``repro.serving.pool`` and the launch entrypoints are pure
registry queries; a new method (BOFT, Givens, principal-subspace, ...) is
one module calling ``register`` -- no framework surgery.

Capabilities a method does not implement fail LOUDLY: the base hooks raise
``NotImplementedError`` naming the method and the missing capability, so a
config that routes e.g. a non-stackable method into the adapter pool is a
registration-time error, not a silent fall-through.

CI enforces the monopoly: ``benchmarks/check_dispatch.py`` greps the source
tree and fails the build if ``acfg.kind == ...`` string dispatch reappears
outside ``src/repro/methods/``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import jax.numpy as jnp


class AdapterMethod:
    """One adapter method's full capability surface.

    Subclass, set ``kind``, implement the required hooks, override the
    optional ones the method actually supports (and flip the matching
    capability flag -- the flags drive the README method x capability
    matrix and the loud-failure diagnostics, so they must tell the truth;
    ``tests/test_methods_registry.py`` cross-checks them).
    """

    kind: str = ""

    # ---- capability flags (the README matrix is generated from these) ----
    has_params: bool = True          # False: the no-adapter passthrough
    stochastic_init: bool = False    # init consumes the PRNG key
    supports_fused_forward: bool = False   # fused_plan != 'unfused' possible
    supports_fused_vjp: bool = False       # the fused fwd's VJP is a kernel
    supports_hoisted_rotations: bool = False   # core/rotations once-per-step
    supports_multi_tenant: bool = False    # serving pool stack + routing
    supports_merge: bool = True
    supports_quantized_base: bool = True   # works over an NF4/AWQ/int8 base
    supports_sharding: bool = False        # mesh-native shard_map fused path

    #: Collective primitives the method's mesh-sharded fused path is
    #: allowed to emit (jaxpr-family names: "psum", "all_gather", ...).
    #: The repro.analysis collective-budget rules read this instead of
    #: hardcoding psum-only, so a method whose sharded algebra genuinely
    #: needs e.g. a butterfly exchange (BOFT) budgets it HERE -- in its
    #: registry entry -- and the CI gate follows.  Empty for methods
    #: without the ``shards`` capability.
    shard_collectives: Tuple[str, ...] = ()

    # ------------------------------------------------------ required hooks --
    def init(self, key, name: str, d_in: int, d_out: int, acfg,
             dtype=jnp.float32) -> dict:
        """Adapter params for one linear.  ``key`` is ALWAYS threaded --
        deterministic methods (OFT zero-init) simply ignore it, so
        stochastic inits (LoRA, HOFT) share one signature and seed
        sensitivity is testable uniformly."""
        raise NotImplementedError(self._msg("init"))

    def param_count(self, name: str, d_in: int, d_out: int, acfg) -> int:
        raise NotImplementedError(self._msg("param_count"))

    def param_defs(self, name: str, d_in: int, d_out: int, acfg,
                   model_axis_size: int = 1):
        """Trainable ``ParamDef``/``CompositeDef`` tree for one linear
        (model-building path; must init-agree with ``init``)."""
        raise NotImplementedError(self._msg("param_defs"))

    def apply(self, x: jnp.ndarray, w: jnp.ndarray, adapter: dict,
              acfg) -> jnp.ndarray:
        """Adapted forward of one linear given a DENSE weight: the
        reference path (a method may still route through its fused kernel
        internally, e.g. ``acfg.fuse_linear``)."""
        raise NotImplementedError(self._msg("apply"))

    # ------------------------------------------------------ optional hooks --
    def forward(self, x: jnp.ndarray, qstate: dict, adapter: dict, acfg,
                qcfg) -> jnp.ndarray:
        """Full adapted forward given the (possibly quantized) frozen
        state.  Default: dequantize, then ``apply``.  Methods with a
        quantization-aware fused kernel (QOFT) override this so the dense
        weight never materializes."""
        from repro.quant.common import dequantize_linear
        return self.apply(x, dequantize_linear(qstate, qcfg, x.dtype),
                          adapter, acfg)

    def fusion_mode(self, acfg, qcfg, qstate_keys: Iterable[str] = ()) -> str:
        """Which fused forward an adapted linear takes under these configs
        ('unfused' unless the method declares fused kernels).  Drives
        ``models.linears.linear_fusion_mode`` and the CI fusion-plan gate."""
        return "unfused"

    def merge(self, w: jnp.ndarray, adapter: dict, acfg) -> jnp.ndarray:
        """Fold the adapter into a dequantized weight for deployment."""
        raise NotImplementedError(self._msg("merge"))

    def requant_report(self, w: jnp.ndarray, adapter: dict, acfg,
                       qcfg) -> Dict[str, float]:
        """Merge -> NF4-requantize -> measure (paper §4).  Default works
        for any method with ``merge``."""
        if not self.supports_merge:
            raise NotImplementedError(self._msg("requant_report (no merge)"))
        from repro.core import merging
        from repro.quant import nf4
        merged = self.merge(w, adapter, acfg)
        q = nf4.quantize(merged, qcfg)
        back = nf4.dequantize(q, qcfg, merged.dtype)
        return {
            "column_norm_drift": float(merging.column_norm_drift(w, merged)),
            "dynamic_range_shift": float(
                merging.dynamic_range_shift(w, merged)),
            "requant_max_err": float(jnp.max(jnp.abs(merged - back))),
            "requant_rel_fro": float(jnp.linalg.norm(merged - back)
                                     / jnp.linalg.norm(merged)),
        }

    # ---- multi-tenant serving (PR 3): both or neither -------------------
    def stack_for_serving(self, trees: List[dict], acfg) -> dict:
        """N per-tenant adapter trees -> ONE pooled tree the model can
        serve with per-row routing (OFT: per-layer ``r_stack``)."""
        raise NotImplementedError(self._msg("multi-tenant stacking"))

    def route_multi(self, x: jnp.ndarray, qstate: dict, adapter: dict,
                    adapter_id, acfg, qcfg, shard=None) -> jnp.ndarray:
        """Adapted forward over a pooled tree, each batch row routed to its
        adapter by ``adapter_id``.  ``shard`` (a ``LinearShard``, on-mesh
        only) asks for the per-shard ``shard_map`` kernel path."""
        raise NotImplementedError(self._msg("multi-tenant routing"))

    # ---- mesh-sharded execution (ISSUE-5): the `shards` capability ------
    def check_sharding(self, name: str, d_in: int, d_out: int, acfg, qcfg,
                       k_shards: int, n_shards: int) -> None:
        """Validate ONE adapted linear's shapes against the mesh factors
        that would shard its in-features (``k_shards``) and out-features
        (``n_shards``).  Called at config time by
        ``repro.distributed.sharding.make_shard_context`` -- raise
        ValueError for shapes that cannot shard (e.g. OFT blocks not
        dividing the model axis)."""
        raise NotImplementedError(self._msg("mesh-sharded execution"))

    def shard_forward(self, x: jnp.ndarray, qstate: dict, adapter: dict,
                      acfg, qcfg, shard, adapter_id=None) -> jnp.ndarray:
        """Adapted forward under a mesh (``shard``: a ``LinearShard``): the
        method runs its fused kernels per-shard inside ``shard_map`` so
        dense W / quant state / rotation blocks are consumed locally with
        no resharding."""
        raise NotImplementedError(self._msg("mesh-sharded execution"))

    def shard_rotations(self, name: str, r: jnp.ndarray, shard):
        """Sharding constraint for a hoisted rotation tensor built for the
        linear ``name`` (``shard``: a ``MeshContext``).  Default identity:
        methods without block rotations have nothing to constrain."""
        return r

    def shard_specs(self, tree: dict, shard):
        """PartitionSpec tree for an adapter tree (single, hoisted, or
        pooled ``r_stack``) under ``shard`` (a ``MeshContext``) -- used to
        place serving pools and checkpointed adapters on the mesh."""
        raise NotImplementedError(self._msg("mesh-sharded execution"))

    # --------------------------------------------------------------- misc --
    def _msg(self, capability: str) -> str:
        return (f"adapter method {self.kind!r} does not support "
                f"{capability} (methods that do: see "
                f"repro.methods.capability_matrix())")

    def __repr__(self) -> str:
        return f"<AdapterMethod {self.kind!r}>"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, AdapterMethod] = {}


def register(method_cls):
    """Register an ``AdapterMethod`` subclass (usable as a class decorator).
    Re-registering a kind is an error -- shadowing a built-in silently is
    exactly the implicit dispatch this package exists to kill."""
    method = method_cls() if isinstance(method_cls, type) else method_cls
    if not method.kind:
        raise ValueError(f"{method!r} has no kind")
    if method.kind in _REGISTRY:
        raise ValueError(f"adapter method {method.kind!r} already registered")
    _REGISTRY[method.kind] = method
    return method_cls


def get(kind: str) -> AdapterMethod:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown adapter kind {kind!r}; registered methods: "
            f"{', '.join(available())}") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def supporting(flag: str) -> Tuple[str, ...]:
    """Kinds whose registry entry sets the given capability flag (e.g.
    ``supporting("supports_multi_tenant")``) -- for diagnostics that name
    the methods that DO have what the failing one lacks."""
    return tuple(kind for kind, m in sorted(_REGISTRY.items())
                 if getattr(m, flag))


# ---------------------------------------------------------------------------
# Capability matrix (README generates from this -- it cannot rot)
# ---------------------------------------------------------------------------
_MATRIX_COLUMNS: Tuple[Tuple[str, str], ...] = (
    ("fused fwd", "supports_fused_forward"),
    ("fused bwd", "supports_fused_vjp"),
    ("hoisted R", "supports_hoisted_rotations"),
    ("multi-tenant", "supports_multi_tenant"),
    ("shards", "supports_sharding"),
    ("merge", "supports_merge"),
    ("quantized base", "supports_quantized_base"),
)


def capability_matrix() -> Dict[str, Dict[str, bool]]:
    """{kind: {capability: bool}} for every registered method with params."""
    return {kind: {col: bool(getattr(m, attr))
                   for col, attr in _MATRIX_COLUMNS}
            for kind, m in sorted(_REGISTRY.items()) if m.has_params}


def capability_matrix_md() -> str:
    """The method x capability matrix as a markdown table.  README embeds
    this verbatim and ``tests/test_methods_registry.py`` asserts the embed
    matches, so the docs are generated, not hand-maintained."""
    cols = [c for c, _ in _MATRIX_COLUMNS]
    lines = ["| method | " + " | ".join(cols) + " |",
             "|" + "---|" * (len(cols) + 1)]
    for kind, caps in capability_matrix().items():
        cells = ["✓" if caps[c] else "·" for c in cols]
        lines.append(f"| `{kind}` | " + " | ".join(cells) + " |")
    return "\n".join(lines)
