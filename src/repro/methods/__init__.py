"""Pluggable adapter-method registry (``repro.methods``).

The paper's reformulation is method-shaped: an adapter method is an
orthogonal (or low-rank) transform plus the capabilities the serving /
training system can exploit.  This package owns that shape --
``AdapterMethod`` is the protocol, ``register`` the entry point, and the
built-in methods (OFTv2/QOFT, OFTv1, LoRA, HOFT, BOFT, GOFT, none) are
ordinary registrants.  All adapter-kind dispatch in the framework is a query
against this registry; ``benchmarks/check_dispatch.py`` (CI-gated) fails
the build if ``acfg.kind == ...`` string dispatch reappears anywhere else
under ``src/repro``.

``python -m repro.methods`` prints the method x capability matrix
(the README embeds it; a test keeps the embed in sync).
"""
from repro.methods.base import (AdapterMethod, available, capability_matrix,
                                capability_matrix_md, get, register,
                                supporting)

# Built-in methods register themselves on import.
from repro.methods import none as _none      # noqa: F401,E402
from repro.methods import oft as _oft        # noqa: F401,E402
from repro.methods import lora as _lora      # noqa: F401,E402
from repro.methods import hoft as _hoft      # noqa: F401,E402
from repro.methods import boft as _boft      # noqa: F401,E402
from repro.methods import goft as _goft      # noqa: F401,E402

__all__ = ["AdapterMethod", "available", "capability_matrix",
           "capability_matrix_md", "get", "register", "supporting"]
