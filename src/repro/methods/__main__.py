"""Print the registry-generated method x capability matrix (the README
embeds this output; tests/test_methods_registry.py keeps it in sync):

    PYTHONPATH=src python -m repro.methods
"""
from repro.methods import capability_matrix_md

if __name__ == "__main__":
    print(capability_matrix_md())
