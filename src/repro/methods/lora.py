"""LoRA / QLoRA baseline as a registered ``AdapterMethod`` (parallel
low-rank update; the paper's main comparison)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.methods.base import AdapterMethod, register


@register
class LoRAMethod(AdapterMethod):
    kind = "lora"
    stochastic_init = True   # A ~ N(0, 1/r); B = 0

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        return lora_lib.lora_init(key, d_in, d_out, acfg.rank, dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return lora_lib.lora_param_count(d_in, d_out, acfg.rank)

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        from repro.models.spec import ParamDef
        return {
            "lora_a": ParamDef((d_in, acfg.rank), (None, "lora_rank"),
                               "normal", scale=1.0),
            "lora_b": ParamDef((acfg.rank, d_out), ("lora_rank", None),
                               "zeros"),
        }

    def apply(self, x, w, adapter, acfg):
        return x @ w + lora_lib.lora_delta(x, adapter, acfg)

    def merge(self, w, adapter, acfg):
        return lora_lib.lora_merge(w, adapter, acfg)
