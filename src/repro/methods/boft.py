"""BOFT (butterfly orthogonal finetuning) as a registered
``AdapterMethod`` -- the method that deliberately BREAKS the "rotations
shard like the weight, zero resharding" invariant.

Math in ``repro.core.boft``; fused forward kernel in
``repro.kernels.boft_linear_fused`` (multi-stage rotate-in-VMEM + matmul;
its VJP is the jnp reference, so ``supports_fused_vjp`` stays False).

Why BOFT cannot shard collective-free: the butterfly's whole point is
cross-block mixing, so a K-sharded linear (o/down under TP) cannot rotate
its local block range independently -- stage k >= 2 exchanges features
with blocks that live on OTHER shards.  The sharded algebra here is
gather -> rotate -> slice:

    fwd:  x_full  = all_gather(x_local)            [budgeted all_gather]
          xr_full = butterfly(x_full)              (rotate-only Pallas
                                                    kernel, all stages in
                                                    VMEM)
          y       = psum(xr_full[my K-slab] @ W_local)   [budgeted psum]
    bwd:  gW_full = all_gather(g @ W_local^T)      [budgeted all_gather]
          (dx_full, dRot) = VJP(butterfly)(gW_full) on re-gathered x
          dx      = dx_full[my K-slab]; dRot psum'd over data/n axes

Both directions are HAND-WRITTEN shard_map bodies under one custom_vjp:
letting jax transpose the forward's ``all_gather`` would emit a
``psum_scatter`` -- a collective family OUTSIDE this method's declared
budget -- so the backward re-gathers instead, keeping the emitted set
exactly ``shard_collectives = ("psum", "all_gather")``.  The
``repro.analysis`` collective-budget rules (jaxpr + compiled HLO) assert
the fused sharded train step against this declaration; remove
"all_gather" from it and both rules fail (tests/test_boft_goft.py proves
it).  The stage rotations themselves replicate: they are tiny
(s * K * b floats) and every shard needs ALL of them -- the exact
opposite of OFTv2's block-aligned sharding, which is the point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import boft as boft_lib
from repro.core import skew
from repro.methods.base import AdapterMethod, register
from repro.methods.oft import _fit_axis


@register
class BOFTMethod(AdapterMethod):
    kind = "boft"
    stochastic_init = False          # zero skew => exact identity at init
    supports_fused_forward = True    # boft_linear_fused (dense W)
    supports_fused_vjp = False       # backward = jnp reference VJP
    supports_hoisted_rotations = False
    supports_multi_tenant = False
    supports_sharding = True
    # the first non-psum budget: the butterfly exchange is an all_gather
    # of the K-sharded activations (fwd) and of gW (bwd) -- declared HERE
    # so the repro.analysis collective-budget rules allow exactly this
    # and nothing more (no all-to-all, no psum_scatter).
    shard_collectives = ("psum", "all_gather")

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        # key accepted (uniform signature) and unused: deterministic init
        return boft_lib.boft_init(d_in, acfg, dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return boft_lib.boft_param_count(d_in, acfg)

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        from repro.models.spec import ParamDef
        r = boft_lib.num_blocks(d_in, acfg)
        s = boft_lib.num_stages(d_in, acfg)
        # replicated on purpose: every shard needs every stage's blocks
        # (cross-block mixing), and the tensor is tiny -- see module doc.
        return {"boft_q": ParamDef((s, r, skew.pack_dim(acfg.block_size)),
                                   (None, None, None), "zeros")}

    def apply(self, x, w, adapter, acfg):
        return boft_lib.boft_linear(x, adapter, acfg, w)

    def fusion_mode(self, acfg, qcfg, qstate_keys=()) -> str:
        # the BOFT kernel rotates into a DENSE weight tile: quantized
        # bases are dequantized first (no in-kernel dequant variant yet)
        return "boft_fused" if acfg.fuse_linear else "unfused"

    def merge(self, w, adapter, acfg):
        return boft_lib.boft_merge(w, adapter, acfg)

    # ------------------------------------------- mesh-sharded execution --
    def check_sharding(self, name, d_in, d_out, acfg, qcfg, k_shards,
                       n_shards):
        # config-time validation first: stage/block bounds fail here, not
        # mid-trace (the uniform ISSUE-10 pattern)
        boft_lib.num_stages(d_in, acfg)
        if k_shards > 1 and d_in % k_shards:
            raise ValueError(
                f"{name}: BOFT in-features {d_in} not divisible by the "
                f"{k_shards}-way model axis (the gather-rotate-slice path "
                f"slices equal K-slabs)")
        if n_shards > 1 and d_out % n_shards:
            raise ValueError(
                f"{name}: out-features {d_out} not divisible by the "
                f"{n_shards}-way model axis")

    def shard_forward(self, x, qstate, adapter, acfg, qcfg, shard,
                      adapter_id=None):
        mode = self.fusion_mode(acfg, qcfg, qstate.keys())
        if mode == "unfused":
            # jnp path: GSPMD partitions plain einsums/matmuls fine
            return self.forward(x, qstate, adapter, acfg, qcfg)
        from repro.quant.common import dequantize_linear
        w = dequantize_linear(qstate, qcfg, x.dtype)
        rot = boft_lib.build_stage_rotations(adapter, acfg)
        mesh = shard.mesh
        data = _fit_axis(mesh, shard.data, x.shape[0])
        k_ax = _fit_axis(mesh, shard.k, w.shape[0])
        n_ax = _fit_axis(mesh, shard.n, w.shape[1])
        fn = _sharded_boft_fused(mesh, data, k_ax, n_ax, x.ndim)
        return fn(x, rot, w)

    def shard_specs(self, tree, shard):
        """BOFT adapter params replicate on the mesh (every shard needs
        every stage; the tensor is tiny), so every leaf's spec is empty."""
        if isinstance(tree, dict):
            return {k: self.shard_specs(v, shard) for k, v in tree.items()}
        return P()


# ---------------------------------------------------------------------------
# The mesh-sharded fused linear: gather -> rotate-in-VMEM -> slice -> matmul.
# lru_cached on the (mesh, resolved axes, rank) key like the OFTv2 factories
# so repeated traces reuse one callable.
# ---------------------------------------------------------------------------
def _sliced(full, ax_name, local_dim: int, axis: int):
    """This shard's slab of a gathered/full-width tensor."""
    start = jax.lax.axis_index(ax_name) * local_dim
    return jax.lax.dynamic_slice_in_dim(full, start, local_dim, axis=axis)


@functools.lru_cache(maxsize=None)
def _sharded_boft_fused(mesh, data, k_ax, n_ax, nd: int):
    """(x, rot_stages, w) -> y; frozen W; custom_vjp with hand-written
    shard_map bodies so the collective set is exactly the declared
    ("psum", "all_gather") budget in BOTH directions (module doc)."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    mid = (None,) * (nd - 2)
    xs = P(data, *mid, k_ax)
    rs = P(None, None, None, None)     # (s, r, b, b) replicated
    ws, ys = P(k_ax, n_ax), P(data, *mid, n_ax)
    f32 = jnp.float32

    def fwd_body(x, rot, w):
        if k_ax is None:
            # full-width x on every shard: the whole thing is ONE fused
            # kernel against the local (K, N_loc) weight slab
            return kops._boft_fused_raw(x, rot, w)
        k_loc = x.shape[-1]
        x_full = jax.lax.all_gather(x, k_ax, axis=nd - 1, tiled=True)
        xr = _sliced(kops.boft_rotate(x_full, rot), k_ax, k_loc, nd - 1)
        y = jnp.einsum("...k,kn->...n", xr.astype(f32), w.astype(f32))
        return jax.lax.psum(y, k_ax).astype(x.dtype)

    fwd = shard_map(fwd_body, mesh=mesh, in_specs=(xs, rs, ws),
                    out_specs=ys, check_rep=False)

    def bwd_body(g, x, rot, w):
        gw = jnp.einsum("...n,kn->...k", g.astype(f32), w.astype(f32))
        if k_ax is None:
            _, vjp = jax.vjp(kref.boft_apply_ref, x, rot)
            dx, drot = vjp(gw.astype(x.dtype))
            if n_ax is not None:
                dx = jax.lax.psum(dx, n_ax)
                drot = jax.lax.psum(drot, n_ax)
        else:
            k_loc = x.shape[-1]
            # re-gather instead of transposing the forward's gather: jax
            # would transpose all_gather into psum_scatter -- off-budget
            gw_full = jax.lax.all_gather(gw, k_ax, axis=nd - 1, tiled=True)
            x_full = jax.lax.all_gather(x, k_ax, axis=nd - 1, tiled=True)
            _, vjp = jax.vjp(kref.boft_apply_ref, x_full, rot)
            dx_full, drot = vjp(gw_full.astype(x.dtype))
            dx = _sliced(dx_full, k_ax, k_loc, nd - 1)
        if data is not None:
            drot = jax.lax.psum(drot, data)
        return dx, drot

    bwd = shard_map(bwd_body, mesh=mesh, in_specs=(ys, xs, rs, ws),
                    out_specs=(xs, rs), check_rep=False)

    @jax.custom_vjp
    def fused(x, rot, w):
        return fwd(x, rot, w)

    def fused_fwd(x, rot, w):
        return fwd(x, rot, w), (x, rot, w)

    def fused_bwd(res, g):
        x, rot, w = res
        dx, drot = bwd(g, x, rot, w)
        return dx, drot, jnp.zeros_like(w)   # frozen base

    fused.defvjp(fused_fwd, fused_bwd)
    return fused
