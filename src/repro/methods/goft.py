"""GOFT (Givens-rotation quasi-orthogonal finetuning) as a registered
``AdapterMethod`` -- the sparse limit of the structured-orthogonality
family.

Math in ``repro.core.goft``; fused forward kernel in
``repro.kernels.goft_linear_fused`` (all brick-wall passes on the
activation tile in VMEM, then the matmul; its VJP is the jnp reference,
so ``supports_fused_vjp`` stays False).  No hoisting (the trig-free
coefficient expansion is O(p d) -- cheaper than storing it), no
multi-tenant routing, no sharded path yet: Givens pairs straddle any
K-shard boundary (the odd passes wrap clear around the feature dim), so
a correct sharded GOFT needs the same gather-rotate-slice algebra as
BOFT -- left for when a workload wants it; until then the base hooks
raise loudly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import goft as goft_lib
from repro.methods.base import AdapterMethod, register


@register
class GOFTMethod(AdapterMethod):
    kind = "goft"
    stochastic_init = False          # zero thetas => exact identity at init
    supports_fused_forward = True    # goft_linear_fused (dense W)
    supports_fused_vjp = False       # backward = jnp reference VJP
    supports_hoisted_rotations = False
    supports_multi_tenant = False
    supports_sharding = False

    def init(self, key, name, d_in, d_out, acfg, dtype=jnp.float32):
        # key accepted (uniform signature) and unused: deterministic init
        return goft_lib.goft_init(d_in, acfg, dtype=dtype)

    def param_count(self, name, d_in, d_out, acfg) -> int:
        return goft_lib.goft_param_count(d_in, acfg)

    def param_defs(self, name, d_in, d_out, acfg, model_axis_size=1):
        from repro.models.spec import ParamDef
        p = goft_lib.num_passes(d_in, acfg)
        return {"thetas": ParamDef((p, d_in // 2), (None, None), "zeros")}

    def apply(self, x, w, adapter, acfg):
        return goft_lib.goft_linear(x, adapter, acfg, w)

    def fusion_mode(self, acfg, qcfg, qstate_keys=()) -> str:
        # the GOFT kernel rotates into a DENSE weight tile: quantized
        # bases are dequantized first (no in-kernel dequant variant yet)
        return "goft_fused" if acfg.fuse_linear else "unfused"

    def merge(self, w, adapter, acfg):
        return goft_lib.goft_merge(w, adapter, acfg)
