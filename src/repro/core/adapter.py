"""Uniform adapter API: every linear layer in the model zoo goes through
``adapted_linear``.  This is the single integration point of the paper's
technique with the framework -- OFTv2/QOFT (sequential, input-centric),
OFTv1 (sequential, weight-centric baseline), LoRA/QLoRA (parallel, low-rank
baseline), or no adapter.

Parameter layout contract (enforced by repro.train.state):
  base params  (frozen, possibly quantized)  live under  tree["base"]
  adapter params (trainable)                 live under  tree["adapter"]
so `jax.grad` over the adapter tree alone gives the PEFT memory story.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig, QuantConfig
from repro.core import lora as lora_lib
from repro.core import oft as oft_lib
from repro.quant.common import dequantize_linear


def wants_adapter(name: str, acfg: AdapterConfig) -> bool:
    return acfg.kind != "none" and name in acfg.targets


def adapter_init(key, name: str, d_in: int, d_out: int, acfg: AdapterConfig,
                 dtype=jnp.float32) -> Optional[dict]:
    """Adapter params for one linear (or None when not targeted)."""
    if not wants_adapter(name, acfg):
        return None
    if acfg.is_oft:
        return oft_lib.oft_init(d_in, acfg.block_size, dtype=dtype)
    if acfg.kind == "lora":
        return lora_lib.lora_init(key, d_in, d_out, acfg.rank, dtype=dtype)
    raise ValueError(f"unknown adapter kind {acfg.kind}")


def adapter_param_count(name: str, d_in: int, d_out: int,
                        acfg: AdapterConfig) -> int:
    if not wants_adapter(name, acfg):
        return 0
    if acfg.is_oft:
        return oft_lib.oft_param_count(d_in, acfg.block_size)
    return lora_lib.lora_param_count(d_in, d_out, acfg.rank)


def fusion_mode(acfg: AdapterConfig, qcfg: QuantConfig,
                qstate_keys=()) -> str:
    """Which forward an adapted linear will take: 'qoft_fused' (NF4 dequant +
    rotate + matmul, one kernel), 'oftv2_fused' (rotate + matmul, one
    kernel), or 'unfused'."""
    if acfg.kind != "oftv2" or not acfg.fuse_linear:
        return "unfused"
    if qcfg.kind == "nf4" and (not qstate_keys or "nf4_codes" in qstate_keys):
        return "qoft_fused"
    return "oftv2_fused"


def adapted_linear(x: jnp.ndarray, qstate: dict, adapter: Optional[dict],
                   acfg: AdapterConfig, qcfg: QuantConfig,
                   constrain=None, adapter_id=None) -> jnp.ndarray:
    """y = adapted forward of one frozen linear.

    OFTv2/QOFT path never touches the quant state before the matmul --
    quantization-agnostic by construction (paper §4, eq. 3).

    With acfg.fuse_linear, the OFTv2 forward is ONE Pallas kernel
    (rotate+matmul; plus in-kernel NF4 dequant for QOFT, so a dense W never
    exists in HBM). See repro.core.oft.oftv2_linear / repro.kernels.

    Multi-tenant serving (repro.serving): when the adapter leaf carries an
    ``r_stack`` -- the pool's per-layer (A, K//b, b, b) rotation stack --
    each batch row is routed to ITS adapter's blocks by ``adapter_id``
    ((B,) int32, threaded from the decode batch) inside the fused kernel.
    A Python-int adapter_id is the all-rows-same-adapter fast path and
    lowers to the single-adapter kernels.

    constrain (optional, on-mesh only): gather-codes optimization -- the
    ZeRO-3 all-gather is forced onto the uint8 quant state (replicate it,
    dequantize locally) instead of the dequantized bf16 weight, cutting
    weight-gather wire ~4x (EXPERIMENTS.md §Perf/llama3 it-4).
    """
    if (constrain is not None and qcfg.gather_codes and qcfg.enabled
            and "w" not in qstate):
        qstate = {k: constrain(v) for k, v in qstate.items()}
    if adapter is not None and "r_stack" in adapter:
        if adapter_id is None:
            raise ValueError(
                "pooled multi-adapter params (r_stack) need a per-row "
                "adapter_id -- pass batch['adapter_id'] (repro.serving)")
        from repro.kernels import ops as kops
        mode = fusion_mode(acfg, qcfg, qstate.keys())
        if mode == "unfused":
            raise ValueError(
                "multi-adapter serving requires the fused OFTv2 path "
                "(AdapterConfig(kind='oftv2', fuse_linear=True))")
        if mode == "qoft_fused":
            from repro.quant import nf4
            return kops.qoft_linear_multi(x, adapter["r_stack"], adapter_id,
                                          qstate["nf4_codes"],
                                          nf4.absmax_fp32(qstate, qcfg),
                                          qcfg.block_size)
        w = dequantize_linear(qstate, qcfg, x.dtype)
        return kops.oftv2_linear_multi(x, adapter["r_stack"], adapter_id, w)
    if (adapter is not None
            and fusion_mode(acfg, qcfg, qstate.keys()) == "qoft_fused"):
        from repro.kernels import ops as kops
        from repro.quant import nf4
        # hoisted per-step rotations when present (core/rotations.py),
        # built on the spot otherwise
        r_blocks = oft_lib.get_r(adapter, acfg)
        return kops.qoft_linear_fused(x, r_blocks, qstate["nf4_codes"],
                                      nf4.absmax_fp32(qstate, qcfg),
                                      qcfg.block_size)
    w = dequantize_linear(qstate, qcfg, x.dtype)
    if adapter is None or acfg.kind == "none":
        return x @ w
    if acfg.kind == "oftv2":
        return oft_lib.oftv2_linear(x, adapter, acfg, w)
    if acfg.kind == "oftv1":
        # Weight-centric baseline: materializes (and backprops through) the
        # transformed d_in x d_out weight every call -- the paper's bottleneck.
        wt = oft_lib.oftv1_transform_weight(w, adapter, acfg)
        return x @ wt
    if acfg.kind == "lora":
        return x @ w + lora_lib.lora_delta(x, adapter, acfg)
    raise ValueError(f"unknown adapter kind {acfg.kind}")


def merge_adapter(w: jnp.ndarray, adapter: Optional[dict],
                  acfg: AdapterConfig) -> jnp.ndarray:
    """Fold the adapter into a (dequantized) weight for deployment."""
    if adapter is None or acfg.kind == "none":
        return w
    if acfg.is_oft:
        return oft_lib.oft_merge(w, adapter, acfg)
    if acfg.kind == "lora":
        return lora_lib.lora_merge(w, adapter, acfg)
    raise ValueError(f"unknown adapter kind {acfg.kind}")
