"""Uniform adapter API: every linear layer in the model zoo goes through
``adapted_linear``.  This is the single integration point of the paper's
technique with the framework -- which technique is a pure registry lookup
(``repro.methods``): OFTv2/QOFT (sequential, input-centric), OFTv1
(sequential, weight-centric baseline), LoRA/QLoRA (parallel, low-rank
baseline), HOFT (Householder-product chain), or no adapter.  There is no
adapter-kind string dispatch here or anywhere else outside
``src/repro/methods/`` -- CI greps for it (benchmarks/check_dispatch.py).

Parameter layout contract (enforced by repro.train.state):
  base params  (frozen, possibly quantized)  live under  tree["base"]
  adapter params (trainable)                 live under  tree["adapter"]
so `jax.grad` over the adapter tree alone gives the PEFT memory story.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import methods
from repro.config.base import AdapterConfig, QuantConfig
from repro.quant.common import dequantize_linear


def wants_adapter(name: str, acfg: AdapterConfig) -> bool:
    return methods.get(acfg.kind).has_params and name in acfg.targets


def adapter_init(key, name: str, d_in: int, d_out: int, acfg: AdapterConfig,
                 dtype=jnp.float32) -> Optional[dict]:
    """Adapter params for one linear (or None when not targeted).

    ``key`` is threaded to EVERY method uniformly -- stochastic inits
    (LoRA A, HOFT reflection vectors) consume it, deterministic ones (OFT
    zero-init) ignore it -- so seed sensitivity is a per-method property,
    not a signature difference."""
    if not wants_adapter(name, acfg):
        return None
    return methods.get(acfg.kind).init(key, name, d_in, d_out, acfg,
                                       dtype=dtype)


def adapter_param_count(name: str, d_in: int, d_out: int,
                        acfg: AdapterConfig) -> int:
    if not wants_adapter(name, acfg):
        return 0
    return methods.get(acfg.kind).param_count(name, d_in, d_out, acfg)


def fusion_mode(acfg: AdapterConfig, qcfg: QuantConfig,
                qstate_keys=()) -> str:
    """Which forward an adapted linear will take, per the method's registry
    entry: e.g. 'qoft_fused' (NF4 dequant + rotate + matmul, one kernel),
    'oftv2_fused' / 'hoft_fused' (transform + matmul, one kernel), or
    'unfused'.  ``qstate_keys`` are the ACTUAL keys of the linear's frozen
    state: a quantized mode is only reported when the matching quant state
    is really there (an empty/raw-``w`` qstate never routes quantized)."""
    return methods.get(acfg.kind).fusion_mode(acfg, qcfg, qstate_keys)


def adapted_linear(x: jnp.ndarray, qstate: dict, adapter: Optional[dict],
                   acfg: AdapterConfig, qcfg: QuantConfig,
                   constrain=None, adapter_id=None,
                   shard=None) -> jnp.ndarray:
    """y = adapted forward of one frozen linear, via the method registry.

    OFTv2/QOFT path never touches the quant state before the matmul --
    quantization-agnostic by construction (paper §4, eq. 3).  With
    acfg.fuse_linear, methods that declare fused kernels collapse the
    forward to ONE Pallas kernel (see repro.kernels).

    Multi-tenant serving (repro.serving): when the adapter leaf carries an
    ``r_stack`` -- the pool's per-layer (A, K//b, b, b) rotation stack --
    each batch row is routed to ITS adapter's blocks by ``adapter_id``
    ((B,) int32, threaded from the decode batch) via the method's
    ``route_multi`` hook.  A Python-int adapter_id is the
    all-rows-same-adapter fast path and lowers to the single-adapter
    kernels.  Methods without the capability raise NotImplementedError.

    constrain (optional, on-mesh only): gather-codes optimization -- the
    ZeRO-3 all-gather is forced onto the uint8 quant state (replicate it,
    dequantize locally) instead of the dequantized bf16 weight, cutting
    weight-gather wire ~4x (EXPERIMENTS.md §Perf/llama3 it-4).

    shard (optional, on-mesh only): this linear's ``LinearShard`` from the
    build-time ``MeshContext`` (repro.distributed.sharding) -- methods with
    the ``shards`` capability run their fused kernels per-shard inside
    shard_map (W / quant state / rotation blocks consumed locally, no
    resharding); make_shard_context already rejected methods without it.
    """
    # gather-codes is a ZeRO-3 optimization (replicate the uint8 state,
    # dequantize locally).  Under the mesh-native fused path (shard) the
    # quant state is TP-sharded and consumed locally by the per-shard
    # kernels -- replicating it would reintroduce the very all-gather the
    # sharded path exists to avoid (tests assert the compiled HLO is free
    # of W/codes-shaped gathers).
    if (constrain is not None and shard is None and qcfg.gather_codes
            and qcfg.enabled and "w" not in qstate):
        qstate = {k: constrain(v) for k, v in qstate.items()}
    method = methods.get(acfg.kind)
    if adapter is not None and "r_stack" in adapter:
        if adapter_id is None:
            raise ValueError(
                "pooled multi-adapter params (r_stack) need a per-row "
                "adapter_id -- pass batch['adapter_id'] (repro.serving)")
        return method.route_multi(x, qstate, adapter, adapter_id, acfg,
                                  qcfg, shard=shard)
    if adapter is None or not method.has_params:
        return x @ dequantize_linear(qstate, qcfg, x.dtype)
    if shard is not None and method.supports_sharding:
        return method.shard_forward(x, qstate, adapter, acfg, qcfg, shard,
                                    adapter_id=adapter_id)
    return method.forward(x, qstate, adapter, acfg, qcfg)


def merge_adapter(w: jnp.ndarray, adapter: Optional[dict],
                  acfg: AdapterConfig) -> jnp.ndarray:
    """Fold the adapter into a (dequantized) weight for deployment."""
    method = methods.get(acfg.kind)
    if adapter is None or not method.has_params:
        return w
    return method.merge(w, adapter, acfg)
