"""Cayley and Cayley-Neumann orthogonal parameterizations (paper §3.3).

Exact Cayley:      R = (I + Q)(I - Q)^{-1}          (rotation; needs a solve)
Cayley-Neumann:    R = (I + Q)(I + sum_{i=1..k} Q^i) (matrix-free; stable)

Q is skew-symmetric, so exact Cayley is exactly orthogonal; the Neumann
truncation is approximately orthogonal with error O(||Q||^{k+1}) -- the
property tests in tests/test_cayley.py assert the geometric decay.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.skew import unpack_skew


def _eye_like(q: jnp.ndarray) -> jnp.ndarray:
    b = q.shape[-1]
    return jnp.broadcast_to(jnp.eye(b, dtype=q.dtype), q.shape)


def cayley_exact(q: jnp.ndarray) -> jnp.ndarray:
    """(..., b, b) skew Q -> orthogonal R via exact Cayley transform.

    Used by the OFTv1 baseline (paper's original formulation). The solve is
    the numerical-stability / cost bottleneck the CNP removes.
    """
    eye = _eye_like(q)
    # R = (I+Q)(I-Q)^{-1}  =>  R (I-Q) = (I+Q)  =>  (I-Q)^T R^T = (I+Q)^T
    lhs = jnp.swapaxes(eye - q, -1, -2)
    rhs = jnp.swapaxes(eye + q, -1, -2)
    rt = jnp.linalg.solve(lhs, rhs)
    return jnp.swapaxes(rt, -1, -2)


def neumann_inverse(q: jnp.ndarray, k: int) -> jnp.ndarray:
    """Truncated Neumann series  I + Q + Q^2 + ... + Q^k  ~=  (I - Q)^{-1}.

    Unrolled (k is small and static); each term is one small matmul that the
    Pallas kernel keeps VMEM-resident.
    """
    eye = _eye_like(q)
    if k <= 0:
        return eye
    acc = eye + q
    power = q
    for _ in range(k - 1):
        power = power @ q
        acc = acc + power
    return acc


def cayley_neumann(q: jnp.ndarray, k: int) -> jnp.ndarray:
    """(..., b, b) skew Q -> approximately-orthogonal R = (I+Q) * Neumann_k(Q)."""
    eye = _eye_like(q)
    if k <= 0:
        return cayley_exact(q)
    return (eye + q) @ neumann_inverse(q, k)


def build_rotation(q_packed: jnp.ndarray, block_size: int,
                   neumann_terms: int) -> jnp.ndarray:
    """Packed skew params (..., r, pack_dim(b)) -> block rotations (..., r, b, b).

    neumann_terms == 0 selects the exact Cayley transform (OFTv1 fidelity);
    otherwise the Cayley-Neumann parameterization (OFTv2 default, k=5 in the
    paper's reference implementation).
    """
    q = unpack_skew(q_packed, block_size)
    if neumann_terms <= 0:
        return cayley_exact(q)
    return cayley_neumann(q, neumann_terms)


def orthogonality_error(r: jnp.ndarray) -> jnp.ndarray:
    """max-norm of RᵀR - I (scalar, for monitoring/tests)."""
    eye = jnp.eye(r.shape[-1], dtype=r.dtype)
    return jnp.max(jnp.abs(jnp.swapaxes(r, -1, -2) @ r - eye))
