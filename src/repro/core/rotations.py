"""Per-train-step rotation hoisting: build every OFT block rotation ONCE,
outside the grad-accumulation scan, and reuse it across all microbatches
and all fused-linear calls.

Before this module, ``build_r`` ran inside ``adapted_linear`` -- once per
adapted linear, per microbatch, per direction (the remat'd scan body also
re-ran it in the backward).  The Cayley--Neumann build is cheap per block
but it multiplies: layers x linears x microbatches x fwd/bwd kernel
launches of a tiny (r, b, b) op.

``with_rotations`` walks the adapter tree, stacks EVERY ``q_packed`` leaf
(any leading dims -- scan groups, experts) into one (R_total, pack_dim)
matrix, runs ``build_r`` exactly once, and splits the result back as an
``r_blocks`` entry next to each ``q_packed``.  Because ``r_blocks`` rides
in the same tree, the scan-over-layers zips it into the per-layer params
with no plumbing changes, and ``oftv2_linear`` / the QOFT path simply pick
it up when present.

Gradients: ``train_step`` takes ``jax.vjp`` of ``with_rotations`` once per
step, differentiates the loss w.r.t. the *augmented* tree (accumulating
dR across the microbatch scan), and pulls the summed dR back through the
Cayley--Neumann VJP once.  The chain rule is linear in the cotangent, so
this is exact -- and the rotation build + its backward trace once per
train step instead of once per microbatch per linear
(tests/test_fused_bwd.py counts the calls through the scan).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

from repro import methods
from repro.config.base import AdapterConfig
from repro.core import oft as oft_lib


def should_hoist(adapter_tree, acfg: AdapterConfig) -> bool:
    """Hoisting applies to methods whose registry entry declares
    ``supports_hoisted_rotations`` (input-centric OFT: v1 rebuilds R as
    part of its weight transform baseline, LoRA/HOFT have no block
    rotations to hoist) -- and only when the tree actually carries
    ``q_packed`` leaves."""
    return (methods.get(acfg.kind).supports_hoisted_rotations
            and any(True for _ in _oft_leaves(adapter_tree)))


def _oft_leaves(tree, path=()) -> Iterator[Tuple[tuple, dict]]:
    """Yield (path, leaf_dict) for every {"q_packed": ...} adapter leaf, in
    deterministic (sorted-key) order."""
    if isinstance(tree, dict):
        if "q_packed" in tree:
            yield path, tree
        else:
            for k in sorted(tree):
                yield from _oft_leaves(tree[k], path + (k,))


def with_rotations(adapter_tree, acfg: AdapterConfig, shard=None):
    """Adapter tree -> same tree with an ``r_blocks`` (lead + (r, b, b))
    entry alongside every ``q_packed`` leaf, built by ONE ``build_r`` call
    over all leaves concatenated.  Differentiable w.r.t. the tree.

    ``shard`` (optional ``MeshContext``): each hoisted rotation leaf is
    constrained to its TP layout through the method's ``shard_rotations``
    hook -- block-sharded over `model` for model-sharded-input linears --
    so the per-shard fused kernels pick the blocks up locally.  The
    constraint is AD-transparent: the dR pullback through the concatenated
    Cayley--Neumann build stays exact."""
    leaves = list(_oft_leaves(adapter_tree))
    if not leaves:
        return adapter_tree
    b = acfg.block_size
    method = methods.get(acfg.kind) if shard is not None else None
    packed = [leaf["q_packed"] for _, leaf in leaves]
    flat = [q.reshape(-1, q.shape[-1]) for q in packed]
    sizes = [f.shape[0] for f in flat]
    stacked = jnp.concatenate(flat, axis=0)
    # time EAGER builds only (serving-pool stacking): under a trace this
    # is abstract and any timing/blocking would perturb the jaxpr, which
    # the telemetry layer is contractually forbidden from doing
    timed = not isinstance(stacked, jax.core.Tracer)
    if timed:
        import time

        from repro import obs
        timed = obs.enabled()
    if timed:
        t0 = time.perf_counter()
    r_all = oft_lib.build_r({"q_packed": stacked}, acfg)
    if timed:
        jax.block_until_ready(r_all)
        obs.metric("oft/rotation_build_seconds").observe(
            time.perf_counter() - t0)

    out = _copy_tree(adapter_tree)
    start = 0
    for (path, _), q, nrows in zip(leaves, packed, sizes):
        r = r_all[start:start + nrows].reshape(q.shape[:-1] + (b, b))
        start += nrows
        if method is not None:
            r = method.shard_rotations(path[-1], r, shard)
        node = out
        for k in path:
            node = node[k]
        node["r_blocks"] = r
    return out


def strip_rotations(tree):
    """Drop ``r_blocks`` entries (inverse of the tree shape change)."""
    if isinstance(tree, dict):
        return {k: strip_rotations(v) for k, v in tree.items()
                if k != "r_blocks"}
    return tree


def _copy_tree(tree):
    if isinstance(tree, dict):
        return {k: _copy_tree(v) for k, v in tree.items()}
    return tree
