"""BOFT: butterfly-factorized orthogonal finetuning (Liu et al.,
"Parameter-Efficient Orthogonal Finetuning via Butterfly Factorization"),
input-centric.

OFTv2 keeps the rotation block-diagonal, so features never mix across
blocks.  BOFT composes ``s`` stages, each a block-diagonal rotation
conjugated by an involutive butterfly permutation, so log-depth stages
mix every feature with every other while each stage stays matvec-cheap:

    y = (x @ B_1 @ B_2 @ ... @ B_s) @ W,
    B_1 = R_bd^{(1)}                       (plain block rotation)
    B_k = P_k @ R_bd^{(k)} @ P_k, k >= 2   (stride h = 2^{k-2} exchange)

``P_k`` pairs block ``i`` with block ``i + h`` and swaps half of each
block's features between the two -- the classic butterfly wiring,
expressed as a reshape/transpose so it is free inside a VMEM tile (the
rotated activations never visit HBM; see
``repro.kernels.boft_linear_fused``).  ``P_k = P_k^T = P_k^{-1}`` (it is
a swap of two size-2 axes), so each stage -- and the whole composition --
is exactly as orthogonal as its Cayley blocks.

Row-vector convention throughout: ``x @ R`` means each stage applies its
blocks on the right, matching ``repro.core.oft``.

Constraints (validated at CONFIG time, uniformly in init / param_count /
param_defs, so a launch-time dry run can never report shapes for a config
that cannot build -- the ISSUE-10 validation pattern):

  * ``d_in`` must be a power-of-two multiple of ``block_size`` (the
    butterfly halves the block count each stride doubling);
  * ``1 <= stages <= log2(d_in/block_size) + 1`` (stage k >= 2 needs
    stride ``2^{k-2} <= r/2``);
  * ``block_size`` must be even when stages >= 2 (half-block exchange).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import AdapterConfig
from repro.core import cayley, skew


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def num_blocks(d_in: int, acfg: AdapterConfig) -> int:
    b = acfg.block_size
    if d_in % b != 0:
        raise ValueError(
            f"BOFT: d_in={d_in} not divisible by block size {b}")
    r = d_in // b
    if not _is_pow2(r):
        raise ValueError(
            f"BOFT: d_in={d_in} must be a power-of-two multiple of the "
            f"block size {b} (got {r} blocks; the butterfly exchange "
            f"halves the block pairing each stage)")
    return r


def max_stages(r: int) -> int:
    """Full butterfly depth for ``r`` blocks: one unpermuted stage plus
    one stage per stride doubling (h = 1, 2, ..., r/2)."""
    return r.bit_length()  # log2(r) + 1 for power-of-two r


def num_stages(d_in: int, acfg: AdapterConfig) -> int:
    """Validated stage count for one adapted linear (0 = auto: the full
    log-depth butterfly)."""
    r = num_blocks(d_in, acfg)
    limit = max_stages(r)
    s = acfg.butterfly_stages or limit
    if not 1 <= s <= limit:
        raise ValueError(
            f"BOFT: butterfly_stages={acfg.butterfly_stages} out of range "
            f"for d_in={d_in}, block_size={acfg.block_size}: need "
            f"1 <= stages <= log2({r}) + 1 = {limit} (0 selects the full "
            f"depth)")
    if s >= 2 and acfg.block_size % 2 != 0:
        raise ValueError(
            f"BOFT: block_size={acfg.block_size} must be even for "
            f"permuted stages (the butterfly exchanges half of each "
            f"block); stages={s}")
    return s


def stage_strides(s: int) -> tuple:
    """Static per-stage butterfly strides: 0 marks the unpermuted stage,
    stage k >= 2 exchanges blocks ``i`` and ``i + 2^(k-2)``."""
    return (0,) + tuple(1 << k for k in range(s - 1))


def boft_init(d_in: int, acfg: AdapterConfig, dtype=jnp.float32) -> dict:
    """Zero-init packed skew params for every stage => every stage's
    blocks are I => the whole butterfly is exactly the identity at init
    (permute-identity-permute = identity)."""
    r = num_blocks(d_in, acfg)
    s = num_stages(d_in, acfg)
    return {"boft_q": jnp.zeros((s, r, skew.pack_dim(acfg.block_size)),
                                dtype=dtype)}


def boft_param_count(d_in: int, acfg: AdapterConfig) -> int:
    return (num_stages(d_in, acfg) * num_blocks(d_in, acfg)
            * skew.pack_dim(acfg.block_size))


def build_stage_rotations(params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """(s, r, p) packed skew -> (s, r, b, b) per-stage block rotations via
    the same Cayley(-Neumann) builder as OFTv2 (``neumann_terms=0`` gives
    the exact Cayley transform: every block exactly orthogonal, so the
    composed butterfly is orthogonal to machine precision -- the property
    tests pin this)."""
    q = params["boft_q"]
    s, r, p = q.shape
    rot = cayley.build_rotation(q.reshape(s * r, p), cfg.block_size,
                                cfg.neumann_terms)
    return rot.reshape(s, r, cfg.block_size, cfg.block_size)


def butterfly_permute(x3: jnp.ndarray, h: int) -> jnp.ndarray:
    """The stride-``h`` butterfly involution on blocked activations.

    x3: (..., r, b).  Viewing the block index as (g, p, j) with
    ``i = g*2h + p*h + j`` and the feature index as (q, c) with halves
    ``q``, the permutation swaps the pair selector ``p`` with the half
    selector ``q``: the new block ``(g, p, j)`` is [half p of block
    (g, 0, j) | half p of block (g, h, j)].  A swap of two size-2 axes is
    its own inverse and transpose, so conjugating a block rotation by it
    stays orthogonal."""
    lead = x3.shape[:-2]
    r, b = x3.shape[-2:]
    g = r // (2 * h)
    x6 = x3.reshape(lead + (g, 2, h, 2, b // 2))
    nd = x6.ndim
    perm = list(range(nd))
    perm[nd - 4], perm[nd - 2] = perm[nd - 2], perm[nd - 4]
    return x6.transpose(perm).reshape(lead + (r, b))


def apply_block_rotations(x3: jnp.ndarray, r_blocks: jnp.ndarray
                          ) -> jnp.ndarray:
    """x3: (..., r, b) @ per-block rotations (r, b, b), blockwise."""
    return jnp.einsum("...rb,rbc->...rc", x3, r_blocks.astype(x3.dtype))


def boft_apply(x: jnp.ndarray, rot_stages: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) through the full butterfly; rot_stages: (s, r, b, b).

    fp32 chain, cast back -- the jnp reference the Pallas kernel is tested
    against (``repro.kernels.ref.boft_linear_ref``)."""
    s, r, b, _ = rot_stages.shape
    lead = x.shape[:-1]
    x3 = x.astype(jnp.float32).reshape(lead + (r, b))
    rot = rot_stages.astype(jnp.float32)
    for k, h in enumerate(stage_strides(s)):
        if h:
            x3 = butterfly_permute(x3, h)
        x3 = apply_block_rotations(x3, rot[k])
        if h:
            x3 = butterfly_permute(x3, h)
    return x3.reshape(lead + (r * b,)).astype(x.dtype)


def boft_linear(x: jnp.ndarray, params: dict, cfg: AdapterConfig,
                w: jnp.ndarray) -> jnp.ndarray:
    """Full input-centric adapted linear: y = (x @ B_1..B_s) @ W.

    With cfg.fuse_linear the whole multi-stage rotate + matmul runs as ONE
    Pallas kernel (``kernels/boft_linear_fused``): the per-stage rotated
    activations never hit HBM.  Its VJP is the jnp reference (no fused
    backward kernel -- the capability matrix says so)."""
    rot_stages = build_stage_rotations(params, cfg)
    if cfg.fuse_linear:
        from repro.kernels import ops as kops
        return kops.boft_linear_fused(x, rot_stages, w)
    return boft_apply(x, rot_stages) @ w


def boft_merge(w: jnp.ndarray, params: dict,
               cfg: AdapterConfig) -> jnp.ndarray:
    """W' = B @ W for deployment, where ``boft_apply(x) == x @ B``:
    materialize B once by pushing the identity through the butterfly
    (merge-time only, never in the train loop)."""
    d_in = w.shape[0]
    b_full = boft_apply(jnp.eye(d_in, dtype=jnp.float32),
                        build_stage_rotations(params, cfg))
    return (b_full @ w.astype(jnp.float32)).astype(w.dtype)


def boft_flops_per_step(d_in: int, tokens: int, acfg: AdapterConfig) -> int:
    """Analytic adapter-overhead FLOPs: s stages, each a blockdiag apply
    (2 T d b) plus the per-stage Cayley builds."""
    r = num_blocks(d_in, acfg)
    s = num_stages(d_in, acfg)
    b = acfg.block_size
    build = s * r * max(acfg.neumann_terms, 1) * 2 * b ** 3
    return build + s * 2 * tokens * d_in * b
