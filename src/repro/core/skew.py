"""Packed skew-symmetric matrices.

The paper stores each OFT block's skew-symmetric generator Q (b x b, Q = -Qᵀ,
zero diagonal) as its packed strict-upper-triangular vector of length
b(b-1)/2, cutting parameter storage ~2x and letting the orthogonal transform
be reconstructed on the fly (paper §3.3, "custom CUDA kernel"; our TPU
adaptation lives in repro.kernels.cayley_neumann).

All ops here are pure jnp, jit/vmap/grad-safe, and serve as the reference
implementation the Pallas kernels are tested against.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def pack_dim(b: int) -> int:
    """Number of packed params for a b x b skew-symmetric matrix."""
    return b * (b - 1) // 2


@functools.lru_cache(maxsize=None)
def _triu_indices(b: int):
    iu = np.triu_indices(b, k=1)
    return np.asarray(iu[0]), np.asarray(iu[1])


@functools.lru_cache(maxsize=None)
def _unpack_gather_index(b: int) -> np.ndarray:
    """(b, b) int32 map: flat packed index of |Q[i, j]| (diagonal maps to slot 0;
    it is multiplied by sign 0)."""
    idx = np.zeros((b, b), dtype=np.int32)
    rows, cols = _triu_indices(b)
    for k, (i, j) in enumerate(zip(rows, cols)):
        idx[i, j] = k
        idx[j, i] = k
    return idx


@functools.lru_cache(maxsize=None)
def _unpack_sign(b: int) -> np.ndarray:
    """(b, b) sign map: +1 above diagonal, -1 below, 0 on diagonal."""
    s = np.zeros((b, b), dtype=np.float32)
    rows, cols = _triu_indices(b)
    s[rows, cols] = 1.0
    s[cols, rows] = -1.0
    return s


def unpack_skew(q_packed: jnp.ndarray, b: int) -> jnp.ndarray:
    """(..., pack_dim(b)) -> (..., b, b) skew-symmetric Q.

    Implemented as a single gather + sign multiply: this is the exact dataflow
    the paper's CUDA kernel implements, expressed shape-wise so XLA/Pallas can
    fuse it.
    """
    if q_packed.shape[-1] != pack_dim(b):
        raise ValueError(
            f"packed dim {q_packed.shape[-1]} does not match block size {b} "
            f"(expected {pack_dim(b)})")
    idx = jnp.asarray(_unpack_gather_index(b))
    sign = jnp.asarray(_unpack_sign(b), dtype=q_packed.dtype)
    q = jnp.take(q_packed, idx.reshape(-1), axis=-1)
    q = q.reshape(q_packed.shape[:-1] + (b, b))
    return q * sign


def pack_skew(q: jnp.ndarray) -> jnp.ndarray:
    """(..., b, b) -> (..., pack_dim(b)): extract strict upper triangle."""
    b = q.shape[-1]
    rows, cols = _triu_indices(b)
    return q[..., rows, cols]


def random_skew(key, shape_prefix, b: int, scale: float = 0.1,
                dtype=jnp.float32) -> jnp.ndarray:
    """Random packed skew params (for tests); OFT training inits to zeros."""
    import jax
    return scale * jax.random.normal(key, tuple(shape_prefix) + (pack_dim(b),),
                                     dtype=dtype)
