"""GOFT: quasi-orthogonal finetuning via Givens rotations (Ma et al.,
"Parameter Efficient Quasi-Orthogonal Fine-Tuning via Givens Rotation"),
input-centric.

The sparse limit of the structured-orthogonality family: where OFTv2
rotates b-dim blocks and BOFT composes butterflies of them, GOFT applies
``p`` brick-wall passes of 2x2 Givens rotations -- d/2 independent plane
rotations per pass, adjacent pairs, odd passes offset by one so the
bricks interleave and any feature can reach any other in ~d passes:

    pass 0 (even): rotate pairs (0,1), (2,3), ...
    pass 1 (odd):  rotate pairs (1,2), (3,4), ..., (d-1,0)  (wraparound)

Each plane rotation is the trig-free Cayley form of one angle parameter
theta (c = 1/sqrt(1+theta^2), s = theta*c -- exactly c^2 + s^2 = 1 in
exact arithmetic, so each pass is orthogonal and the float residual of
the COMPOSITION grows only with accumulated rounding, not with theta;
the property tests bound it as passes accumulate).  theta = 0 gives the
exact identity, so zero-init is identity-at-init for free.

Row-vector convention: for the pair (i, j) with angle params (c, s),

    y_i = c*x_i - s*x_j,   y_j = s*x_i + c*x_j.

The kernel-friendly formulation avoids any (d/2, 2) reshape in the lane
dimension: expand per-pair (c, s) to per-LANE vectors cos_k (d,) and a
SIGNED sin_k (d,) with ``new = cos_k*x + sin_k*partner`` where partner
is the pair sibling (roll by -1 on even lanes of the pass, +1 on odd)
-- see ``expand_pass_coeffs`` and ``repro.kernels.goft_linear_fused``.

Config-time validation (uniform across init / param_count / param_defs):
``d_in`` must be even (complete pairing) and ``1 <= givens_passes <=
d_in`` (beyond d passes every plane has been revisited with no added
reach -- a config asking for more is a bug, not a preference).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig


def num_passes(d_in: int, acfg: AdapterConfig) -> int:
    """Validated brick-wall pass count for one adapted linear."""
    if d_in % 2 != 0:
        raise ValueError(
            f"GOFT: d_in={d_in} must be even (Givens rotations pair "
            f"adjacent features)")
    p = acfg.givens_passes
    if not 1 <= p <= d_in:
        raise ValueError(
            f"GOFT: givens_passes={p} out of range for d_in={d_in}: need "
            f"1 <= passes <= d_in (full mixing reach is ~d passes; more "
            f"adds parameters with no added connectivity)")
    return p


def goft_init(d_in: int, acfg: AdapterConfig, dtype=jnp.float32) -> dict:
    """theta = 0 => every plane rotation is I => exact identity at init."""
    p = num_passes(d_in, acfg)
    return {"thetas": jnp.zeros((p, d_in // 2), dtype=dtype)}


def goft_param_count(d_in: int, acfg: AdapterConfig) -> int:
    return num_passes(d_in, acfg) * (d_in // 2)


def givens_cs(thetas: jnp.ndarray):
    """Trig-free Cayley-Givens coefficients: (c, s) with c^2 + s^2 = 1.

    tan(angle/2) parameterization -- smooth, unbounded thetas, no trig
    on-device, and exactly orthogonal per-plane in exact arithmetic."""
    t = thetas.astype(jnp.float32)
    c = jax.lax.rsqrt(1.0 + t * t)
    return c, t * c


def expand_pass_coeffs(thetas: jnp.ndarray):
    """(p, d/2) angles -> per-lane (cos_k, sin_k), each (p, d).

    cos_k[k, i] is the cosine the lane-i feature sees in pass k; sin_k is
    SIGNED: -s on the first lane of its pair, +s on the second, so every
    lane computes ``new = cos_k*x + sin_k*partner`` uniformly.  Odd
    passes are handled by the caller rotating the lane view, so the
    expansion itself is pass-shape-agnostic."""
    c, s = givens_cs(thetas)
    cos_k = jnp.repeat(c, 2, axis=-1)
    sin_k = jnp.stack([-s, s], axis=-1).reshape(s.shape[:-1] + (-1,))
    return cos_k, sin_k


def _rotate_pairs(x: jnp.ndarray, cos_k: jnp.ndarray,
                  sin_k: jnp.ndarray) -> jnp.ndarray:
    """One even-aligned pass on (..., d): partner of lane 2i is 2i+1 and
    vice versa, i.e. roll within each pair."""
    d = x.shape[-1]
    x2 = x.reshape(x.shape[:-1] + (d // 2, 2))
    partner = x2[..., ::-1].reshape(x.shape)
    return cos_k * x + sin_k * partner


def goft_apply(x: jnp.ndarray, thetas: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) through p brick-wall Givens passes; thetas: (p, d/2).

    fp32 chain, cast back -- the jnp reference the Pallas kernel is
    tested against (``repro.kernels.ref.goft_linear_ref``).  Odd passes
    are conjugated by a roll: shift the lanes left by one, apply an
    even-aligned pass, shift back -- which rotates pairs (1,2), (3,4),
    ..., (d-1,0) including the wraparound brick."""
    p = thetas.shape[0]
    xf = x.astype(jnp.float32)
    cos_k, sin_k = expand_pass_coeffs(thetas)
    for k in range(p):
        if k % 2 == 1:
            xf = jnp.roll(xf, -1, axis=-1)
        xf = _rotate_pairs(xf, cos_k[k], sin_k[k])
        if k % 2 == 1:
            xf = jnp.roll(xf, 1, axis=-1)
    return xf.astype(x.dtype)


def goft_linear(x: jnp.ndarray, params: dict, cfg: AdapterConfig,
                w: jnp.ndarray) -> jnp.ndarray:
    """y = GOFT(x) @ W; with cfg.fuse_linear all p passes run on the
    activation tile in VMEM inside one Pallas kernel before the matmul
    (``kernels/goft_linear_fused``)."""
    if cfg.fuse_linear:
        from repro.kernels import ops as kops
        return kops.goft_linear_fused(x, params["thetas"], w)
    return goft_apply(x, params["thetas"]) @ w


def goft_merge(w: jnp.ndarray, params: dict,
               cfg: AdapterConfig) -> jnp.ndarray:
    """W' = G @ W where ``goft_apply(x) == x @ G``: push the identity
    through the passes once at merge time."""
    d_in = w.shape[0]
    g_full = goft_apply(jnp.eye(d_in, dtype=jnp.float32), params["thetas"])
    return (g_full @ w.astype(jnp.float32)).astype(w.dtype)


def goft_flops_per_step(d_in: int, tokens: int, acfg: AdapterConfig) -> int:
    """Each pass is 4 flops/feature (2 mul + 1 add per output, x2 lanes
    share the pair) -- linear in d, the sparse limit of the family."""
    p = num_passes(d_in, acfg)
    return p * 4 * tokens * d_in
