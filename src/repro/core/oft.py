"""OFT: weight-centric (v1) and input-centric (v2) orthogonal finetuning.

Weight convention throughout the framework: linear weights are stored as
``W: (d_in, d_out)`` and the forward pass is ``y = x @ W``.  OFT learns a
block-diagonal orthogonal ``R = Diag(R_1..R_r)`` acting on the *input*
features (paper eq. 1/2, transposed to row-vector convention):

    v1 (weight-centric):  y = x @ (R_bd @ W)      -- O(d^2 n) matrix-matrix
    v2 (input-centric) :  y = (x @ R_bd) @ W      -- O(T d b + T d n) matvecs

Both are implemented blockwise (never materializing the d x d ``R_bd``) and
are numerically identical; tests/test_oft.py asserts it. The complexity gap
is real nonetheless: v1 re-materializes (and differentiates through) a full
d x n weight every step, v2 touches activations only -- that is the paper's
entire scalability claim, and it is what the dry-run memory/flops analysis
shows at scale.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig
from repro.core import cayley, skew


def num_blocks(d: int, block_size: int) -> int:
    if d % block_size != 0:
        raise ValueError(f"d_in={d} not divisible by OFT block size {block_size}")
    return d // block_size


def oft_init(d_in: int, block_size: int, dtype=jnp.float32) -> dict:
    """Zero-init packed skew params => R = I => finetuning starts at the
    pretrained model (paper §3.3)."""
    r = num_blocks(d_in, block_size)
    return {"q_packed": jnp.zeros((r, skew.pack_dim(block_size)), dtype=dtype)}


def oft_param_count(d_in: int, block_size: int) -> int:
    return num_blocks(d_in, block_size) * skew.pack_dim(block_size)


def build_r(params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """(r, p) packed -> (r, b, b) block rotations."""
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.cayley_neumann(params["q_packed"], cfg.block_size,
                                   cfg.neumann_terms)
    return cayley.build_rotation(params["q_packed"], cfg.block_size,
                                 cfg.neumann_terms)


def get_r(params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """Rotations for one adapted linear: the hoisted per-train-step
    ``r_blocks`` when present (repro.core.rotations built them once for the
    whole step), else built from the packed skew params on the spot."""
    r_blocks = params.get("r_blocks")
    if r_blocks is not None:
        return r_blocks
    return build_r(params, cfg)


def apply_blockdiag(x: jnp.ndarray, r_blocks: jnp.ndarray) -> jnp.ndarray:
    """y = x @ Diag(R_1..R_r) for x: (..., d), r_blocks: (r, b, b)."""
    rb, b, _ = r_blocks.shape
    lead = x.shape[:-1]
    xr = x.reshape(lead + (rb, b))
    yr = jnp.einsum("...rb,rbc->...rc", xr, r_blocks.astype(x.dtype))
    return yr.reshape(lead + (rb * b,))


def oftv2_transform_input(x: jnp.ndarray, params: dict,
                          cfg: AdapterConfig) -> jnp.ndarray:
    """Input-centric OFT (the paper's contribution): x' = x @ R_bd."""
    r_blocks = get_r(params, cfg)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.block_oft_apply(x, r_blocks)
    return apply_blockdiag(x, r_blocks)


def oftv2_linear(x: jnp.ndarray, params: dict, cfg: AdapterConfig,
                 w: jnp.ndarray) -> jnp.ndarray:
    """Full input-centric adapted linear: y = (x @ R_bd) @ W.

    With cfg.fuse_linear the rotation and matmul run as ONE Pallas kernel
    (rotated activations never hit HBM) whose backward is also one fused
    kernel; the base W is frozen by the parameter-layout contract, so the
    dW matmul is skipped structurally (train_w=False).  Otherwise
    rotate-then-matmul as two ops. Numerics are identical --
    tests/test_kernels.py asserts it."""
    if cfg.fuse_linear:
        from repro.kernels import ops as kops
        return kops.oftv2_linear_fused(x, get_r(params, cfg), w,
                                       train_w=False)
    return oftv2_transform_input(x, params, cfg) @ w


def oftv1_transform_weight(w: jnp.ndarray, params: dict,
                           cfg: AdapterConfig) -> jnp.ndarray:
    """Weight-centric OFT baseline: W' = R_bd @ W (matrix-matrix, cubic).

    w: (d_in, d_out). Reshaped blockwise: W'[i] = R_i @ W[i]."""
    r_blocks = build_r(params, cfg)
    rb, b, _ = r_blocks.shape
    d_in, d_out = w.shape
    wr = w.reshape(rb, b, d_out)
    wt = jnp.einsum("rab,rbn->ran", r_blocks.astype(w.dtype), wr)
    return wt.reshape(d_in, d_out)


def oft_merge(w: jnp.ndarray, params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """Merge the adapter into the pretrained weight for deployment
    (identical math to v1's weight transform -- done once, not per step)."""
    return oftv1_transform_weight(w, params, cfg)


def oft_flops_per_step(d_in: int, d_out: int, tokens: int, block_size: int,
                       input_centric: bool, neumann_terms: int = 5) -> int:
    """Analytic adapter-overhead FLOPs (2*mnk per matmul), used by the Fig-1
    benchmark and roofline cross-checks.

    v1: build R (r * k * 2b^3) + weight transform (2 * d_in * b * d_out)
        [per step, independent of token count]
    v2: build R (same) + blockdiag apply (2 * tokens * d_in * b)
    """
    r = num_blocks(d_in, block_size)
    build = r * max(neumann_terms, 1) * 2 * block_size ** 3
    if input_centric:
        return build + 2 * tokens * d_in * block_size
    return build + 2 * d_in * block_size * d_out
