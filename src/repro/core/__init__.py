"""The paper's contribution: OFTv2 (input-centric orthogonal finetuning),
Cayley-Neumann parameterization, QOFT, plus OFTv1/LoRA/QLoRA baselines."""
from repro.core.adapter import (adapted_linear, adapter_init,
                                adapter_param_count, merge_adapter,
                                wants_adapter)
from repro.core.cayley import (build_rotation, cayley_exact, cayley_neumann,
                               orthogonality_error)
from repro.core.oft import (apply_blockdiag, oft_init, oft_param_count,
                            oftv1_transform_weight, oftv2_transform_input)
from repro.core.skew import pack_dim, pack_skew, unpack_skew

__all__ = [
    "adapted_linear", "adapter_init", "adapter_param_count", "merge_adapter",
    "wants_adapter", "build_rotation", "cayley_exact", "cayley_neumann",
    "orthogonality_error", "apply_blockdiag", "oft_init", "oft_param_count",
    "oftv1_transform_weight", "oftv2_transform_input", "pack_dim",
    "pack_skew", "unpack_skew",
]
