"""LoRA / QLoRA baseline (paper compares against Hu et al. 2022 / Dettmers
et al. 2023).  Parallel low-rank update: y = x @ W + (alpha/r) * (x @ A) @ B."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig


def lora_init(key, d_in: int, d_out: int, rank: int,
              dtype=jnp.float32) -> dict:
    """A ~ N(0, 1/r) (kaiming-ish), B = 0 => adapter starts as identity map."""
    ka, _ = jax.random.split(key)
    a = jax.random.normal(ka, (d_in, rank), dtype=dtype) / jnp.sqrt(
        jnp.asarray(rank, dtype=dtype))
    b = jnp.zeros((rank, d_out), dtype=dtype)
    return {"lora_a": a, "lora_b": b}


def lora_param_count(d_in: int, d_out: int, rank: int) -> int:
    return rank * (d_in + d_out)


def lora_delta(x: jnp.ndarray, params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """(alpha/r) * (x @ A) @ B  -- the parallel branch added to the frozen path."""
    scale = cfg.alpha / cfg.rank
    a = params["lora_a"].astype(x.dtype)
    b = params["lora_b"].astype(x.dtype)
    return ((x @ a) @ b) * jnp.asarray(scale, dtype=x.dtype)


def lora_merge(w: jnp.ndarray, params: dict, cfg: AdapterConfig) -> jnp.ndarray:
    """W' = W + (alpha/r) A @ B -- note this *changes the dynamic range* of W,
    which is the paper's requantization argument against QLoRA (§4)."""
    scale = cfg.alpha / cfg.rank
    return w + scale * (params["lora_a"] @ params["lora_b"]).astype(w.dtype)
