"""HOFT: compact Householder-product orthogonal finetuning (HOFT,
arXiv:2505.16531 / HRA, arXiv:2405.17484 family), input-centric.

The learned orthogonal transform is a chain of m Householder reflections

    H = H_1 H_2 ... H_m,    H_i = I - 2 v_i v_iᵀ / ||v_i||²

applied to the INPUT features in row-vector convention exactly like OFTv2:
y = (x @ H) @ W.  Each reflection is matrix-vector work on the activations
-- x @ H_i = x - c_i (x·v_i) v_iᵀ, c_i = 2/||v_i||² -- so the per-token
cost is O(m d), the same quadratic-cost story as OFTv2 §3 (vs the cubic
weight-transform of weight-centric OFT), with a different parameterization:
m full-width reflection vectors (m·d params) instead of d/b packed b x b
skew blocks.

Identity at init (finetuning starts at the pretrained model): reflections
cannot be zero-initialized -- H(v) is a reflection for ANY v != 0 -- so
``hoft_init`` samples m/2 random vectors and duplicates each consecutively.
H(v)H(v) = I exactly, so the paired chain is the identity while the two
copies sit at different chain positions and diverge freely under training.
This is why HOFT's init is stochastic (seed-sensitive) where OFT's is not,
and why ``reflections`` must be even.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import AdapterConfig

# Guard for ||v||²: keeps an all-zero reflection vector (e.g. sublane
# padding rows in the fused kernel) an exact no-op instead of a NaN.  The
# Pallas kernel and the jnp oracle use the SAME guard so they agree bitwise.
NORM_EPS = 1e-12


def num_reflections(acfg: AdapterConfig) -> int:
    m = acfg.reflections
    if m <= 0 or m % 2 != 0:
        raise ValueError(
            f"AdapterConfig.reflections must be a positive even number "
            f"(paired Householder vectors make the init-time chain the "
            f"identity); got {m}")
    return m


def hoft_init(key, d_in: int, m: int, dtype=jnp.float32) -> dict:
    """m paired reflection vectors: v[2i] == v[2i+1] at init, so the
    product of reflections is exactly I (see module docstring)."""
    if m % 2 != 0:
        raise ValueError(f"reflections must be even, got {m}")
    half = jax.random.normal(key, (m // 2, d_in), jnp.float32) \
        / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return {"hh_v": jnp.repeat(half, 2, axis=0).astype(dtype)}


def hoft_param_count(d_in: int, m: int) -> int:
    return m * d_in


def hoft_apply(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d) @ H_1...H_m for v: (m, d); fp32 chain, cast back.

    Sequential by construction (reflection i sees the output of i-1); m is
    small and static, so the loop unrolls into m fused matvec+axpy steps."""
    xf = x.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    for i in range(v.shape[0]):
        vi = vf[i]
        c = 2.0 / jnp.maximum(jnp.sum(vi * vi), NORM_EPS)
        xf = xf - c * (xf @ vi)[..., None] * vi
    return xf.astype(x.dtype)


def hoft_linear(x: jnp.ndarray, params: dict, cfg: AdapterConfig,
                w: jnp.ndarray) -> jnp.ndarray:
    """Full input-centric adapted linear: y = (x @ H_1...H_m) @ W.

    With cfg.fuse_linear the whole chain + matmul run as ONE Pallas kernel
    (``kernels/hoft_linear_fused``): the reflected activations never hit
    HBM.  Its VJP falls back to the jnp reference (no fused backward kernel
    yet -- the capability matrix says so)."""
    if cfg.fuse_linear:
        from repro.kernels import ops as kops
        return kops.hoft_linear_fused(x, params["hh_v"], w)
    return hoft_apply(x, params["hh_v"]) @ w


def hoft_merge(w: jnp.ndarray, params: dict,
               cfg: AdapterConfig) -> jnp.ndarray:
    """W' = H_1...H_m @ W for deployment: x @ W' == hoft_apply(x) @ W.

    Applied right-to-left (H_m first), each step matrix-vector work on W:
    H_i @ M = M - c_i v_i (v_iᵀ M)."""
    v = params["hh_v"].astype(jnp.float32)
    wt = w.astype(jnp.float32)
    for i in range(v.shape[0] - 1, -1, -1):
        vi = v[i]
        c = 2.0 / jnp.maximum(jnp.sum(vi * vi), NORM_EPS)
        wt = wt - c * vi[:, None] * (vi @ wt)[None, :]
    return wt.astype(w.dtype)


def hoft_flops_per_step(d_in: int, d_out: int, tokens: int, m: int) -> int:
    """Analytic adapter-overhead FLOPs: m reflections, each a matvec +
    rank-1 update over the activations (4 * tokens * d per reflection)."""
    return 4 * tokens * d_in * m
