"""Merge-back + requantization analysis (paper §4, "QOFT vs QLoRA").

The paper argues the merged OFT weight R@W preserves per-column l2 norms
exactly (orthogonality) and element dynamic range approximately, while
LoRA's W + AB shifts the dynamic range by up to ||AB||_inf -- so
requantizing a merged QOFT model is strictly better conditioned. These
functions quantify that claim; tests/test_merging.py and
benchmarks/requant_error.py exercise them.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.config.base import AdapterConfig, QuantConfig


def column_norm_drift(w: jnp.ndarray, merged: jnp.ndarray) -> jnp.ndarray:
    """max_j | ||merged[:,j]|| - ||w[:,j]|| | / ||w[:,j]|| -- exactly 0 for OFT
    (up to Neumann truncation + float error)."""
    n0 = jnp.linalg.norm(w, axis=0)
    n1 = jnp.linalg.norm(merged, axis=0)
    return jnp.max(jnp.abs(n1 - n0) / jnp.maximum(n0, 1e-12))


def dynamic_range_shift(w: jnp.ndarray, merged: jnp.ndarray) -> jnp.ndarray:
    """| max|merged| - max|w| | -- the requantization-range perturbation."""
    return jnp.abs(jnp.max(jnp.abs(merged)) - jnp.max(jnp.abs(w)))


def lora_worstcase_range_shift(adapter: dict, acfg: AdapterConfig) -> jnp.ndarray:
    """||(alpha/r) A@B||_inf -- the paper's worst-case bound for QLoRA."""
    delta = (acfg.alpha / acfg.rank) * (adapter["lora_a"] @ adapter["lora_b"])
    return jnp.max(jnp.abs(delta))


def requantization_report(w: jnp.ndarray, adapter: dict, acfg: AdapterConfig,
                          qcfg: QuantConfig) -> Dict[str, float]:
    """Merge -> requantize -> measure, via the method's ``requant_report``
    registry hook (the base-class default covers any method with ``merge``;
    a method may override to report method-specific diagnostics).  Returns
    scalars (floats)."""
    from repro import methods
    return methods.get(acfg.kind).requant_report(w, adapter, acfg, qcfg)
