"""LR schedules (cosine/linear/constant with warmup), pure functions of the
step so they live inside the jitted train step."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import TrainConfig


def learning_rate(step: jnp.ndarray, tc: TrainConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.maximum(tc.warmup_steps, 1)
    warmup = s / warm
    total = jnp.maximum(tc.steps - tc.warmup_steps, 1)
    prog = jnp.clip((s - tc.warmup_steps) / total, 0.0, 1.0)
    floor = tc.min_lr_ratio
    if tc.schedule == "cosine":
        decay = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    elif tc.schedule == "linear":
        decay = floor + (1 - floor) * (1 - prog)
    else:
        decay = jnp.ones_like(prog)
    return tc.learning_rate * jnp.where(s < tc.warmup_steps, warmup, decay)
