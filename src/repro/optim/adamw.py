"""AdamW in pure JAX (no optax in this container). State is a pytree
mirroring the trainable params -- for PEFT that is the adapter tree only,
which is the whole memory story of the paper: optimizer state is O(adapter),
not O(model)."""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray        # ()
    mu: dict                 # first moment
    nu: dict                 # second moment


def init(params: dict) -> AdamWState:
    z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                               params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree_util.tree_map(jnp.copy, z))


def update(grads: dict, state: AdamWState, params: dict, lr: jnp.ndarray,
           tc: TrainConfig) -> Tuple[dict, AdamWState]:
    """Returns (new_params, new_state). lr is a traced scalar (schedule)."""
    step = state.step + 1
    b1, b2, eps = tc.b1, tc.b2, tc.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if tc.weight_decay > 0:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    new = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
