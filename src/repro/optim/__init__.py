from repro.optim.adamw import AdamWState, init, update
from repro.optim.clipping import clip_by_global_norm, global_norm
from repro.optim.schedule import learning_rate

__all__ = ["AdamWState", "init", "update", "clip_by_global_norm",
           "global_norm", "learning_rate"]
