"""Gradient compression for the cross-pod data-parallel all-reduce.

int8 symmetric quantization with error feedback (residual accumulation):
each step the (adapter) gradient is quantized to int8 + per-leaf fp32
scale before the collective, and the quantization error is carried into the
next step's gradient. For PEFT the gradient volume is tiny, but across
slow inter-pod links (DCI) this 4x cut keeps the pod axis latency-bound
rather than bandwidth-bound -- and the machinery generalizes to full
finetuning.

Inside jit we expose `compress_decompress` (quantize -> dequantize with
error feedback) applied *before* the mean-reduction; under GSPMD the
collective itself stays a dense all-reduce of the dequantized values unless
the shard_map DP driver (repro.distributed.pipeline) is used, where the
int8 payload crosses the wire for real.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error_state(params: dict) -> dict:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: dict, err: dict) -> Tuple[dict, dict]:
    """Error-feedback int8 round-trip. Returns (usable_grads, new_err)."""

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_leaf(gf)
        dq = dequantize_leaf(q, s)
        return dq.astype(g.dtype), gf - dq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))
