"""Typed configuration system.

Every experiment is described by a ``RunConfig`` bundling:
  * ``ModelConfig``    -- architecture (one per assigned arch in repro.configs)
  * ``AdapterConfig``  -- the paper's technique (oftv1 / oftv2 / lora / none)
  * ``QuantConfig``    -- frozen-base quantization (none / nf4 / awq / int8)
  * ``ParallelConfig`` -- mesh + sharding + remat + microbatching
  * ``TrainConfig``    -- optimizer / schedule / loop

Configs are frozen dataclasses so they can be hashed as jit static args and
stored verbatim in checkpoints.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the assembly path in ``repro.models.model``:
      dense | moe | hybrid | ssm | encoder | vlm
    """

    name: str = "unnamed"
    family: str = "dense"

    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 2
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1024

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0          # 0 -> d_ff
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    moe_period: int = 1        # MoE on layers where idx % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- hybrid (jamba): attention on layers where idx % attn_period == attn_offset,
    # SSM elsewhere. attn_period == 0 -> pure attention model. ---
    attn_period: int = 0
    attn_offset: int = 0

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # --- attention details ---
    causal: bool = True
    sliding_window: int = 0    # 0 = full attention
    rope_theta: float = 500000.0
    use_rope: bool = True
    attn_logit_softcap: float = 0.0
    attn_chunk: int = 1024     # kv-chunk for online-softmax attention

    # --- modality frontend stubs ---
    frontend: str = "none"     # none | audio_frames | vision_patches
    frontend_dim: int = 0      # dim of precomputed frame/patch embeddings
    num_frontend_tokens: int = 0   # vlm: image tokens prepended to text

    # --- assembly ---
    is_encoder: bool = False   # encoder-only (bidirectional, no decode step)
    act: str = "silu"          # silu (SwiGLU) | gelu (plain MLP)
    glu: bool = True           # gated MLP (SwiGLU) vs plain 2-layer MLP
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    scan_layers: bool = True   # scan-over-layers (compact HLO for the dry-run)
    scan_block: int = 1        # layers per scan body (jamba: attn_period)

    # --- numerics ---
    dtype: str = "float32"       # activation dtype
    param_dtype: str = "float32"

    # --- TP padding (filled by with_mesh_padding) ---
    pad_heads_to: int = 0      # 0 -> num_heads (no padding)
    pad_vocab_to: int = 0      # 0 -> vocab_size

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived ----
    @property
    def padded_heads(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def padded_vocab(self) -> int:
        return self.pad_vocab_to or self.vocab_size

    @property
    def is_ssm_layer(self):
        """Callable: layer_idx -> bool (True = SSM/mamba layer)."""
        if self.family == "ssm":
            return lambda i: True
        if self.family == "hybrid" and self.attn_period > 0:
            return lambda i: (i % self.attn_period) != self.attn_offset
        return lambda i: False

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts <= 0:
            return False
        return (i % self.moe_period) == self.moe_offset

    def with_mesh_padding(self, model_axis: int) -> "ModelConfig":
        """Pad head count / vocab so TP sharding divides evenly (exact numerics:
        padded q-heads feed zero o-proj columns; padded vocab rows get -inf logits
        masked in the loss)."""
        import math

        heads = self.num_heads
        if heads % model_axis != 0:
            heads = _round_up(heads, model_axis)
        vocab = _round_up(self.vocab_size, math.lcm(256, model_axis))
        return dataclasses.replace(self, pad_heads_to=heads, pad_vocab_to=vocab)

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (unpadded), used for MODEL_FLOPS and memory
        accounting.  MoE: active_only counts top_k experts only."""
        d, h = self.d_model, self.num_heads
        hd, kv = self.head_dim, self.num_kv_heads
        att = d * h * hd + 2 * d * kv * hd + h * hd * d  # q,k,v,o
        if self.glu:
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        if self.num_experts:
            e = self.top_k if active_only else self.num_experts
            mlp_moe = e * (3 if self.glu else 2) * d * self.moe_d_ff + d * self.num_experts
        else:
            mlp_moe = 0
        ssm = 0
        if self.ssm_state:
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_headdim
            ssm = (d * (2 * d_in + 2 * self.ssm_ngroups * self.ssm_state + nh)  # in_proj
                   + d_in * d            # out_proj
                   + self.ssm_conv_width * (d_in + 2 * self.ssm_ngroups * self.ssm_state)
                   + 2 * nh)             # A_log, dt_bias
        total = 0
        for i in range(self.num_layers):
            if self.is_ssm_layer(i):
                total += ssm
            else:
                total += att
            if self.is_moe_layer(i):
                total += mlp_moe
                if self.dense_residual:
                    total += mlp_dense
            else:
                total += mlp_dense
            total += 2 * d  # norms
        total += self.vocab_size * d  # embed
        if not self.tie_embeddings and not self.is_encoder:
            total += self.vocab_size * d
        if self.frontend != "none" and self.frontend_dim:
            total += self.frontend_dim * d
        total += d  # final norm
        return total


@dataclass(frozen=True)
class AdapterConfig:
    """The paper's technique + baselines + registry methods.

    ``kind`` names an ``AdapterMethod`` registered in ``repro.methods``
    (built-ins: none | oftv1 | oftv2 | lora | hoft | boft | goft);
    everything the framework does with it is a registry query, never
    string dispatch."""

    kind: str = "oftv2"        # an adapter method registered in repro.methods
    block_size: int = 32       # OFT block size b
    neumann_terms: int = 5     # k; 0 = exact Cayley (matrix solve)
    rank: int = 16             # LoRA rank r
    alpha: float = 16.0        # LoRA scaling
    reflections: int = 8       # HOFT Householder count m (even: paired
                               # vectors make the init-time chain identity)
    butterfly_stages: int = 0  # BOFT stage count (0 = auto: log2(d/b)+1,
                               # the full log-depth butterfly)
    givens_passes: int = 4     # GOFT brick-wall Givens passes (1..d_in)
    targets: Tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down",
                                "in_proj", "out_proj")
    adapt_experts: bool = False
    use_pallas: bool = False   # route adapter math through Pallas kernels
    # Fused forward: one Pallas kernel does transform+matmul (and NF4
    # dequant in the QOFT path) so transformed activations / dequantized
    # weights never round-trip through HBM. Honored by methods whose
    # registry entry declares supports_fused_forward (oftv2, hoft);
    # implies the Pallas path for the adapted linear itself.
    fuse_linear: bool = False


@dataclass(frozen=True)
class QuantConfig:
    kind: str = "none"         # none | nf4 | awq | int8
    block_size: int = 64       # nf4 absmax block (along in-features)
    double_quant: bool = True
    double_block: int = 256
    group_size: int = 128      # awq
    # beyond-paper (EXPERIMENTS.md §Perf/llama3 it-4): under ZeRO-3, gather
    # the quantized codes across the fsdp axes and dequantize locally, so
    # uint8 crosses the wire instead of dequantized bf16 (~3.7x less).
    gather_codes: bool = True

    @property
    def enabled(self) -> bool:
        return self.kind != "none"


@dataclass(frozen=True)
class ParallelConfig:
    mesh_shape: Tuple[int, ...] = (1, 1)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    remat: str = "full"          # none | full
    microbatches: int = 1        # grad-accumulation microbatches inside train_step
    seq_shard_saved: bool = True  # SP: shard saved activations' seq dim over model
    moe_layout: str = "auto"     # auto | tp | ep
    gradient_compression: str = "none"   # none | int8
    decode_cache_seq_shard: bool = True  # split-KV decode for big archs

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Axes that shard the batch (pod + data when present)."""
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model" if "model" in self.mesh_axes else self.mesh_axes[-1]

    @property
    def model_axis_size(self) -> int:
        for ax, sz in zip(self.mesh_axes, self.mesh_shape):
            if ax == "model":
                return sz
        return 1

    @property
    def data_axis_size(self) -> int:
        n = 1
        for ax, sz in zip(self.mesh_axes, self.mesh_shape):
            if ax in ("pod", "data"):
                n *= sz
        return n


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    steps: int = 100
    learning_rate: float = 4e-4
    schedule: str = "cosine"     # constant | cosine | linear
    warmup_steps: int = 10
    min_lr_ratio: float = 0.1
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_dir: str = "/tmp/repro_ckpt"
    eval_every: int = 0
    z_loss: float = 0.0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    adapter: AdapterConfig = field(default_factory=AdapterConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape presets assigned to this paper (LM family): every (arch x shape)
# cell is one of these.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapePreset:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


SHAPES = {
    "train_4k":    ShapePreset("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapePreset("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapePreset("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapePreset("long_500k",   524288, 1,   "decode"),
}
