from repro.config.base import (SHAPES, AdapterConfig, ModelConfig,
                               ParallelConfig, QuantConfig, RunConfig,
                               ShapePreset, TrainConfig)

__all__ = [
    "SHAPES", "AdapterConfig", "ModelConfig", "ParallelConfig", "QuantConfig",
    "RunConfig", "ShapePreset", "TrainConfig",
]
