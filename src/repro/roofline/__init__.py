from repro.roofline.analysis import (model_flops, parse_collectives,
                                     roofline_terms, shape_bytes)
from repro.roofline.hw import V5E, Chip

__all__ = ["model_flops", "parse_collectives", "roofline_terms",
           "shape_bytes", "V5E", "Chip"]
