"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step on the
per-device partitioned program (XLA compiles the per-device module, so
cost_analysis is already chips-normalized):

  compute    = device_FLOPs / peak_FLOP/s
  memory     = device_HBM_bytes / HBM_bw
  collective = device_wire_bytes / (links x link_bw)

Collective bytes are NOT in cost_analysis: we parse the post-SPMD optimized
HLO and apply a ring-traffic model per op kind (G = replica-group size):

  all-gather          B_out * (G-1)/G
  reduce-scatter      B_out * (G-1)          (operand = B_out * G)
  all-reduce          2 * B * (G-1)/G        (RS + AG phases)
  all-to-all          B * (G-1)/G
  collective-permute  B
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.roofline.hw import V5E, Chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(?P<result>[^=]*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(text: str) -> int:
    """Sum bytes of every `dtype[shape]` pattern in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        a, b = int(m.group(1)), int(m.group(2))
        # iota [n_groups, group_size]
        return b
    return default


def _wire_bytes(op: str, b: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return b * (g - 1) / g
    if op == "reduce-scatter":
        return b * (g - 1)
    if op == "all-reduce":
        return 2 * b * (g - 1) / g
    if op == "all-to-all":
        return b * (g - 1) / g
    if op == "collective-permute":
        return float(b)
    return float(b)


def parse_collectives(hlo_text: str, total_devices: int
                      ) -> Tuple[float, Dict[str, dict]]:
    """Returns (total_wire_bytes_per_device, per-op-kind breakdown)."""
    per_kind: Dict[str, dict] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
    total = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = shape_bytes(m.group("result"))
        g = _group_size(line, total_devices)
        wb = _wire_bytes(op, b, g)
        d = per_kind[op]
        d["count"] += 1
        d["bytes"] += b
        d["wire_bytes"] += wb
        total += wb
    return total, dict(per_kind)


def roofline_terms(device_flops: float, device_bytes: float,
                   wire_bytes: float, chip: Chip = V5E) -> Dict[str, float]:
    compute = device_flops / chip.peak_flops_bf16
    memory = device_bytes / chip.hbm_bw
    collective = wire_bytes / (chip.ici_links * chip.ici_link_bw)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(compute, memory, collective)
    terms["roofline_fraction_compute"] = compute / bound if bound else 0.0
    return terms


def attention_correction(cfg, seq_len: int, global_batch: int, mode: str,
                         data_shards: int, model_shards: int,
                         microbatches: int = 1) -> Dict[str, float]:
    """Analytic per-device flops/bytes of chunked (flash-style) attention at
    full sequence length.

    Needed because the online-softmax q/kv chunk loops are lax.scans whose
    bodies HLO cost analysis counts once; the probe extrapolation recovers
    the *layer* scan but not the *chunk* scans, so the attention core is
    added analytically (exact pair counts; SWA windows honored). Applied to
    train/prefill cells only -- decode attention takes the dense (scan-free)
    path and is already counted.

    Returns per-LAYER per-device {"flops": f, "bytes": b} (caller multiplies
    by the number of attention layers).
    """
    if cfg.num_heads == 0:
        return {"flops": 0.0, "bytes": 0.0}
    s = seq_len
    w = cfg.sliding_window
    if cfg.is_encoder or not cfg.causal:
        pairs = float(s) * s
    elif w and w < s:
        pairs = float(s) * w - 0.5 * w * w
    else:
        pairs = 0.5 * float(s) * s
    b_loc = max(global_batch // (data_shards * microbatches), 1)
    h_dev = max(cfg.padded_heads // model_shards, 1)
    hd = cfg.head_dim
    kvh = cfg.num_kv_heads
    dbytes = 2  # bf16
    qc = min(cfg.attn_chunk, s)

    flops_fwd = 4.0 * b_loc * pairs * h_dev * hd
    # kv re-reads: each q-chunk reads its kv span once
    kv_reads = pairs / qc
    bytes_fwd = b_loc * (kv_reads * kvh * hd * 2 * dbytes
                         + s * h_dev * hd * 2 * dbytes)
    if mode == "train":
        # bwd ~= 2x fwd; full remat recomputes fwd once more
        mult = 4.0
    else:
        mult = 1.0
    return {"flops": flops_fwd * mult * microbatches,
            "bytes": bytes_fwd * mult * microbatches}


def model_flops(cfg, tokens: int, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference fwd), N = active
    non-embedding params (MoE: top-k experts only)."""
    n_active = cfg.param_count(active_only=True)
    embed = cfg.vocab_size * cfg.d_model
    n_eff = n_active - embed   # lm head kept (it is a real matmul)
    mult = 6 if mode == "train" else 2
    return float(mult) * n_eff * tokens
