"""TPU v5e hardware model (per chip), per the assignment constants."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # FLOP/s
    hbm_bw: float = 819e9               # B/s
    ici_link_bw: float = 50e9           # B/s per link (assignment constant)
    ici_links: int = 1                  # conservative: 1 effective link
    hbm_bytes: float = 16e9             # 16 GB HBM per v5e chip


V5E = Chip()


def meshes():
    return {"single": {"chips": 256, "shape": (16, 16)},
            "multi": {"chips": 512, "shape": (2, 16, 16)}}
