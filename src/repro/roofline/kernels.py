"""Analytic per-kernel cost models: modeled FLOPs and HBM bytes for every
Pallas kernel in ``repro.kernels``.

``linear_hbm_bytes`` / ``linear_bwd_hbm_bytes`` moved here from
``benchmarks/kernels_bench.py`` so the live telemetry layer
(``repro.obs.kernels``) and the offline bench rows attribute traffic from
the SAME model -- the fused-vs-unfused claim is one formula, not two.

``kernel_cost(name, **shape)`` is the telemetry entry point: given the
shape kwargs a kernel entry passes to ``runtime.record_launch``, it
returns ``{"flops", "hbm_bytes", "hbm_bytes_unfused"}`` (or None for a
kernel with no model).  ``hbm_bytes`` is the fused kernel's traffic;
``hbm_bytes_unfused`` is what the same math staged through separate XLA
kernels would move, so the ratio of the two live counters reproduces the
paper's traffic-reduction claim on real traffic.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional


def linear_hbm_bytes(t: int, k: int, n: int, b: int, fused: bool,
                     quant_bs: int = 0, dt: int = 4) -> int:
    """HBM bytes per fused-vs-unfused OFTv2/QOFT linear forward.

    Unfused launches each stage as its own kernel, so every intermediate
    (rotated activations; dequantized W in the QOFT path) round-trips
    through HBM.  Fused reads x, R, W(/codes+absmax) once and writes y."""
    r_bytes = (k // b) * b * b * dt
    x_in, y_out = t * k * dt, t * n * dt
    if quant_bs:
        w_read = (k // 2) * n + (k // quant_bs) * n * 4   # codes + absmax
        w_roundtrip = 2 * k * n * dt                      # dense W out + in
    else:
        w_read = k * n * dt
        w_roundtrip = 0
    fused_total = x_in + r_bytes + w_read + y_out
    if fused:
        return fused_total
    return fused_total + w_roundtrip + 2 * t * k * dt     # + xr out + in


def linear_bwd_hbm_bytes(t: int, k: int, n: int, b: int, fused: bool,
                         quant_bs: int = 0, dt: int = 4) -> int:
    """HBM bytes per fused-vs-unfused OFTv2/QOFT linear BACKWARD (frozen
    base: dx + dR only, no dW).

    Unfused is three kernels: gW = g @ Wᵀ writes the (T, K) intermediate to
    HBM and both the dx rotation and the dR token-contraction read it back;
    the QOFT path additionally re-materializes the dense W first (write +
    read).  Fused reads g, x, R, W(/codes+absmax) once and writes dx + dR:
    neither gW nor a dense W ever exists in HBM."""
    r_bytes = (k // b) * b * b * dt
    g_in, x_in = t * n * dt, t * k * dt
    dx_out, dr_out = t * k * dt, r_bytes
    if quant_bs:
        w_read = (k // 2) * n + (k // quant_bs) * n * 4   # codes + absmax
        w_roundtrip = 2 * k * n * dt                      # dense W out + in
    else:
        w_read = k * n * dt
        w_roundtrip = 0
    fused_total = g_in + x_in + r_bytes + w_read + dx_out + dr_out
    if fused:
        return fused_total
    # + gW out once, read twice (dx stage, dR stage); + dense W roundtrip
    return fused_total + w_roundtrip + 3 * t * k * dt


def linear_flops(t: int, k: int, n: int, b: int) -> int:
    """Block-diagonal rotate (2TKb) + dense matmul (2TKN)."""
    return 2 * t * k * b + 2 * t * k * n


def linear_bwd_flops(t: int, k: int, n: int, b: int) -> int:
    """gW = g @ Wᵀ (2TKN) + rotate-back dx (2TKb) + dR contraction
    (2TKb)."""
    return 2 * t * k * n + 4 * t * k * b


def _linear_fwd(quant: bool):
    def cost(t, k, n, b, quant_bs=0, dt=4, **_):
        qbs = quant_bs if quant else 0
        return {"flops": linear_flops(t, k, n, b),
                "hbm_bytes": linear_hbm_bytes(t, k, n, b, True, qbs, dt),
                "hbm_bytes_unfused":
                    linear_hbm_bytes(t, k, n, b, False, qbs, dt)}
    return cost


def _linear_bwd(quant: bool):
    def cost(t, k, n, b, quant_bs=0, dt=4, **_):
        qbs = quant_bs if quant else 0
        return {"flops": linear_bwd_flops(t, k, n, b),
                "hbm_bytes": linear_bwd_hbm_bytes(t, k, n, b, True, qbs, dt),
                "hbm_bytes_unfused":
                    linear_bwd_hbm_bytes(t, k, n, b, False, qbs, dt)}
    return cost


def _block_oft_apply(t, k, b, dt=4, **_):
    # single-stage op: fused == unfused (nothing to round-trip)
    by = t * k * dt * 2 + (k // b) * b * b * dt
    return {"flops": 2 * t * k * b, "hbm_bytes": by,
            "hbm_bytes_unfused": by}


def _cayley_neumann(rb, b, terms, dt=4, **_):
    # per block: one b×b inverse-free Neumann series, (terms-1) b³ matmuls
    blk = rb * b * b * dt
    return {"flops": rb * 2 * b * b * b * max(terms - 1, 1),
            "hbm_bytes": 2 * blk, "hbm_bytes_unfused": 2 * blk}


def _nf4_dequant(k, n, quant_bs, dt=4, **_):
    codes = (k // 2) * n + (k // max(quant_bs, 1)) * n * 4
    by = codes + k * n * dt
    return {"flops": k * n, "hbm_bytes": by, "hbm_bytes_unfused": by}


def _multi_stage_rotate(t, k, b, s, dt=4, **_):
    # s butterfly stages fused on the tile: the permutes are reshapes in
    # VMEM, so fused traffic is one x round-trip + the stage rotations;
    # unfused stages each rotated (T, K) intermediate through HBM
    r_bytes = s * (k // b) * b * b * dt
    fused = 2 * t * k * dt + r_bytes
    return {"flops": s * 2 * t * k * b, "hbm_bytes": fused,
            "hbm_bytes_unfused": fused + 2 * (s - 1) * t * k * dt}


def _boft_linear(t, k, n, b, s, dt=4, **_):
    # s block-rotation stages (2TKb each) + dense matmul
    r_bytes = s * (k // b) * b * b * dt
    fused = t * k * dt + r_bytes + k * n * dt + t * n * dt
    # unfused: every stage's rotated activations round-trip through HBM
    return {"flops": s * 2 * t * k * b + 2 * t * k * n,
            "hbm_bytes": fused,
            "hbm_bytes_unfused": fused + 2 * s * t * k * dt}


def _goft_linear(t, k, n, p, dt=4, **_):
    # p brick-wall Givens passes (4 flops/lane) + dense matmul; the
    # per-lane coefficients are 2 (p, K) fp32 reads
    coeff = 2 * p * k * dt
    fused = t * k * dt + coeff + k * n * dt + t * n * dt
    return {"flops": p * 4 * t * k + 2 * t * k * n,
            "hbm_bytes": fused,
            "hbm_bytes_unfused": fused + 2 * p * t * k * dt}


def _hoft_linear(t, k, n, m, dt=4, **_):
    # m full-width Householder reflections (4TK each) + dense matmul
    fused = t * k * dt + m * k * dt + k * n * dt + t * n * dt
    # unfused stages each reflection through HBM: m (T, K) round-trips
    return {"flops": 4 * t * k * m + 2 * t * k * n,
            "hbm_bytes": fused,
            "hbm_bytes_unfused": fused + 2 * m * t * k * dt}


KERNEL_COSTS: Dict[str, Callable[..., dict]] = {
    "oftv2_linear_fused": _linear_fwd(quant=False),
    "oftv2_linear_multi": _linear_fwd(quant=False),
    "qoft_linear_fused": _linear_fwd(quant=True),
    "qoft_linear_multi": _linear_fwd(quant=True),
    "oftv2_linear_bwd": _linear_bwd(quant=False),
    "qoft_linear_bwd": _linear_bwd(quant=True),
    "block_oft_apply": _block_oft_apply,
    "cayley_neumann": _cayley_neumann,
    "nf4_dequant": _nf4_dequant,
    "hoft_linear_fused": _hoft_linear,
    "multi_stage_rotate": _multi_stage_rotate,
    "boft_linear_fused": _boft_linear,
    "goft_linear_fused": _goft_linear,
}


def kernel_cost(name: str, **shape) -> Optional[dict]:
    """Modeled cost for one launch of ``name`` at ``shape``; None when the
    kernel has no cost model (it is still counted, just not attributed)."""
    fn = KERNEL_COSTS.get(name)
    if fn is None:
        return None
    try:
        return fn(**shape)
    except TypeError:
        return None
