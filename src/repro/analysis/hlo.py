"""The compiled-HLO walker: parse ``lowered.compile().as_text()`` into a
stream of ops with result shapes and line provenance.

The jaxpr layer cannot see GSPMD: partitioning runs AFTER tracing, so the
collectives the compiler inserts (resharding all-gathers, halo exchanges)
never appear in any jaxpr.  Rules that budget collectives therefore run
twice -- once on the jaxpr (what the program asked for) and once here
(what the compiler actually emitted).  PR 5's W-gather incident is the
motivating case: the jaxpr was clean while GSPMD was quietly replicating
the TP-sharded NF4 codes through an all-gather.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import List, Tuple

# `%name = <result types> opcode(...)`; ROOT-prefixed and tuple-shaped
# results included.  XLA's collective combiner can merge several
# all-gathers into ONE tuple-shaped instruction, so EVERY shape on the
# left-hand side is captured, not just a single-operand form.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<lhs>.*?)\s*"
    r"(?P<op>[a-zA-Z][\w\-]*)\(")
_SHAPE = re.compile(r"\w+\[([0-9,]*)\]")


@dataclass
class HloOp:
    """One HLO instruction: opcode, every result shape, and the 1-based
    line it came from (findings provenance)."""
    opcode: str
    result_shapes: List[Tuple[int, ...]] = field(default_factory=list)
    lineno: int = 0
    text: str = ""


def parse_hlo(text: str) -> List[HloOp]:
    """Parse optimized-HLO text into an op stream.  Robust to the fusion
    bodies / metadata noise of ``as_text()``: anything that does not look
    like ``lhs = types opcode(`` is skipped."""
    ops = []
    for lineno, line in enumerate(text.splitlines(), 1):
        m = _INSTR.match(line)
        if not m:
            continue
        shapes = []
        for sm in _SHAPE.finditer(m.group("lhs")):
            dims = sm.group(1)
            shapes.append(tuple(int(d) for d in dims.split(","))
                          if dims else ())
        ops.append(HloOp(m.group("op"), shapes, lineno, line.strip()))
    return ops


def compile_text(fn, *args) -> str:
    """``jax.jit(fn).lower(*args).compile().as_text()`` -- the input every
    HLO rule inspects."""
    import jax
    return jax.jit(fn).lower(*args).compile().as_text()


#: HLO collective opcodes -> the jaxpr-level collective family they
#: implement.  A method's budget is declared in jaxpr terms (the
#: registry's ``shard_collectives``); this map translates it for the
#: compiled side.  psum lowers to all-reduce, and XLA may rewrite an
#: all-reduce into reduce-scatter + all-gather pairs only when it can
#: prove equivalence -- reduce-scatter therefore rides the psum budget.
COLLECTIVE_FAMILY = {
    "all-reduce": "psum",
    "reduce-scatter": "psum",
    "all-gather": "all_gather",
    "all-to-all": "all_to_all",
    "collective-permute": "ppermute",
}


def collectives(ops: List[HloOp]) -> List[HloOp]:
    return [op for op in ops if op.opcode in COLLECTIVE_FAMILY]


def weight_shapes(cfg) -> set:
    """Trailing-2D shapes that identify a per-layer weight (or its NF4
    codes / absmax) of ``cfg`` in compiled HLO: the full (d_in, d_out),
    the packed-codes (d_in/2, d_out), and the absmax rows for the swept
    block sizes.  Gathering any of these is the scaling regression the
    HLO collective rule pins down; tiny adapter-state gathers (q_packed,
    dR re-gathers) deliberately do not match."""
    from repro.models.linears import layer_linear_shapes
    shapes = set()
    for din, dout in layer_linear_shapes(cfg).values():
        shapes |= {(din, dout), (din // 2, dout)}
        for bs in (16, 32, 64):
            if din % bs == 0:
                shapes.add((din // bs, dout))
    return shapes
