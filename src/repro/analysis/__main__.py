"""The analysis CLI: ``python -m repro.analysis``.

Runs every registered rule over its layer's targets --

  * ``ast``:    every module under ``src/repro``;
  * ``jaxpr`` / ``hlo`` / ``trace``: the representative programs of
    :mod:`repro.analysis.fixtures` (fused fwd+bwd kernels, multi-adapter
    routing, an NF4 fused train step, the paged serving engine in steady
    state, and -- with >= 2 devices -- the mesh-sharded fused step);
  * ``bench``:  a ``benchmarks/run.py --json`` artifact (``--bench``);
  * ``metrics``: live-smoke ``metrics.jsonl`` dirs (``--metrics-dir``).

Exit code 1 if any finding has severity ``error``, else 0.  Layers with
no targets are reported in the skip notes, never silently dropped.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load_metrics(dirs) -> dict:
    """Merged {family: sample count} across the newest snapshot of each
    ``DIR/metrics.jsonl`` (same artifact format check_metrics gates)."""
    import os
    merged: dict = {}
    for d in dirs:
        path = os.path.join(d, "metrics.jsonl")
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
        if not lines:
            raise SystemExit(f"analysis: {path} is empty")
        for m in json.loads(lines[-1])["metrics"]:
            merged[m["name"]] = merged.get(m["name"], 0) + len(m["samples"])
    return merged


def main(argv=None) -> int:
    from repro.analysis import core
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="unified static contract checker "
                    "(jaxpr + HLO + AST + trace + artifacts)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table (markdown) and exit")
    parser.add_argument("--rules", default="",
                        help="comma-separated rule ids to run (default all)")
    parser.add_argument("--ast-only", action="store_true",
                        help="skip the traced fixtures (fast source-level "
                             "pass)")
    parser.add_argument("--no-sharded", action="store_true",
                        help="skip the mesh-sharded fixture")
    parser.add_argument("--bench", default=None, metavar="JSON",
                        help="benchmarks/run.py --json artifact to gate")
    parser.add_argument("--metrics-dir", action="append", default=[],
                        metavar="DIR",
                        help="metrics.jsonl dir to gate (repeatable)")
    parser.add_argument("--json", default=None, metavar="PATH",
                        help="write the findings report as JSON")
    args = parser.parse_args(argv)

    core._load_shipped()
    if args.list_rules:
        print(core.rules_table_md())
        return 0
    picked = None
    if args.rules:
        picked = [core.get(r.strip()) for r in args.rules.split(",")
                  if r.strip()]

    report = core.Report()

    from repro.analysis import pyast
    report.merge(core.run_layer("ast", pyast.iter_modules(), rules=picked))

    if args.ast_only:
        report.skipped.append("jaxpr/hlo/trace layers: --ast-only")
    else:
        from repro.analysis import fixtures
        targets = fixtures.collect(sharded=not args.no_sharded)
        report.merge(core.run_layer("jaxpr", targets["programs"],
                                    rules=picked))
        report.merge(core.run_layer("hlo", targets["programs"],
                                    rules=picked))
        report.merge(core.run_layer("trace", targets["traces"],
                                    rules=picked))
        report.skipped.extend(targets["skipped"])

    if args.bench:
        with open(args.bench) as f:
            rows = json.load(f)
        report.merge(core.run_layer("bench", [core.BenchRows(rows)],
                                    rules=picked))
    else:
        report.skipped.append("bench layer: no --bench artifact given")

    if args.metrics_dir:
        export = core.MetricsExport(_load_metrics(args.metrics_dir))
        report.merge(core.run_layer("metrics", [export], rules=picked))
    else:
        report.skipped.append("metrics layer: no --metrics-dir given")

    print(report.render())
    if args.json:
        report.write_json(args.json)
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
