"""Trace-layer rule: jit cache-miss accounting across steady-state smokes.

Retracing is invisible to every other layer -- the jaxpr is fine, the HLO
is fine, there are just N of them.  The fixtures run a representative
steady-state workload (train steps at fixed shapes; a second serving
drain over an identical-shape request mix) and hand this rule the
compile counts against their budgets.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax

from repro.analysis import core
from repro.analysis.core import Finding, Rule, TraceCounts


def jit_cache_size(jitted) -> int:
    """Compiled-variant count of a ``jax.jit`` wrapper (0 when the object
    carries no cache, e.g. the ``jit=False`` escape hatch)."""
    probe = getattr(jitted, "_cache_size", None)
    return int(probe()) if callable(probe) else 0


def measure_jit(label: str, fn, calls: Sequence[tuple],
                budget: int = 1) -> TraceCounts:
    """Jit ``fn``, execute every ``calls`` tuple, report compiles vs
    ``budget`` as a ``no-retrace`` target."""
    jitted = jax.jit(fn)
    for args in calls:
        jax.block_until_ready(jitted(*args))
    return TraceCounts(label, {label: (jit_cache_size(jitted), budget)})


def model_cache_counts(model) -> Dict[str, int]:
    """Per-entry compile counts of a model's serving jit cache
    (``repro.train.serving.model_jit_fn``)."""
    cache = getattr(model, "_jit_cache", {}) or {}
    return {name: jit_cache_size(fn) for name, fn in cache.items()}


def steady_state_counts(name: str, before: Dict[str, int],
                        after: Dict[str, int]) -> TraceCounts:
    """Compile GROWTH between two snapshots of the same jit caches; a
    steady-state rerun of an identical-shape workload has budget 0."""
    counts = {}
    for label in sorted(set(before) | set(after)):
        counts[label] = (after.get(label, 0) - before.get(label, 0), 0)
    return TraceCounts(name, counts)


@core.register
class NoRetrace(Rule):
    """Engine ticks and train steps trace once: steady-state smokes at
    fixed shapes must not grow any jit cache past its budget."""

    id = "no-retrace"
    layer = "trace"
    severity = core.ERROR
    description = ("steady-state smokes compile once: train steps and "
                   "serving ticks at fixed shapes never grow a jit cache "
                   "past its budget")

    def check(self, target: TraceCounts) -> List[Finding]:
        findings = []
        for label, (compiles, budget) in sorted(target.counts.items()):
            if compiles > budget:
                findings.append(self.finding(
                    f"{target.name}::{label}",
                    f"{compiles} compile(s) against a budget of {budget} "
                    f"-- something retraces per call (baked shape/value, "
                    f"or a fresh closure jitted per tick)"))
        return findings

    def fixture(self) -> TraceCounts:
        """A jitted fn fed three distinct shapes compiles three times --
        measured live through the same cache probe the real smokes use,
        so the accounting itself is proven, not just the comparison."""
        import jax.numpy as jnp
        return measure_jit(
            "shape-unstable-step", lambda x: x * 2.0,
            [(jnp.ones((n,)),) for n in (4, 5, 6)], budget=1)
