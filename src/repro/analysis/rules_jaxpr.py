"""Jaxpr-layer rules: the contracts a traced program must satisfy before
it ever reaches a compiler.

Each rule reads the ``Program`` metadata it needs and skips programs that
do not declare it -- the fixtures in ``repro.analysis.fixtures`` attach
the right metadata to each representative traced program of the tree.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.analysis import core, jaxprs
from repro.analysis.core import Finding, Program, Rule

#: Primitives that force a device->host round trip (or a host callback)
#: inside a traced computation: poison for a hot path, where one sync
#: serializes the device queue.
HOST_SYNC_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "host_callback_call", "infeed", "outfeed",
})

#: Collective primitive families the budget rule recognizes; anything in a
#: jaxpr that starts with one of these names is charged to that family
#: (e.g. ``all_gather_invariant`` -> ``all_gather``).
COLLECTIVE_FAMILIES = ("psum_scatter", "psum", "all_gather", "all_to_all",
                       "ppermute", "pbroadcast", "pmax", "pmin", "pgather",
                       "reduce_scatter")


def collective_family(prim: str) -> str:
    for fam in COLLECTIVE_FAMILIES:
        if prim.startswith(fam):
            return fam
    return ""


def resolve_budget(meta: dict):
    """Resolve a program's collective budget from its metadata.

    An explicit ``allowed_collectives`` tuple wins; otherwise
    ``adapter_kind`` is looked up in the method registry
    (``AdapterMethod.shard_collectives``).  Returns ``(allowed, None)``
    on success, ``(None, None)`` when the program opts into neither key
    (the rule skips it), and ``(None, reason)`` when the kind CANNOT
    resolve -- unregistered, or registered without the ``shards``
    capability.  Callers turn the reason into a clean severity-error
    Finding: an analyzer run over a misconfigured fixture must report
    the misconfiguration, not die mid-run on the registry's ValueError
    (both budget rules share this helper, so jaxpr and HLO agree)."""
    if "allowed_collectives" in meta:
        return frozenset(meta["allowed_collectives"]), None
    kind = meta.get("adapter_kind")
    if kind is None:
        return None, None
    from repro import methods
    try:
        method = methods.get(kind)
    except ValueError as e:
        return None, f"cannot resolve collective budget: {e}"
    if not method.supports_sharding:
        return None, (
            f"adapter kind {kind!r} has no `shards` capability "
            f"(shard_collectives={method.shard_collectives!r}): a sharded "
            f"program was built for a method that cannot shard -- methods "
            f"that can: {', '.join(methods.supporting('supports_sharding'))}")
    return frozenset(method.shard_collectives), None


@core.register
class NoDenseWInHbm(Rule):
    """The paper's matrix-free OFTv2 claim, as a detector: a fused program
    over a quantized (or frozen) base must never materialize a W-shaped
    float intermediate -- every dequant happens tile-by-tile in VMEM."""

    id = "no-dense-w-in-hbm"
    layer = "jaxpr"
    severity = core.ERROR
    description = ("fused fwd/bwd/multi jaxprs never materialize a "
                   "W-shaped dense/dequantized float intermediate in HBM "
                   "(pallas-internal VMEM tiles exempt)")

    def check(self, program: Program) -> List[Finding]:
        banned = {tuple(s) for s in
                  program.meta.get("banned_float_shapes", ())}
        if not banned or not program.jaxprs:
            return []
        findings = []
        shaped = jaxprs.float_outvar_shapes(program.jaxprs[0])
        if not shaped:
            findings.append(self.finding(
                program.name, "detector saw no float intermediates at all "
                "-- the traced program is empty or the walker regressed"))
        for shape, prim, path in shaped:
            if shape in banned:
                where = f"{program.name}::{'/'.join(path) or '<top>'}"
                findings.append(self.finding(
                    where, f"dense {shape} weight-shaped float "
                    f"materialized by `{prim}` -- the fused path must "
                    f"keep it in VMEM tiles"))
        return findings

    def fixture(self) -> Program:
        """A deliberately unfused quantized linear: dequantize the whole
        W, then matmul -- the (64, 48) dense weight hits HBM."""
        codes = jnp.zeros((64, 48), jnp.int8)
        absmax = jnp.ones((64 // 16, 48), jnp.float32)

        def unfused_linear(x, codes, absmax):
            w = codes.astype(jnp.float32).reshape(4, 16, 48)
            w = (w * absmax[:, None, :]).reshape(64, 48)   # dense dequant
            return x @ w

        jx = jaxprs.trace(unfused_linear, jnp.ones((8, 64)), codes, absmax)
        return Program("fixture/unfused-dequant-linear", [jx],
                       meta={"banned_float_shapes": {(64, 48)}})


@core.register
class CollectiveBudget(Rule):
    """Sharded programs emit ONLY the collectives their method's registry
    entry budgets (``AdapterMethod.shard_collectives``) -- generalizing
    the hardcoded psum-only gate so methods that legitimately need more
    (BOFT's cross-block mixing) declare it instead of bypassing the
    gate."""

    id = "collective-budget"
    layer = "jaxpr"
    severity = core.ERROR
    description = ("sharded jaxprs contain only the collectives budgeted "
                   "by the method registry's `shards` capability; "
                   "budgeted psums must actually appear when the model "
                   "axis is sharded")

    def check(self, program: Program) -> List[Finding]:
        allowed, reason = resolve_budget(program.meta)
        if reason is not None:
            return [self.finding(program.name, reason)]
        if allowed is None or not program.jaxprs:
            return []
        findings = []
        seen_families = set()
        for eqn, path in jaxprs.iter_eqns(program.jaxprs[0]):
            fam = collective_family(eqn.primitive.name)
            if not fam:
                continue
            seen_families.add(fam)
            if fam not in allowed:
                where = (f"{program.name}::"
                         f"{'/'.join(path) or '<top>'}")
                findings.append(self.finding(
                    where, f"collective `{eqn.primitive.name}` is outside "
                    f"the method's budget {sorted(allowed)}"))
        if (program.meta.get("model_shards", 1) > 1 and "psum" in allowed
                and "psum" not in seen_families):
            findings.append(self.finding(
                program.name, "model axis is sharded but no psum appears "
                "-- partial outputs are never reduced (or the program "
                "silently fell back to a replicated path)"))
        return findings

    def fixture(self) -> Program:
        """A program that all-gathers under oftv2's psum-only budget: the
        budget resolves through the method REGISTRY (``adapter_kind``
        metadata, the production path) and the gather must be flagged.
        ``axis_env`` traces the collective without devices."""
        def leaky(x):
            return jax.lax.psum(jax.lax.all_gather(x, "model"), "model")

        jx = jaxprs.trace(leaky, jnp.ones((4,)),
                          axis_env=[("model", 2)])
        return Program("fixture/extra-all-gather", [jx],
                       meta={"adapter_kind": "oftv2",
                             "model_shards": 2})


@core.register
class NoBakedScalar(Rule):
    """Traced block ids / step counters must stay traced: the program is
    traced at >= 2 different input VALUES (same shapes) and the
    structural fingerprints must be identical.  A divergence means some
    value was captured as a jaxpr constant -- the PR-6 block-table baking
    bug class, where every distinct id triggered its own XLA compile."""

    id = "no-baked-scalar"
    layer = "jaxpr"
    severity = core.ERROR
    description = ("traced scalars (block ids, adapter ids, step "
                   "counters) never bake into jaxprs as constants: "
                   "variant traces at different values fingerprint "
                   "identically")

    def check(self, program: Program) -> List[Finding]:
        if len(program.jaxprs) < 2:
            return []
        mask = bool(program.meta.get("mask_top_literals", False))
        prints = [jaxprs.structural_fingerprint(jx, mask_top_literals=mask)
                  for jx in program.jaxprs]
        findings = []
        for i, fp in enumerate(prints[1:], 1):
            if fp != prints[0]:
                findings.append(self.finding(
                    program.name,
                    f"variant trace {i} diverges from variant 0 -- a "
                    f"value is baked as a constant: "
                    f"{jaxprs.first_divergence(prints[0], fp)}"))
        return findings

    def fixture(self) -> Program:
        """A block id captured as a Python int: the two variants bake
        different constants and the fingerprints diverge."""
        pool = jnp.zeros((8, 4))

        def copy_with_baked_id(block_id):
            return lambda p: p.at[block_id].set(p[0])

        return Program(
            "fixture/baked-block-id",
            [jaxprs.trace(copy_with_baked_id(i), pool) for i in (3, 5)])


@core.register
class NoHostSync(Rule):
    """Hot paths (train step, decode tick, fused kernels) must stay on
    device: no pure_callback / debug printing / io_callback primitives
    anywhere in the trace."""

    id = "no-host-sync"
    layer = "jaxpr"
    severity = core.ERROR
    description = ("hot-path jaxprs contain no host-callback primitives "
                   "(pure_callback / debug.print / io_callback): nothing "
                   "forces a device-to-host sync per step")

    def check(self, program: Program) -> List[Finding]:
        if not program.meta.get("hot") or not program.jaxprs:
            return []
        findings = []
        for eqn, path in jaxprs.iter_eqns(program.jaxprs[0]):
            if eqn.primitive.name in HOST_SYNC_PRIMS:
                where = f"{program.name}::{'/'.join(path) or '<top>'}"
                findings.append(self.finding(
                    where, f"host-sync primitive `{eqn.primitive.name}` "
                    f"in a hot path"))
        return findings

    def fixture(self) -> Program:
        def chatty(x):
            jax.debug.print("x = {x}", x=x)
            return x + 1.0

        return Program("fixture/debug-print-in-hot-path",
                       [jaxprs.trace(chatty, jnp.ones((4,)))],
                       meta={"hot": True})
