"""repro.analysis: a unified static contract checker over jaxprs,
compiled HLO, and the Python AST (ISSUE-9).

One declarative rule engine, three walkers:

  * :mod:`repro.analysis.jaxprs` -- traverse closed jaxprs, recursing
    into nested pjit/scan/cond/custom_vjp/shard_map bodies (pallas
    kernel interiors stay out of scope; their HBM operands/results do
    not);
  * :mod:`repro.analysis.hlo` -- parse ``lowered.compile().as_text()``
    into an op stream (the layer where GSPMD-inserted collectives are
    visible);
  * :mod:`repro.analysis.pyast` -- parsed source modules (comments and
    docstrings can never trip a gate).

Rules implement the :class:`~repro.analysis.core.Rule` protocol (id,
layer, severity, description, ``check``, and a seeded known-bad
``fixture`` that proves the detector live).  ``python -m repro.analysis``
runs the full tree: AST rules over ``src/repro``, the jaxpr/HLO/trace
rules over representative fused, multi-adapter, serving, and sharded
programs (:mod:`repro.analysis.fixtures`), and -- given the artifacts --
the bench/metrics gates.  ``benchmarks/check_dispatch.py``,
``check_fusion.py`` and ``check_metrics.py`` are thin wrappers over this
engine.

Tests assert through :mod:`repro.analysis.checks`, so pytest and the CI
gate share one detector per contract.
"""
from repro.analysis.core import (BenchRows, ERROR, Finding, INFO, LAYERS,
                                 MetricsExport, Program, Report, Rule,
                                 SEVERITIES, TraceCounts, WARNING,
                                 all_rules, get, register, rules_for_layer,
                                 rules_table_md, run_layer, selftest)
from repro.analysis.checks import (assert_collective_budget,
                                   assert_no_dense_w,
                                   assert_no_host_sync,
                                   assert_no_w_gathers_hlo,
                                   assert_not_baked, assert_traces_once)
from repro.analysis.jaxprs import (first_divergence, float_outvar_shapes,
                                   float_shapes, iter_eqns,
                                   jaxpr_fingerprint, open_jaxpr,
                                   primitive_names, structural_fingerprint,
                                   subjaxprs, trace)

__all__ = [
    "BenchRows", "ERROR", "Finding", "INFO", "LAYERS", "MetricsExport",
    "Program", "Report", "Rule", "SEVERITIES", "TraceCounts", "WARNING",
    "all_rules", "get", "register", "rules_for_layer", "rules_table_md",
    "run_layer", "selftest",
    "assert_collective_budget", "assert_no_dense_w", "assert_no_host_sync",
    "assert_no_w_gathers_hlo", "assert_not_baked", "assert_traces_once",
    "first_divergence", "float_outvar_shapes", "float_shapes", "iter_eqns",
    "jaxpr_fingerprint", "open_jaxpr", "primitive_names",
    "structural_fingerprint", "subjaxprs", "trace",
]
