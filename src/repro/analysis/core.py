"""The contract-checker core: ``Finding``, the ``Rule`` protocol, the rule
registry, and the findings ``Report``.

A *rule* is one static contract the runtime cannot see (a fused kernel
never round-tripping dense W through HBM, a sharded path emitting only its
budgeted collectives, dispatch staying inside the method registry, ...).
Each rule declares the *layer* it inspects -- a traced jaxpr, the compiled
HLO text, the Python AST, a jit-cache trace count, or a benchmark/metrics
artifact -- and carries its own seeded known-bad **fixture**: a target that
MUST produce findings.  ``selftest(rule)`` runs the fixture, so every rule
in the registry is proven live (tests/test_analysis.py sweeps them all);
a rule whose detector silently rots fails its own fixture, not a future
incident review.

The walkers live next door (``jaxprs`` / ``hlo`` / ``pyast``), the shipped
rules in ``rules_*`` modules, and the representative traced programs of
the real tree in ``fixtures``.  ``python -m repro.analysis`` drives the
whole thing.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence

ERROR = "error"
WARNING = "warning"
INFO = "info"
SEVERITIES = (ERROR, WARNING, INFO)

#: Layers a rule can inspect.  ast/jaxpr/hlo are the ISSUE-9 tentpole
#: walkers; trace counts jit-cache growth; bench/metrics lift the legacy
#: check_fusion / check_metrics artifact gates onto the same engine.
LAYERS = ("ast", "jaxpr", "hlo", "trace", "bench", "metrics")


@dataclass
class Finding:
    """One contract violation, with enough provenance to act on:
    ``where`` is ``file:line`` for AST findings, ``program::eqn-path`` for
    jaxpr findings, and ``program::hlo:<line>`` for HLO findings."""
    rule: str
    severity: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule} @ {self.where}: {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "where": self.where, "message": self.message}


# ---------------------------------------------------------------------------
# targets (what a rule inspects, by layer)
# ---------------------------------------------------------------------------
@dataclass
class Program:
    """A traced program: one or more jaxpr variants (>= 2 means the traces
    were taken at different *values* of the same-shaped inputs, which the
    ``no-baked-scalar`` rule compares), optional compiled-HLO text, and
    rule-facing metadata:

    ``banned_float_shapes``  set of float shapes that must not appear as
                             jaxpr intermediates (``no-dense-w-in-hbm``);
    ``allowed_collectives``  the method's collective budget
                             (``collective-budget`` / HLO twin);
    ``model_shards``         model-axis size (psum presence is required
                             only when > 1);
    ``w_shapes``             trailing W shapes the HLO gather rule bans;
    ``hot``                  True marks a hot path (``no-host-sync``);
    ``mask_top_literals``    the no-baked-scalar fingerprint masks literal
                             values OUTSIDE the first jit boundary (set by
                             programs traced at an eager call site).
    """
    name: str
    jaxprs: List = field(default_factory=list)
    hlo: Optional[str] = None
    meta: dict = field(default_factory=dict)


@dataclass
class TraceCounts:
    """Jit-cache compile counts from a steady-state smoke:
    ``counts[label] = (compiles, budget)``; ``no-retrace`` flags any label
    whose compiles exceed its budget."""
    name: str
    counts: Dict[str, tuple] = field(default_factory=dict)


@dataclass
class BenchRows:
    """Rows of a ``benchmarks/run.py --json`` report (the fusion-plan and
    expect_ge ratio gates run over these)."""
    rows: List[dict] = field(default_factory=list)


@dataclass
class MetricsExport:
    """Merged ``{family: sample count}`` from live-smoke metrics.jsonl
    snapshots (the documented-schema export gate runs over this)."""
    samples: Dict[str, int] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Rule protocol + registry
# ---------------------------------------------------------------------------
class Rule:
    """One declarative contract.  Subclass, set the class attrs, implement
    ``check(target)`` for the layer's target type, and ``fixture()``
    returning a seeded known-bad target that ``check`` MUST flag."""

    id: str = ""
    layer: str = ""
    severity: str = ERROR
    description: str = ""          # one line; the README table renders it

    def check(self, target) -> List[Finding]:
        raise NotImplementedError(self.id)

    def fixture(self):
        raise NotImplementedError(self.id)

    def finding(self, where: str, message: str,
                severity: Optional[str] = None) -> Finding:
        return Finding(self.id, severity or self.severity, where, message)

    def __repr__(self) -> str:
        return f"<Rule {self.id!r} ({self.layer})>"


_RULES: Dict[str, Rule] = {}


def register(rule_cls):
    """Register a ``Rule`` subclass (usable as a class decorator).
    Duplicate ids are an error -- a silently shadowed gate is a gate that
    no longer gates."""
    rule = rule_cls() if isinstance(rule_cls, type) else rule_cls
    if not rule.id:
        raise ValueError(f"{rule!r} has no id")
    if rule.layer not in LAYERS:
        raise ValueError(f"rule {rule.id!r}: unknown layer {rule.layer!r} "
                         f"(layers: {', '.join(LAYERS)})")
    if rule.severity not in SEVERITIES:
        raise ValueError(f"rule {rule.id!r}: unknown severity "
                         f"{rule.severity!r}")
    if rule.id in _RULES:
        raise ValueError(f"rule {rule.id!r} already registered")
    _RULES[rule.id] = rule
    return rule_cls


def get(rule_id: str) -> Rule:
    try:
        return _RULES[rule_id]
    except KeyError:
        raise ValueError(f"unknown rule {rule_id!r}; registered: "
                         f"{', '.join(sorted(_RULES))}") from None


def all_rules() -> List[Rule]:
    _load_shipped()
    return [_RULES[k] for k in sorted(_RULES)]


def rules_for_layer(layer: str) -> List[Rule]:
    return [r for r in all_rules() if r.layer == layer]


def _load_shipped() -> None:
    """Import the shipped rule modules exactly once (registration is an
    import side effect, like ``repro.methods``)."""
    from repro.analysis import (rules_ast, rules_bench,  # noqa: F401
                                rules_hlo, rules_jaxpr, rules_trace)


def selftest(rule: Rule) -> List[Finding]:
    """Prove ``rule`` live: its seeded known-bad fixture must produce at
    least one finding.  Returns the findings for inspection."""
    findings = rule.check(rule.fixture())
    if not findings:
        raise AssertionError(
            f"rule {rule.id!r} reported ZERO findings on its own known-bad "
            f"fixture -- the detector is dead")
    return findings


def rules_table_md() -> str:
    """The shipped rule set as a markdown table.  README embeds this
    verbatim (``python -m repro.analysis --list-rules``) and
    tests/test_analysis.py pins the embed, like the capability matrix."""
    lines = ["| rule | layer | severity | checks |",
             "|---|---|---|---|"]
    for r in all_rules():
        lines.append(f"| `{r.id}` | {r.layer} | {r.severity} | "
                     f"{r.description} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------
@dataclass
class Report:
    """Everything one analysis run saw: findings, how many targets each
    layer covered, and what was skipped (and WHY -- a skipped sharded
    fixture must be visible, or 'ran clean' overstates the coverage)."""
    findings: List[Finding] = field(default_factory=list)
    checked: Dict[str, int] = field(default_factory=dict)
    skipped: List[str] = field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        for layer, n in other.checked.items():
            self.checked[layer] = self.checked.get(layer, 0) + n
        self.skipped.extend(other.skipped)
        return self

    def to_json(self) -> dict:
        return {"findings": [f.to_json() for f in self.findings],
                "checked": dict(self.checked),
                "skipped": list(self.skipped),
                "errors": len(self.errors)}

    def render(self) -> str:
        out = []
        for f in self.findings:
            out.append(str(f))
        cov = ", ".join(f"{layer}={n}" for layer, n in
                        sorted(self.checked.items())) or "nothing"
        out.append(f"analysis: checked {cov}; {len(self.findings)} "
                   f"finding(s), {len(self.errors)} at severity error")
        for note in self.skipped:
            out.append(f"analysis: skipped {note}")
        return "\n".join(out)

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


def run_layer(layer: str, targets: Iterable,
              rules: Optional[Sequence[Rule]] = None) -> Report:
    """Run every registered rule of ``layer`` (or the given subset) over
    each target; rules skip targets lacking their metadata by returning
    no findings."""
    picked = [r for r in (rules if rules is not None
                          else rules_for_layer(layer)) if r.layer == layer]
    report = Report()
    n = 0
    for target in targets:
        n += 1
        for rule in picked:
            report.findings.extend(rule.check(target))
    report.checked[layer] = n
    return report
