"""AST-layer rules: source-level contracts, checked on parsed code so
comments, strings, and docstrings can never trip a gate (the failure mode
of the retired line-regex ``check_dispatch``).
"""
from __future__ import annotations

import ast
from typing import List, Tuple

from repro.analysis import core, pyast
from repro.analysis.core import Finding, Rule
from repro.analysis.pyast import PyModule

#: Fallback adapter kinds for the dispatch rule when the registry is not
#: importable (e.g. analyzing a checkout without jax); kept in sync lazily
#: -- the live registry wins whenever it loads.
_KNOWN_KINDS = ("hoft", "lora", "none", "oftv1", "oftv2")


def _registered_kinds() -> Tuple[str, ...]:
    try:
        from repro import methods
        return methods.available()
    except Exception:
        return _KNOWN_KINDS


def _in_scope(module: PyModule, prefix: str = "src/repro/",
              exclude: Tuple[str, ...] = ()) -> bool:
    rel = module.relpath
    return rel.startswith(prefix) and not any(rel.startswith(e)
                                              for e in exclude)


def _is_kind_attr(node: ast.AST, owners=("acfg", "adapter")) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "kind"
            and isinstance(node.value, ast.Name)
            and node.value.id in owners)


def _is_any_kind(node: ast.AST) -> bool:
    return ((isinstance(node, ast.Name) and node.id == "kind")
            or (isinstance(node, ast.Attribute) and node.attr == "kind"))


@core.register
class RegistryDispatch(Rule):
    """Adapter-kind dispatch is allowed only inside ``repro.methods``:
    everywhere else, comparing / membership-testing / prefix-testing an
    adapter kind bypasses the registry the framework dispatches through.
    The AST port of benchmarks/check_dispatch.py -- same patterns, but a
    docstring QUOTING a banned pattern no longer fails the build."""

    id = "registry-dispatch"
    layer = "ast"
    severity = core.ERROR
    description = ("adapter-kind string dispatch (acfg.kind ==, is_oft, "
                   "kind in (...), kind.startswith) appears only inside "
                   "src/repro/methods/ -- matched on the AST, so "
                   "docstrings and comments are exempt")

    def check(self, module: PyModule) -> List[Finding]:
        if not _in_scope(module, exclude=("src/repro/methods/",)):
            return []
        # "none" is excluded from the literal-kind set: `self.kind !=
        # "none"` (has-an-adapter predicate) and `qcfg.kind == "none"`
        # (quant-kind dispatch, a different axis) are legitimate -- the
        # historical regex gate drew the same line
        kinds = set(_registered_kinds()) - {"none"}
        findings = []

        def flag(node: ast.AST, why: str) -> None:
            findings.append(self.finding(
                module.where(node),
                f"{module.line(node.lineno)!r}: {why}"))

        for node in pyast.walk(module.tree):
            if isinstance(node, ast.Attribute) and node.attr == "is_oft":
                flag(node, "is_oft predicate -- retired; use the "
                           "method's capability flags")
            elif isinstance(node, ast.Compare):
                sides = pyast.compare_sides(node)
                eq_like = all(isinstance(op, (ast.Eq, ast.NotEq))
                              for op in node.ops)
                in_like = any(isinstance(op, (ast.In, ast.NotIn))
                              for op in node.ops)
                if eq_like and any(_is_kind_attr(s) for s in sides):
                    flag(node, "adapter-kind comparison -- query "
                               "repro.methods instead")
                elif in_like and _is_kind_attr(node.left):
                    flag(node, "adapter-kind membership test (the old "
                               "is_oft shape) -- use the method's "
                               "capability flags")
                elif eq_like and any(_is_any_kind(s) for s in sides) and any(
                        isinstance(s, ast.Constant) and s.value in kinds
                        for s in sides):
                    flag(node, "adapter-kind literal comparison -- query "
                               "repro.methods instead")
                elif (eq_like and isinstance(node.left, ast.Name)
                      and node.left.id == "adapter"
                      and any(isinstance(s, ast.Constant)
                              and isinstance(s.value, str)
                              for s in node.comparators)):
                    flag(node, "adapter-kind literal comparison -- query "
                               "repro.methods instead")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr == "startswith"
                  and _is_kind_attr(node.func.value)):
                flag(node, "adapter-kind prefix test -- use the method's "
                           "capability flags")
        return findings

    def fixture(self) -> PyModule:
        """An out-of-registry dispatch branch -- plus a docstring and a
        comment quoting the same pattern, which must NOT flag (the regex
        gate's false positive, now fixed by construction)."""
        return pyast.parse_source(
            '"""Docs may say acfg.kind == "oftv2" freely."""\n'
            "def route(acfg, adapter, kind):\n"
            "    # comment: acfg.kind == 'lora' is also just prose\n"
            '    if acfg.kind == "oftv2":\n'
            "        return 1\n"
            '    if kind != "lora" or adapter.kind in ("oftv1",):\n'
            "        return 2\n"
            '    if adapter.kind.startswith("oft") or acfg.is_oft:\n'
            "        return 3\n",
            relpath="src/repro/serving/fixture_dispatch.py")


@core.register
class DocumentedMetrics(Rule):
    """Every literal ``obs.metric("...")`` call site statically resolves
    against the documented schema (``repro/obs/schema.py``) -- the static
    twin of the runtime KeyError, catching names that only fire on cold
    paths CI never executes."""

    id = "documented-metrics"
    layer = "ast"
    severity = core.ERROR
    description = ("every literal obs.metric(...) call-site name resolves "
                   "against the documented schema in repro/obs/schema.py")

    def check(self, module: PyModule) -> List[Finding]:
        if not _in_scope(module):
            return []
        try:
            from repro.obs import schema
            specs = schema.SPECS
        except Exception:                      # pragma: no cover
            return []
        findings = []
        for node in pyast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if pyast.call_name(node) != "metric":
                continue
            name = pyast.str_arg(node)
            if name is not None and name not in specs:
                findings.append(self.finding(
                    module.where(node),
                    f"metric {name!r} is not in the documented schema "
                    f"(repro/obs/schema.py) -- this call site raises the "
                    f"first time the path executes"))
        return findings

    def fixture(self) -> PyModule:
        return pyast.parse_source(
            "from repro import obs\n"
            "def tick():\n"
            '    obs.metric("serving/definitely_not_documented").inc()\n',
            relpath="src/repro/serving/fixture_metric.py")


@core.register
class NoWallclockInKernels(Rule):
    """Kernel modules never read the wall clock: their Python bodies run
    at TRACE time, so a ``time.time()`` there measures tracing (once) and
    silently lies forever after.  Timing belongs to the host-side obs
    layer around the jitted call."""

    id = "no-wallclock-in-kernels"
    layer = "ast"
    severity = core.ERROR
    description = ("src/repro/kernels/ never calls time.*/datetime.now: "
                   "kernel bodies run at trace time, so a wall-clock read "
                   "there measures tracing once and lies forever")

    BANNED = frozenset({
        "time.time", "time.time_ns", "time.perf_counter",
        "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
        "time.process_time", "time.sleep", "datetime.now",
        "datetime.utcnow", "datetime.datetime.now",
        "datetime.datetime.utcnow",
    })

    def check(self, module: PyModule) -> List[Finding]:
        if not module.relpath.startswith("src/repro/kernels/"):
            return []
        findings = []
        for node in pyast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = pyast.dotted(node.func)
            if name in self.BANNED:
                findings.append(self.finding(
                    module.where(node),
                    f"wall-clock call `{name}()` in a kernel module -- "
                    f"this executes at trace time, not per launch"))
        return findings

    def fixture(self) -> PyModule:
        return pyast.parse_source(
            "import time\n"
            "def kernel_entry(x):\n"
            "    t0 = time.perf_counter()\n"
            "    return x, t0\n",
            relpath="src/repro/kernels/fixture_timed.py")
