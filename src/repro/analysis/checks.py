"""Test-facing assertion wrappers: each one builds a target for exactly
the rule CI runs (``python -m repro.analysis``) and raises AssertionError
with the findings.  Tests become one-line callers of the shared engine --
the same detector fires in pytest and in the CI gate, so they cannot
drift apart (previously each test carried its own copy-pasted walker).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.analysis import core, hlo, jaxprs


def _run(rule_id: str, target) -> None:
    findings = core.get(rule_id).check(target)
    if findings:
        raise AssertionError(
            f"{rule_id}: {len(findings)} finding(s):\n"
            + "\n".join(f"  {f}" for f in findings))


def assert_no_dense_w(fn, args: Sequence, banned_shapes: Iterable[tuple],
                      name: str = "program") -> None:
    """The fused program of ``fn(*args)`` never materializes a float
    intermediate of any banned (W-like) shape outside VMEM tiles."""
    core._load_shipped()
    _run("no-dense-w-in-hbm", core.Program(
        name, [jaxprs.trace(fn, *args)],
        meta={"banned_float_shapes": {tuple(s) for s in banned_shapes}}))


def assert_collective_budget(fn, args: Sequence, model_shards: int,
                             kind: str = "oftv2",
                             allowed: Optional[Sequence[str]] = None,
                             name: str = "program") -> None:
    """``fn(*args)``'s jaxpr emits only the collectives budgeted by the
    ``kind`` method's registry entry (``shard_collectives``), and a
    budgeted psum actually appears when the model axis is sharded.

    When ``allowed`` is None the budget resolves through the rule's own
    registry lookup (``adapter_kind`` metadata), so an unregistered or
    shard-incapable ``kind`` surfaces as a rule finding in the
    AssertionError -- not a ValueError out of the registry."""
    core._load_shipped()
    meta = {"model_shards": int(model_shards)}
    if allowed is None:
        meta["adapter_kind"] = kind
    else:
        meta["allowed_collectives"] = tuple(allowed)
    _run("collective-budget", core.Program(
        name, [jaxprs.trace(fn, *args)], meta=meta))


def assert_no_w_gathers_hlo(fn, args: Sequence, cfg, kind: str = "oftv2",
                            allowed: Optional[Sequence[str]] = None,
                            name: str = "program") -> None:
    """Compiled-HLO twin of the collective budget: compile ``fn(*args)``
    under the ambient mesh and scan the optimized HLO -- no off-budget
    all-to-all, and no all-gather carrying a W / NF4-codes / absmax
    trailing shape of ``cfg`` (tiny adapter-state gathers are allowed).
    Like ``assert_collective_budget``, a None ``allowed`` defers to the
    rule's registry resolution of ``kind``."""
    core._load_shipped()
    meta = {"w_shapes": hlo.weight_shapes(cfg)}
    if allowed is None:
        meta["adapter_kind"] = kind
    else:
        meta["allowed_collectives"] = tuple(allowed)
    _run("hlo-collective-budget", core.Program(
        name, [], hlo=hlo.compile_text(fn, *args), meta=meta))


def assert_not_baked(make_fn, variants: Sequence[Sequence], *,
                     mask_top_literals: bool = False,
                     name: str = "program") -> None:
    """``make_fn(*variant)`` traced at every variant (same shapes,
    different values) fingerprints identically -- no value baked into the
    jaxpr as a constant."""
    core._load_shipped()
    _run("no-baked-scalar", core.Program(
        name, [jaxprs.trace(make_fn, *v) for v in variants],
        meta={"mask_top_literals": mask_top_literals}))


def assert_no_host_sync(fn, args: Sequence, name: str = "program") -> None:
    """``fn(*args)``'s jaxpr contains no host-callback primitives."""
    core._load_shipped()
    _run("no-host-sync", core.Program(
        name, [jaxprs.trace(fn, *args)], meta={"hot": True}))


def assert_traces_once(fn, calls: Sequence[Sequence], budget: int = 1,
                       name: str = "program") -> None:
    """Jit ``fn``, run every call, and require at most ``budget``
    compiles -- the steady-state no-retrace contract."""
    from repro.analysis import rules_trace
    core._load_shipped()
    _run("no-retrace", rules_trace.measure_jit(name, fn, calls, budget))
