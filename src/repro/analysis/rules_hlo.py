"""HLO-layer rules: what the compiler actually emitted, after GSPMD.

The jaxpr collective budget cannot see compiler-inserted resharding; this
is the compiled twin that caught PR 5's replicated-NF4-codes all-gather.
"""
from __future__ import annotations

from typing import List

from repro.analysis import core, hlo
from repro.analysis.core import Finding, Program, Rule
from repro.analysis.rules_jaxpr import resolve_budget


@core.register
class HloCollectiveBudget(Rule):
    """Compiled-HLO twin of ``collective-budget``: no collective opcode
    outside the method's budget -- with one tolerance, matching the
    historical gate: an off-budget ``all-gather`` is flagged only when its
    result carries a W / NF4-codes / absmax trailing shape.  GSPMD
    legitimately re-gathers tiny adapter state (q_packed, dR) around the
    concatenated rotation build; gathering a weight-shaped tensor is the
    scaling regression."""

    id = "hlo-collective-budget"
    layer = "hlo"
    severity = core.ERROR
    description = ("compiled HLO emits no off-budget collectives: no "
                   "all-to-all, and no all-gather whose result carries a "
                   "W/NF4-codes/absmax shape (GSPMD resharding caught "
                   "after the jaxpr layer goes blind)")

    def check(self, program: Program) -> List[Finding]:
        if program.hlo is None:
            return []
        allowed, reason = resolve_budget(program.meta)
        if reason is not None:
            return [self.finding(program.name, reason)]
        if allowed is None:
            return []
        w_shapes = {tuple(s) for s in program.meta.get("w_shapes", ())}
        findings = []
        for op in hlo.collectives(hlo.parse_hlo(program.hlo)):
            family = hlo.COLLECTIVE_FAMILY[op.opcode]
            if family in allowed:
                continue
            if op.opcode == "all-gather":
                gathered = [s for s in op.result_shapes
                            if len(s) >= 2 and s[-2:] in w_shapes]
                if not gathered:
                    continue
                msg = (f"all-gather of weight-shaped result(s) "
                       f"{gathered} -- the kernels must consume local "
                       f"shards")
            else:
                msg = (f"`{op.opcode}` is outside the method's budget "
                       f"{sorted(allowed)}")
            findings.append(self.finding(
                f"{program.name}::hlo:{op.lineno}", msg))
        return findings

    def fixture(self) -> Program:
        """Synthetic optimized-HLO with a W-shaped all-gather AND an
        all-to-all, against a psum-only budget: both must flag, while the
        budgeted all-reduce and a tiny (adapter-state) gather pass."""
        text = "\n".join([
            "HloModule fixture, is_scheduled=true",
            "ENTRY %main (p0: f32[8,8,48]) -> f32[8,64,48] {",
            "  %p0 = f32[8,8,48]{2,1,0} parameter(0)",
            "  %ar = f32[8,8,48]{2,1,0} all-reduce(f32[8,8,48]{2,1,0} "
            "%p0), replica_groups={}",
            "  %small = f32[8,4]{1,0} all-gather(f32[8,1]{1,0} %q), "
            "dimensions={1}",
            "  %bad = f32[8,64,48]{2,1,0} all-gather(f32[8,8,48]{2,1,0} "
            "%ar), dimensions={1}",
            "  %worse = f32[8,16]{1,0} all-to-all(f32[8,16]{1,0} %x), "
            "dimensions={0}",
            "}",
        ])
        return Program("fixture/w-gather-hlo", [], hlo=text,
                       meta={"allowed_collectives": ("psum",),
                             "w_shapes": {(64, 48)}})
