"""The Python AST walker: parse source files into ``PyModule`` targets the
AST rules inspect.

This replaces the line-regex idiom of the original
``benchmarks/check_dispatch.py`` gate: a regex cannot tell a banned
dispatch site from a docstring *mentioning* one (a comment quoting
``acfg.kind ==`` used to fail the build).  AST nodes are code by
construction -- comments never parse, and string constants are
``ast.Constant`` leaves no Compare/Attribute rule ever visits.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, List, Optional


@dataclass
class PyModule:
    """One parsed source file.  ``relpath`` is posix-style relative to the
    repo root -- rules scope themselves by it (e.g. the wallclock rule
    applies only under ``src/repro/kernels/``)."""
    path: Path
    relpath: str
    source: str
    tree: ast.Module

    def line(self, lineno: int) -> str:
        lines = self.source.splitlines()
        return lines[lineno - 1].strip() if 0 < lineno <= len(lines) else ""

    def where(self, node: ast.AST) -> str:
        return f"{self.relpath}:{getattr(node, 'lineno', 0)}"


def parse_module(path: Path, root: Optional[Path] = None) -> PyModule:
    path = Path(path)
    source = path.read_text()
    rel = (path.relative_to(root) if root and path.is_absolute()
           else path)
    return PyModule(path, rel.as_posix(), source,
                    ast.parse(source, filename=str(path)))


def parse_source(source: str, relpath: str = "<fixture>") -> PyModule:
    """A PyModule from literal source -- rule fixtures and tests."""
    return PyModule(Path(relpath), relpath, source, ast.parse(source))


def repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def iter_modules(root: Optional[Path] = None,
                 subdirs: tuple = ("src/repro",)) -> Iterator[PyModule]:
    """Every parseable ``*.py`` under ``root``'s ``subdirs`` as PyModule
    targets, sorted for stable reports."""
    root = Path(root) if root else repo_root()
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            yield parse_module(path, root)


def walk(tree: ast.AST) -> Iterator[ast.AST]:
    yield from ast.walk(tree)


def call_name(node: ast.Call) -> str:
    """The trailing name of a call target: ``obs.metric(...)`` ->
    ``metric``, ``metric(...)`` -> ``metric``, ``a.b.c(...)`` -> ``c``."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression: ``time.perf_counter`` ->
    ``'time.perf_counter'``; non-name parts collapse to ``?``."""
    if isinstance(node, ast.Attribute):
        return f"{dotted(node.value)}.{node.attr}"
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return dotted(node.func)
    return "?"


def str_arg(node: ast.Call, index: int = 0) -> Optional[str]:
    """The ``index``-th positional argument if it is a string literal."""
    if len(node.args) > index:
        arg = node.args[index]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return arg.value
    return None


def compare_sides(node: ast.Compare) -> List[ast.AST]:
    return [node.left, *node.comparators]
