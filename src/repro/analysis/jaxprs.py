"""The jaxpr walker: traverse closed jaxprs, recursing into every nested
sub-jaxpr a primitive carries in its params -- ``pjit`` bodies, ``scan`` /
``while`` / ``cond`` branches, ``custom_vjp``/``custom_jvp`` call jaxprs,
``shard_map`` bodies -- with one deliberate exception: ``pallas_call``
kernel bodies are NOT entered by default.  A Pallas kernel's inner tiles
live in VMEM; what the HBM-contract rules care about is the pallas_call
eqn's *own* operands and results (which are HBM buffers), so those are
always visited while the VMEM interior stays out of scope.

This is the single implementation of the jaxpr-walking idiom that used to
be copy-pasted across tests/test_fused_bwd.py (``_float_shapes`` /
``_subjaxprs``), tests/test_sharded_fused.py (``collect_prims``) and
tests/test_obs.py (``_jaxpr_str``).
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
from jax.extend.core import ClosedJaxpr, Jaxpr, Literal


def open_jaxpr(jx) -> Jaxpr:
    """Accept a ClosedJaxpr or a raw Jaxpr (or anything with ``.jaxpr``)."""
    if isinstance(jx, ClosedJaxpr):
        return jx.jaxpr
    if isinstance(jx, Jaxpr):
        return jx
    inner = getattr(jx, "jaxpr", None)
    if inner is not None:
        return open_jaxpr(inner)
    raise TypeError(f"not a jaxpr: {type(jx).__name__}")


def subjaxprs(val) -> Iterator[Jaxpr]:
    """Every jaxpr buried in one eqn-param value (params hold Jaxprs,
    ClosedJaxprs, and lists/tuples of either -- cond carries a tuple of
    branches, custom_vjp a closed call_jaxpr, ...)."""
    if isinstance(val, ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from subjaxprs(v)


def iter_eqns(jx, into_pallas: bool = False,
              _path: Tuple[str, ...] = ()) -> Iterator[tuple]:
    """Yield ``(eqn, path)`` for every eqn in ``jx`` and its sub-jaxprs.
    ``path`` is the chain of enclosing primitives (e.g. ``('pjit',
    'scan')``) -- the eqn-level provenance findings report."""
    for eqn in open_jaxpr(jx).eqns:
        name = eqn.primitive.name
        yield eqn, _path
        if name == "pallas_call" and not into_pallas:
            continue
        for val in eqn.params.values():
            for sub in subjaxprs(val):
                yield from iter_eqns(sub, into_pallas, _path + (name,))


def trace(fn, *args, axis_env: Optional[Sequence[tuple]] = None,
          **kwargs) -> ClosedJaxpr:
    """``jax.make_jaxpr`` with the axis_env passthrough the collective
    rules use to trace sharded bodies without devices."""
    if axis_env is not None:
        return jax.make_jaxpr(fn, axis_env=list(axis_env))(*args, **kwargs)
    return jax.make_jaxpr(fn)(*args, **kwargs)


def primitive_names(jx) -> Set[str]:
    """All primitive names anywhere in the jaxpr (sub-jaxprs included;
    pallas bodies excluded, like every walker here)."""
    return {eqn.primitive.name for eqn, _ in iter_eqns(jx)}


def float_outvar_shapes(jx) -> List[tuple]:
    """``(shape, primitive, path)`` for every floating-point eqn output.
    A pallas_call's own outvars ARE recorded (they are HBM buffers), its
    VMEM interior is not -- so a kernel that materializes a dense W to HBM
    (e.g. an unfused nf4 dequant) is caught while in-kernel tiles pass."""
    out = []
    for eqn, path in iter_eqns(jx):
        for v in eqn.outvars:
            aval = v.aval
            if (hasattr(aval, "shape") and hasattr(aval, "dtype")
                    and jnp.issubdtype(aval.dtype, jnp.floating)):
                out.append((tuple(aval.shape), eqn.primitive.name, path))
    return out


def float_shapes(jx) -> List[tuple]:
    """Just the shapes of :func:`float_outvar_shapes` (the historical
    tests/test_fused_bwd.py helper surface)."""
    return [s for s, _, _ in float_outvar_shapes(jx)]


def jaxpr_fingerprint(fn, *args, **kwargs) -> str:
    """The full printed jaxpr of ``fn(*args)`` -- the identity check the
    telemetry tests use (collectors on vs off must not perturb a trace)."""
    return str(jax.make_jaxpr(fn)(*args, **kwargs))


def _aval_str(v) -> str:
    aval = v.aval
    dtype = getattr(aval, "dtype", "?")
    return f"{dtype}{tuple(getattr(aval, 'shape', ()))}"


def _param_str(val) -> str:
    """Static eqn params, minus the sub-jaxprs (walked separately) and
    anything unhashably rich; slice starts / broadcast dims / static ints
    DO print, because a baked scalar often lands exactly there."""
    if isinstance(val, (ClosedJaxpr, Jaxpr)):
        return "<jaxpr>"
    if isinstance(val, (list, tuple)):
        return "(" + ",".join(_param_str(v) for v in val) + ")"
    if isinstance(val, (int, float, bool, str, bytes, type(None))):
        return repr(val)
    return type(val).__name__


def structural_fingerprint(jx, mask_top_literals: bool = False) -> str:
    """A value-sensitive canonical print of a jaxpr: primitive names,
    operand/result avals, static params -- and, crucially, **literal
    values**.  Two traces of the same function at different input VALUES
    (same shapes) produce identical fingerprints unless some value was
    baked into the trace as a constant: that divergence is exactly the
    block-table-baking bug class ``no-baked-scalar`` detects.

    ``mask_top_literals=True`` hides literal values at depth 0 only: a
    program traced at an *eager* call site (e.g. the serving engine
    calling an independently-jitted block copy with host-side ints) keeps
    those ints outside the jit boundary, where they are recompile-free by
    construction; values inside any nested jaxpr are always compared.
    """
    closed = jx if isinstance(jx, ClosedJaxpr) else None
    lines = []
    if closed is not None:
        for c in closed.consts:
            shape = tuple(getattr(c, "shape", ()))
            scalar = shape == () or (len(shape) == 1 and shape[0] == 1)
            # Top-level consts sit outside the first jit boundary exactly
            # like depth-0 literals (an eager `jnp.int32(x)` argument
            # closes over as a const) -- mask their values together.
            if scalar and not mask_top_literals:
                try:
                    lines.append(f"const={float(jnp.asarray(c))}")
                    continue
                except (TypeError, ValueError):
                    pass
            lines.append(f"const:{getattr(c, 'dtype', '?')}{shape}")

    def walk(jaxpr: Jaxpr, depth: int) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            ins = []
            for v in eqn.invars:
                if isinstance(v, Literal):
                    if depth == 0 and mask_top_literals:
                        ins.append(f"lit[{_aval_str(v)}]")
                    else:
                        ins.append(f"lit[{_aval_str(v)}]={v.val}")
                else:
                    ins.append(_aval_str(v))
            params = ",".join(f"{k}={_param_str(v)}"
                              for k, v in sorted(eqn.params.items()))
            outs = ",".join(_aval_str(v) for v in eqn.outvars)
            lines.append(f"{'.' * depth}{name}({';'.join(ins)})"
                         f"[{params}]->{outs}")
            if name == "pallas_call":
                continue
            for val in eqn.params.values():
                for sub in subjaxprs(val):
                    walk(sub, depth + 1)

    walk(open_jaxpr(jx), 0)
    return "\n".join(lines)


def first_divergence(a: str, b: str) -> str:
    """The first differing line of two structural fingerprints -- the
    provenance a no-baked-scalar finding reports."""
    for la, lb in zip(a.splitlines(), b.splitlines()):
        if la != lb:
            return f"{la!r} != {lb!r}"
    return f"fingerprint lengths differ ({len(a.splitlines())} vs "\
           f"{len(b.splitlines())} lines)"
