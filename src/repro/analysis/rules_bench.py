"""Bench/metrics-layer rules: the legacy artifact gates
(``benchmarks/check_fusion.py``, ``benchmarks/check_metrics.py``) lifted
onto the rule engine, so one CLI run gates code, traces, AND the smoke
artifacts -- and one findings report carries all the provenance.  The
benchmark scripts stay thin wrappers with their historical CLIs.
"""
from __future__ import annotations

from typing import List

from repro.analysis import core
from repro.analysis.core import BenchRows, Finding, MetricsExport, Rule

#: ISSUE-8 acceptance floor for the CI smoke: distinct documented
#: families that must carry samples.
MIN_SAMPLED_FAMILIES = 25


@core.register
class FusionPlan(Rule):
    """Every ``fusion_plan/.../expect_X`` row's dispatcher-chosen mode
    (``got=Y``) matches its expectation: a fused path silently falling
    back to the unfused oracle is a perf regression the test suite cannot
    see, since unfused is numerically identical."""

    id = "fusion-plan"
    layer = "bench"
    severity = core.ERROR
    description = ("no fusion_plan/* bench row fell back from its "
                   "expected fused mode (silent unfused fallbacks are "
                   "invisible to numeric tests); the plan rows must "
                   "exist at all")

    def check(self, target: BenchRows) -> List[Finding]:
        plan = [r for r in target.rows
                if r["name"].startswith("fusion_plan/")]
        if not plan:
            return [self.finding(
                "bench-report", "no fusion_plan/* rows in the report -- "
                "the benchmark no longer emits the plan")]
        findings = []
        for r in plan:
            expect = r["name"].rsplit("/expect_", 1)[-1]
            got = dict(kv.split("=", 1)
                       for kv in r["derived"].split(";"))["got"]
            if got != expect:
                findings.append(self.finding(
                    r["name"], f"fell back to '{got}'"))
        return findings

    def fixture(self) -> BenchRows:
        return BenchRows([{"name": "fusion_plan/layer/q/expect_qoft_fused",
                           "derived": "got=unfused"}])


@core.register
class RatioThreshold(Rule):
    """Every self-describing ``.../expect_ge_T`` ratio row measured at or
    above its threshold (serving speedups, load throughput/p99, obs
    overhead, resume parity -- any gate spelled in the row name)."""

    id = "ratio-threshold"
    layer = "bench"
    severity = core.ERROR
    description = ("every .../expect_ge_T bench ratio row (serving "
                   "speedup, load p99, obs overhead, ...) measured at or "
                   "above its self-declared threshold")

    def check(self, target: BenchRows) -> List[Finding]:
        findings = []
        for r in target.rows:
            if "/expect_ge_" not in r["name"]:
                continue
            threshold = float(r["name"].rsplit("/expect_ge_", 1)[-1])
            kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
            ratio = float(kv.get("ratio", kv.get("multi_over_seq")))
            if ratio < threshold:
                findings.append(self.finding(
                    r["name"],
                    f"measured {ratio:.2f}x (< {threshold}x)"))
        return findings

    def fixture(self) -> BenchRows:
        return BenchRows([{"name": "serving/speedup/n4/expect_ge_2.0",
                           "derived": "multi_over_seq=1.20"}])


@core.register
class MetricsSchema(Rule):
    """Live-smoke metric exports match the documented schema both ways:
    every documented family present, every smoke_required family sampled,
    no undocumented exports, and the ISSUE-8 coverage floor (>= 25
    sampled families spanning all four layers) holds."""

    id = "metrics-schema"
    layer = "metrics"
    severity = core.ERROR
    description = ("live-smoke metric exports match repro/obs/schema.py "
                   "both ways (documented families present + sampled, no "
                   "undocumented exports, >= 25 families across all four "
                   "layers)")

    def check(self, target: MetricsExport) -> List[Finding]:
        from repro.obs import schema
        merged = target.samples
        findings = []
        for name, spec in schema.SPECS.items():
            if name not in merged:
                findings.append(self.finding(
                    f"metrics::{name}", "documented family missing from "
                    "every artifact -- an instrumented call site was "
                    "deleted (or the exporter broke)"))
            elif spec.smoke_required and merged[name] == 0:
                findings.append(self.finding(
                    f"metrics::{name}", "smoke_required family has no "
                    "samples -- dead telemetry that looks alive in "
                    "/metrics"))
        for name in sorted(merged):
            if name not in schema.SPECS:
                findings.append(self.finding(
                    f"metrics::{name}", "exported family is not in the "
                    "documented schema (repro/obs/schema.py)"))
        sampled = {n for n, c in merged.items()
                   if c and n in schema.SPECS}
        if len(sampled) < MIN_SAMPLED_FAMILIES:
            findings.append(self.finding(
                "metrics::coverage",
                f"only {len(sampled)} documented families carry samples "
                f"(floor: {MIN_SAMPLED_FAMILIES})"))
        for layer in schema.LAYERS:
            if not any(schema.SPECS[n].layer == layer for n in sampled):
                findings.append(self.finding(
                    f"metrics::layer/{layer}",
                    f"no sampled family from the {layer!r} layer"))
        return findings

    def fixture(self) -> MetricsExport:
        """One undocumented export, one unsampled smoke_required family,
        and a coverage hole -- each strand of the gate fires."""
        from repro.obs import schema
        smoke = next(n for n, s in schema.SPECS.items() if s.smoke_required)
        return MetricsExport({smoke: 0, "bogus/family_total": 3})
