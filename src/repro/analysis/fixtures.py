"""Representative programs of the REAL tree for the analysis CLI: tiny
but faithful instances of every hot path the rules gate -- the fused
oftv2/qoft forward+backward kernels, the multi-adapter serving kernels,
a full NF4 fused train step, a paged serving engine driven through a
steady-state workload twice, and (devices permitting) the mesh-sharded
fused step with its compiled HLO.

Everything here mirrors an existing test/bench builder (obs_bench's
``_build_train``, test_serving_paged's ``_serving_model``,
test_sharded_fused's ``make_run``/``make_sharded``) at the same tiny
shapes, so one ``python -m repro.analysis`` run traces the same programs
CI already exercises -- and the rules see the tree as it is actually
executed, not a hand-maintained approximation.

``collect()`` returns programs + trace targets + explicit skip notes
(a sharded fixture that cannot run on this host is REPORTED skipped,
never silently dropped).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.analysis import hlo, jaxprs, rules_trace
from repro.analysis.core import Program, TraceCounts


# ---------------------------------------------------------------------------
# kernel-level programs (fused fwd+bwd, multi-adapter routing)
# ---------------------------------------------------------------------------
def _kernel_inputs(d=64, n=48, b=16, t=24, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.core import skew
    from repro.core.cayley import build_rotation
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, d), jnp.float32)
    w = jax.random.normal(key, (d, n), jnp.float32) / np.sqrt(d)
    qp = skew.random_skew(key, (d // b,), b, scale=0.1)
    r = build_rotation(qp, b, 5)
    return x, r, w


def kernel_programs() -> List[Program]:
    import jax
    import jax.numpy as jnp
    from repro.config.base import QuantConfig
    from repro.kernels import ops as kops
    from repro.quant import nf4

    d, n, b, bs = 64, 48, 16, 32
    x, r, w = _kernel_inputs(d, n, b)
    programs = []

    # fused OFTv2 fwd+bwd: hot path, host-sync-free
    def oftv2_loss(x, r, w):
        return jnp.sum(jnp.sin(kops.oftv2_linear_fused(x, r, w)))

    programs.append(Program(
        "kernels/oftv2_fused_grad",
        [jaxprs.trace(jax.grad(oftv2_loss, argnums=(0, 1, 2)), x, r, w)],
        meta={"hot": True}))

    # fused QOFT fwd+bwd: additionally, the dense (d, n) W must never
    # materialize as a float intermediate (the paper's memory claim)
    q = nf4.quantize(0.1 * w, QuantConfig(kind="nf4", block_size=bs,
                                          double_quant=False))

    def qoft_loss(x, r):
        return jnp.sum(kops.qoft_linear_fused(x, r, q["nf4_codes"],
                                              q["absmax"], bs))

    programs.append(Program(
        "kernels/qoft_fused_grad",
        [jaxprs.trace(jax.grad(qoft_loss, argnums=(0, 1)), x, r)],
        meta={"hot": True, "banned_float_shapes": {(d, n)}}))

    # multi-adapter routing kernel traced at two different adapter-id /
    # token mixes (same shapes): the trace must not depend on the values
    from repro.core import skew
    from repro.core.cayley import build_rotation
    key = jax.random.PRNGKey(1)
    r2 = build_rotation(skew.random_skew(key, (d // b,), b, scale=0.1), b, 5)
    r_stack = jnp.stack([r, r2])
    aid_a = np.array([0, 1, 0, 1], np.int32)
    aid_b = np.array([1, 0, 1, 1], np.int32)
    xb = jax.random.normal(key, (4, d), jnp.float32)

    def multi(aid):
        return lambda x, rs, w: kops.oftv2_linear_multi(x, rs, aid, w)

    programs.append(Program(
        "kernels/oftv2_multi_routing",
        [jaxprs.trace(multi(aid_a), xb, r_stack, w),
         jaxprs.trace(multi(aid_b), xb, r_stack, w)],
        meta={"hot": True, "mask_top_literals": True}))
    return programs


# ---------------------------------------------------------------------------
# train-step program (tiny NF4 fused model; obs_bench's builder shapes)
# ---------------------------------------------------------------------------
def _build_train():
    import jax
    import jax.numpy as jnp
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig, TrainConfig)
    from repro.data.loader import ShardedLoader
    from repro.data.synthetic import SyntheticSpec
    from repro.models import build
    from repro.train import state as state_lib
    from repro.train.step import make_train_step
    cfg = ModelConfig(name="analysis-train", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
                      d_ff=128, vocab_size=256, rope_theta=1e4)
    # seq_len 24 -> 48 tokens per step: the flattened activation shapes
    # (48, d) must NOT collide with any banned W shape (64, *) / (128, *),
    # or legitimate activations would read as dense-W materializations
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="nf4", block_size=32,
                                      double_quant=False),
                    train=TrainConfig(global_batch=2, seq_len=24, steps=1))
    model = build(run)
    state = state_lib.create(model.init(jax.random.PRNGKey(0)))
    step = make_train_step(model, run)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=24, kind="lm")
    loader = ShardedLoader(spec, global_batch=2, process_index=0,
                           process_count=1, seed=0)
    batch = jax.tree_util.tree_map(jnp.asarray, loader.next_batch())
    return run, step, state, batch


def _quantized_banned_shapes(run) -> set:
    """Every per-layer linear the fusion plan routes through qoft_fused:
    its dense (d_in, d_out) float shape is banned from the step's jaxpr --
    the no-dequant-to-HBM contract, derived from the SAME plan the
    check_fusion gate pins."""
    from repro.models.linears import layer_linear_shapes, model_fusion_plan
    plan = model_fusion_plan(run.model, run.adapter, run.quant)
    shapes = layer_linear_shapes(run.model)
    return {shapes[name] for name, mode in plan.items()
            if mode == "qoft_fused"}


def train_targets() -> Tuple[List[Program], List[TraceCounts]]:
    run, step, state, batch = _build_train()
    banned = _quantized_banned_shapes(run)
    program = Program(
        "train/nf4_fused_step",
        [jaxprs.trace(step, state, batch)],
        hlo=hlo.compile_text(step, state, batch),
        meta={"hot": True, "banned_float_shapes": banned,
              # single device: the compiled step must emit NO collectives
              "allowed_collectives": (),
              "w_shapes": hlo.weight_shapes(run.model)})
    counts = rules_trace.measure_jit(
        "train/nf4_fused_step", step,
        [(state, batch), (state, batch), (state, batch)], budget=1)
    return [program], [counts]


# ---------------------------------------------------------------------------
# paged serving engine: steady-state retrace accounting + value-baking
# ---------------------------------------------------------------------------
def _serving_setup():
    import jax
    from repro.config.base import (AdapterConfig, ModelConfig, QuantConfig,
                                   RunConfig)
    from repro.models import build
    from repro.serving import AdapterPool, init_adapters
    cfg = ModelConfig(name="analysis-serve", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, rope_theta=1e4)
    run = RunConfig(model=cfg,
                    adapter=AdapterConfig(kind="oftv2", block_size=16,
                                          neumann_terms=5,
                                          fuse_linear=True),
                    quant=QuantConfig(kind="none", block_size=32))
    model = build(run)
    params = model.init(jax.random.PRNGKey(0))
    pool = AdapterPool(model)
    for i, tree in enumerate(init_adapters(model, 2, jax.random.PRNGKey(7))):
        pool.register(f"t{i}", tree)
    return model, params, pool, cfg


def _requests(cfg, seed=3):
    import jax
    from repro.serving import Request, SamplingParams
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(seed), i), (n,), 0,
        cfg.vocab_size)) for i, n in enumerate([3, 6, 11, 9])]
    return [Request(f"r{i}", prompts[i], adapter_id=i % 2,
                    sampling=SamplingParams(max_new_tokens=4))
            for i in range(4)]


def _jit_snapshot(model) -> Dict[str, int]:
    from repro.serving import kv_cache
    snap = dict(rules_trace.model_cache_counts(model))
    snap["kv/copy_block"] = rules_trace.jit_cache_size(
        kv_cache._copy_block_fn)
    snap["kv/flush"] = rules_trace.jit_cache_size(kv_cache._flush_fn)
    return snap


def serving_targets() -> Tuple[List[Program], List[TraceCounts]]:
    import jax.numpy as jnp
    from repro.serving import ServingEngine, kv_cache

    model, params, pool, cfg = _serving_setup()

    def engine():
        return ServingEngine(model, params, pool, n_slots=4, mode="paged",
                             page_size=4, prefill_chunk=8)

    # warm every jit cache with one full drain, snapshot, then rerun the
    # IDENTICAL workload on a fresh engine: growth budget is zero
    engine().run(_requests(cfg))
    before = _jit_snapshot(model)
    eng = engine()
    orig_step, captured = eng._step_fn, {}

    def capturing_step(*args):
        captured.setdefault("args", args)
        return orig_step(*args)

    eng._step_fn = capturing_step
    eng.run(_requests(cfg))
    counts = rules_trace.steady_state_counts(
        "serving/paged_steady_state", before, _jit_snapshot(model))

    programs = []
    # the paged step traced at two value-perturbed copies of one real
    # tick's operands (token/adapter-id values changed, shapes identical):
    # the PR-6 bug class -- a block id / token value baked into the trace
    p, kv_pool, tok, pos, tables, aid = captured["args"]

    def step_at(tok_v, aid_v):
        return lambda p_, pool_: orig_step(p_, pool_, tok_v, pos, tables,
                                           aid_v)

    programs.append(Program(
        "serving/paged_step",
        [jaxprs.trace(step_at(tok, aid), p, kv_pool),
         jaxprs.trace(step_at((tok + 1) % cfg.vocab_size, 1 - aid),
                      p, kv_pool)],
        meta={"hot": True, "mask_top_literals": True}))

    # the paged-KV block copy invoked exactly like PagedKV._copy_block
    # does (eager host ints wrapped at the call site): different
    # src/dst/keep values must not perturb the trace
    def copy_at(src, dst, keep):
        return lambda pool_: kv_cache._copy_block_fn(
            pool_, jnp.int32(src), jnp.int32(dst), jnp.int32(keep))

    programs.append(Program(
        "serving/kv_block_copy",
        [jaxprs.trace(copy_at(3, 2, 2), kv_pool),
         jaxprs.trace(copy_at(1, 4, 3), kv_pool)],
        meta={"hot": True, "mask_top_literals": True}))
    return programs, [counts]


# ---------------------------------------------------------------------------
# mesh-sharded fused step (jaxpr + compiled-HLO collective budgets)
# ---------------------------------------------------------------------------
def sharded_targets() -> Tuple[List[Program], List[str]]:
    import jax
    n_dev = len(jax.devices())
    if n_dev >= 8:
        mesh_shape = (2, 4)
    elif n_dev >= 2:
        mesh_shape = (1, 2)
    else:
        return [], [f"sharded fixture: only {n_dev} device(s) visible "
                    f"(need >= 2; CI runs with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=8)"]

    from jax.sharding import NamedSharding
    from repro.config.base import (AdapterConfig, ModelConfig,
                                   ParallelConfig, QuantConfig, RunConfig,
                                   TrainConfig)
    from repro.distributed.sharding import (batch_spec, fit_tree,
                                            make_constrain,
                                            make_shard_context)
    from repro.models import build
    from repro.models.spec import rules_variant
    from repro.train import state as state_lib
    from repro.train.step import make_train_step

    pcfg = ParallelConfig(mesh_shape=mesh_shape,
                          mesh_axes=("data", "model"))
    cfg = ModelConfig(name="analysis-shard", num_layers=2, d_model=64,
                      num_heads=8, num_kv_heads=2, d_ff=256, vocab_size=256,
                      rope_theta=1e4).with_mesh_padding(pcfg.model_axis_size)
    # one psum-only method (oftv2: rotations shard like W, zero
    # resharding) and one that budgets a cross-shard exchange (boft: the
    # butterfly mixes blocks across K shards, so its sharded step
    # all-gathers activations by declaration)
    adapters = [
        AdapterConfig(kind="oftv2", block_size=16, neumann_terms=4,
                      fuse_linear=True),
        AdapterConfig(kind="boft", block_size=16, neumann_terms=4,
                      fuse_linear=True),
    ]
    mesh = jax.make_mesh(mesh_shape, pcfg.mesh_axes)
    rules = rules_variant(pcfg, "fused_tp")
    programs = []
    for acfg in adapters:
        run = RunConfig(
            model=cfg, adapter=acfg,
            quant=QuantConfig(kind="none", block_size=16),
            parallel=pcfg,
            train=TrainConfig(global_batch=8, seq_len=32,
                              learning_rate=1e-3, steps=1, warmup_steps=0))
        ctx = make_shard_context(mesh, rules, run)
        model = build(run, constrain=make_constrain(rules, mesh), shard=ctx)
        params = fit_tree(model.init(jax.random.PRNGKey(0)),
                          model.param_specs(rules), mesh)
        state = state_lib.create(params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": jax.device_put(
            tokens, NamedSharding(mesh, batch_spec(pcfg, 2)))}
        step = make_train_step(model, run)
        with mesh:
            # the budget comes from the METHOD's registry entry via the
            # rules' own `adapter_kind` resolution, not a hardcoded
            # psum-only list: a method that legitimately needs more
            # (boft) widens its own budget by declaring shard_collectives
            programs.append(Program(
                f"sharded/train_step/{acfg.kind}/"
                f"{mesh_shape[0]}x{mesh_shape[1]}",
                [jaxprs.trace(step, state, batch)],
                hlo=hlo.compile_text(step, state, batch),
                meta={"adapter_kind": acfg.kind,
                      "model_shards": pcfg.model_axis_size,
                      "w_shapes": hlo.weight_shapes(cfg)}))
    return programs, []


# ---------------------------------------------------------------------------
# the full collection the CLI drives
# ---------------------------------------------------------------------------
def collect(sharded: bool = True) -> dict:
    """All representative targets: ``{"programs": [...], "traces": [...],
    "skipped": [...]}``.  ``sharded=False`` leaves the mesh fixture out
    (and says so in ``skipped``) -- for fast local runs."""
    programs: List[Program] = []
    traces: List[TraceCounts] = []
    skipped: List[str] = []

    programs.extend(kernel_programs())

    t_programs, t_counts = train_targets()
    programs.extend(t_programs)
    traces.extend(t_counts)

    s_programs, s_counts = serving_targets()
    programs.extend(s_programs)
    traces.extend(s_counts)

    if sharded:
        m_programs, m_skips = sharded_targets()
        programs.extend(m_programs)
        skipped.extend(m_skips)
    else:
        skipped.append("sharded fixture: disabled (--no-sharded)")
    return {"programs": programs, "traces": traces, "skipped": skipped}
