"""Elastic restart: restore a checkpoint onto a different mesh/topology.

Checkpoints store *logical* (unsharded) arrays + the config hash; restoring
is therefore topology-free: we rebuild the target sharding from the new
mesh's rules and `jax.device_put` each leaf with its new NamedSharding.
A job checkpointed on 2x(16,16) pods restarts cleanly on (16,16), (8,8), or
a single host -- the elastic-scaling test exercises 1 -> {2,4}-device CPU
meshes end to end.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def reshard_tree(tree: Any, specs: Any, mesh: Optional[Mesh]):
    """device_put every leaf with its PartitionSpec under `mesh` (or leave on
    default device when mesh is None)."""
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, tree)

    def put(leaf, spec):
        spec = spec if spec is not None else PartitionSpec()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree, specs)
