"""Elastic restart: restore a checkpoint onto a different mesh/topology.

Checkpoints store *logical* (unsharded) arrays + the config hash; restoring
is therefore topology-free: we rebuild the target sharding from the new
mesh's rules and `jax.device_put` each leaf with its new NamedSharding.
A job checkpointed on 2x(16,16) pods restarts cleanly on (16,16), (8,8), or
a single host -- the chaos suite exercises save-on-(2,4) ->
resume-on-{(8,1),(4,2),(1,8),single-device} CPU meshes end to end with
loss-trajectory parity (tests/test_chaos.py).

Placement goes through ``fit_spec`` (distributed/sharding.py): a spec axis
that no longer divides the leaf's dim on the NEW mesh is dropped to
replicated rather than failing -- reshaping from a 4-way to an 8-way model
axis must not depend on every adapter dim happening to divide the new
axis size.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh


def reshard_tree(tree: Any, specs: Any, mesh: Optional[Mesh]):
    """device_put every leaf with its PartitionSpec fitted to the leaf's
    shape under `mesh` (or leave on the default device when mesh is
    None)."""
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, tree)
    from repro.distributed.sharding import fit_tree
    return fit_tree(jax.tree_util.tree_map(jax.numpy.asarray, tree),
                    specs, mesh)


def restore_elastic(manager, like: Any, specs: Any = None,
                    mesh: Optional[Mesh] = None,
                    step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore from ``manager`` (newest VALID step when ``step`` is None,
    checksum-verified with corrupt-latest fallback) and place the tree on
    ``mesh`` per ``specs`` -- the one-call elastic-resume entry point:

        state, meta = restore_elastic(mgr, like=state,
                                      specs=model.param_specs(rules),
                                      mesh=new_mesh)

    works no matter what mesh shape (or single device) the checkpoint was
    written under."""
    tree, meta = manager.restore(step, like=like)
    if mesh is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, tree), meta
    return reshard_tree(tree, specs, mesh), meta
